"""Paper Figure 5: DeepSeek-R1 1M-context Pareto frontier on GB200.

Reproduces the headline claims: Helix improves user interactivity by up to
~1.5x and supports up to ~32x more concurrent users (Tokens/s/GPU) vs the
best baseline (TP / TP+PP / EP / vanilla-KVP) — our analytical GB200 model
lands at ~1.7x / ~20x (EXPERIMENTS.md discusses the deltas)."""
from __future__ import annotations

from benchmarks.helix_sim import (BASELINES, DEEPSEEK_R1, GB200,
                                  batch_gain_at_fixed_ttl, frontier,
                                  max_interactivity_gain)

S = 1_000_000


def run(log=print):
    base = frontier(DEEPSEEK_R1, GB200, S, BASELINES)
    hx = frontier(DEEPSEEK_R1, GB200, S, ("helix",))
    log("# fig5: deepseek-r1 pareto (tok/s/user, tok/s/gpu, config)")
    log("frontier,tok_s_user,tok_s_gpu,cfg,batch")
    for name, front in (("baseline", base), ("helix", hx)):
        for x, y, (cfg, b) in front:
            log(f"{name},{x:.1f},{y:.2f},{cfg.strategy}"
                f"(tp{cfg.tp}.kvp{cfg.kvp}.tpf{cfg.tpf}.ep{cfg.ep}),{b}")
    ig = max_interactivity_gain(DEEPSEEK_R1, GB200, S)
    bg = batch_gain_at_fixed_ttl(DEEPSEEK_R1, GB200, S)
    log(f"# interactivity gain x{ig:.2f} (paper: up to 1.5x)")
    log(f"# concurrent-user/throughput gain x{bg:.1f} (paper: up to 32x)")
    return {"interactivity_gain": ig, "batch_gain": bg}


if __name__ == "__main__":
    run()
