"""Paper Figure 6: Llama-405B 1M-context Pareto frontier on GB200.

Headline claims: ~1.13x interactivity, ~4x throughput/batch capacity vs TP
sharding; Medha (vanilla KVP, FFN tied to TP<=K, comm exposed) sits between.
Our model: ~1.3x / ~4.8x."""
from __future__ import annotations

from benchmarks.helix_sim import (BASELINES, GB200, LLAMA_405B,
                                  batch_gain_at_fixed_ttl, frontier,
                                  max_interactivity_gain)

S = 1_000_000


def run(log=print):
    base = frontier(LLAMA_405B, GB200, S, BASELINES)
    medha = frontier(LLAMA_405B, GB200, S, ("kvp_medha",))
    hx = frontier(LLAMA_405B, GB200, S, ("helix",))
    log("# fig6: llama-405b pareto")
    log("frontier,tok_s_user,tok_s_gpu,cfg,batch")
    for name, front in (("baseline", base), ("medha", medha), ("helix", hx)):
        for x, y, (cfg, b) in front:
            log(f"{name},{x:.1f},{y:.2f},{cfg.strategy}"
                f"(tp{cfg.tp}.kvp{cfg.kvp}.tpf{cfg.tpf}),{b}")
    ig = max_interactivity_gain(LLAMA_405B, GB200, S)
    bg = batch_gain_at_fixed_ttl(LLAMA_405B, GB200, S)
    # Medha comparison: helix max interactivity vs medha's
    ig_medha = max(x for x, _, _ in hx) / max(x for x, _, _ in medha)
    log(f"# interactivity gain x{ig:.2f} (paper: 1.13x)")
    log(f"# throughput/batch gain x{bg:.1f} (paper: 4x)")
    log(f"# vs medha interactivity x{ig_medha:.2f} (paper: helix > medha; "
        f"medha exposes all comm + ties FFN to TP<=K)")
    return {"interactivity_gain": ig, "batch_gain": bg,
            "vs_medha": ig_medha}


if __name__ == "__main__":
    run()
