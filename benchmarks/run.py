"""Benchmark orchestrator — one target per paper table/figure.

  fig1   Appendix-A DRAM-read rooflines (paper Figure 1)
  fig5   DeepSeek-R1 1M-ctx Pareto (paper Figure 5: 1.5x TTL, 32x batch)
  fig6   Llama-405B 1M-ctx Pareto (paper Figure 6: 1.13x, 4x; + Medha)
  fig7   HOP-B ablation (paper Figure 7: ~12% / ~1%)
  roofline  §Roofline terms per (arch x shape) from dry-run artifacts
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig1_roofline, fig5_dsr1, fig6_llama405b,
                            fig7_hopb, roofline)

    t0 = time.time()
    ok = True
    for name, mod in (("fig1", fig1_roofline), ("fig5", fig5_dsr1),
                      ("fig6", fig6_llama405b), ("fig7", fig7_hopb)):
        print(f"\n===== {name} =====")
        try:
            mod.run()
        except AssertionError as e:
            ok = False
            print(f"[{name}] FAILED: {e}")
    print("\n===== roofline (16x16, from dry-run artifacts) =====")
    roofline.run()
    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s"
          + ("" if ok else " (WITH FAILURES)"))
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
