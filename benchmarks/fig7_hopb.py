"""Paper Figure 7: HOP-B ablation (batch-wise comm/compute overlap).

Claims: turning HOP-B off costs up to ~12% Tokens/s/User for Llama-405B;
~1% for DeepSeek-R1 at its (throughput-dominated) operating points, where
latent projections and multi-expert GEMMs dominate."""
from __future__ import annotations

from benchmarks.helix_sim import (DEEPSEEK_R1, GB200, LLAMA_405B,
                                  hopb_tsu_drop)

S = 1_000_000


def run(log=print):
    log("# fig7: HOP-B ON vs OFF, same config+batch along the helix frontier")
    log("model,max_drop_pct,throughput_end_drop_pct,paper")
    out = {}
    for m, paper in ((LLAMA_405B, "up to ~12%"), (DEEPSEEK_R1, "~1%")):
        mx, end = hopb_tsu_drop(m, GB200, S)
        log(f"{m.name},{mx * 100:.1f},{end * 100:.1f},{paper}")
        out[m.name] = {"max": mx, "throughput_end": end}
    return out


if __name__ == "__main__":
    run()
