"""Decode-attention microbenchmark: ref (pure jnp) vs the Pallas
flash-decode kernel, swept over KV length S.

  PYTHONPATH=src python benchmarks/bench_decode_kernel.py \
      [--backends ref pallas-interpret] [--s 4096 16384 65536] \
      [--batch 4] [--iters 20]

On CPU only `ref` and `pallas-interpret` are available; the interpreter's
wall-clock is NOT kernel performance (it executes the kernel body step by
step) — its purpose here is exercising the exact code path.  On a TPU host
pass ``--backends ref pallas`` for real numbers: the kernel streams the KV
shard HBM->VMEM once, which is the §2.1 DRAM-bound regime the paper's TTL
model assumes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention


def bench_one(backend: str, *, b: int, qh: int, kh: int, s: int, hsz: int,
              iters: int, warmup: int = 3) -> float:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, qh, hsz))
    k = jax.random.normal(ks[1], (b, kh, s, hsz))
    v = jax.random.normal(ks[2], (b, kh, s, hsz))
    total_len = s  # fully-populated cache: worst-case read volume

    fn = jax.jit(lambda q, k, v: decode_attention(
        q, k, v, total_len, backend=backend)[0])
    out = fn(q, k, v)
    out.block_until_ready()
    for _ in range(warmup - 1):
        fn(q, k, v).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(q, k, v)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(backends=("ref", "pallas-interpret"), s_values=(1024, 4096),
        b: int = 4, qh: int = 32, kh: int = 8, hsz: int = 128,
        iters: int = 10):
    dev = jax.devices()[0].platform
    print(f"[bench_decode_kernel] device={dev} B={b} Qh={qh} Kh={kh} "
          f"hsz={hsz} iters={iters}")
    kv_bytes = lambda s: 2 * b * kh * s * hsz * 4   # f32 K+V read volume
    header = f"{'S':>8s} " + "".join(f"{be:>20s}" for be in backends) \
        + f"{'KV bytes':>12s}"
    print(header)
    rows = []
    for s in s_values:
        times = [bench_one(be, b=b, qh=qh, kh=kh, s=s, hsz=hsz, iters=iters)
                 for be in backends]
        row = f"{s:>8d} " + "".join(f"{t * 1e3:>17.2f} ms" for t in times) \
            + f"{kv_bytes(s) / 2**20:>10.1f} Mi"
        print(row)
        rows.append((s, dict(zip(backends, times))))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", nargs="+",
                    default=["ref", "pallas-interpret"],
                    choices=["ref", "pallas-interpret", "pallas"])
    ap.add_argument("--s", nargs="+", type=int, default=[1024, 4096])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--qh", type=int, default=32)
    ap.add_argument("--kh", type=int, default=8)
    ap.add_argument("--hsz", type=int, default=128)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()
    run(backends=tuple(args.backends), s_values=tuple(args.s), b=args.batch,
        qh=args.qh, kh=args.kh, hsz=args.hsz, iters=args.iters)


if __name__ == "__main__":
    main()
