"""Decode-attention microbenchmark: ref (pure jnp) vs the Pallas
flash-decode kernel, swept over KV capacity S and cache fill — including
the fused KV-append epilogue vs the separate append_kv pass, and the
block-accounting numbers for length-aware pruning.

  PYTHONPATH=src python benchmarks/bench_decode_kernel.py \
      [--backends ref pallas-interpret] [--s 4096 16384 65536] \
      [--fill 1.0 0.25] [--batch 4] [--iters 20] \
      [--json BENCH_decode.json] [--no-fused] [--no-prune]

Each measured step is one *full decode attention step including the KV
append* (that is what serve_step pays per layer): append_kv + attention for
the unfused rows, the in-kernel append epilogue for the ``+fused`` rows.
``--fill`` sweeps the cache occupancy (total_len = fill * S): at fill < 1 a
slot-provisioned engine pays for dead capacity unless the kernel prunes it.

Results are also written as machine-readable JSON (default
``BENCH_decode.json``) so the perf trajectory is tracked across PRs:

  {"meta": {device, b, qh, kh, hsz, iters}, "rows":
   [{"s": 4096, "fill": 0.25, "total_len": 1024,
     "timings_ms": {"ref": ..., "pallas-interpret+fused": ...},
     "accounting": {"pruned": {blocks_visited, bytes_read, ...},
                    "dense":  {...}}}]}

The ``accounting`` block comes from ``flash_decode_accounting`` (the
registry's accounting layer): it replays the kernel's pruning index_map and
reports the K/V blocks/bytes the kernel actually streams from HBM — the
number that matters on TPU, where decode TTL is DRAM-bound (PAPER.md §1).
On CPU only `ref` and `pallas-interpret` are available; the interpreter's
wall-clock is NOT kernel performance (it executes the kernel body step by
step, and it also cannot elide the pruned blocks' DMAs — only the compiled
``pallas`` backend realizes the bytes_read reduction as time).  On a TPU
host pass ``--backends ref pallas`` for real numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.helix import append_kv
from repro.kernels.flash_decode import (flash_decode, flash_decode_ref,
                                        flash_decode_accounting)


def _mk(b, qh, kh, s, hsz):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (b, qh, hsz))
    k = jax.random.normal(ks[1], (b, kh, s, hsz))
    v = jax.random.normal(ks[2], (b, kh, s, hsz))
    kn = jax.random.normal(ks[3], (b, kh, hsz))
    vn = jax.random.normal(ks[4], (b, kh, hsz))
    return q, k, v, kn, vn


def bench_one(backend: str, *, b: int, qh: int, kh: int, s: int, hsz: int,
              iters: int, total_len: int | None = None, fused: bool = False,
              prune: bool = True, warmup: int = 3) -> float:
    """Mean seconds per decode-attention step (append + attend) at KV
    capacity ``s`` filled to ``total_len`` (default: full).  ``fused=True``
    uses the in-kernel append epilogue (Pallas backends only)."""
    q, k, v, kn, vn = _mk(b, qh, kh, s, hsz)
    total_len = s if total_len is None else total_len
    interpret = backend != "pallas"

    if fused:
        assert backend != "ref"

        def step(q, k, v, kn, vn):
            out, _, kc, vc = flash_decode(q, k, v, total_len, 0, kvp=1,
                                          k_new=kn, v_new=vn, prune=prune,
                                          interpret=interpret)
            return out, kc, vc
    elif backend == "ref":
        def step(q, k, v, kn, vn):
            kc, vc = append_kv(k, v, kn, vn, total_len, kvp=1, rr_block=16)
            out, _ = flash_decode_ref(q, kc, vc, total_len, 0, kvp=1)
            return out, kc, vc
    else:
        def step(q, k, v, kn, vn):
            kc, vc = append_kv(k, v, kn, vn, total_len, kvp=1, rr_block=16)
            out, _ = flash_decode(q, kc, vc, total_len, 0, kvp=1, prune=prune,
                                  interpret=interpret)
            return out, kc, vc

    fn = jax.jit(step)
    out = fn(q, k, v, kn, vn)[0]
    out.block_until_ready()
    for _ in range(warmup - 1):
        fn(q, k, v, kn, vn)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(q, k, v, kn, vn)[0]
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def _accounting(b, qh, kh, s, hsz, total_len):
    """Pruned vs dense K/V block accounting for one bench config, plus the
    shared-pool *paged* replay (page-table indirection; page size =
    ``page_positions(1, 16)`` — the serving engine's layout at KVP=1).
    Only shapes/dtypes are consumed, so ShapeDtypeStructs avoid
    materializing the (potentially multi-GiB) K/V tensors a second time."""
    import numpy as np
    from repro.core.kvcache import page_positions
    q = jax.ShapeDtypeStruct((b, qh, hsz), jnp.float32)
    k = v = jax.ShapeDtypeStruct((b, kh, s, hsz), jnp.float32)
    out = {}
    for label, prune in (("pruned", True), ("dense", False)):
        out[label] = flash_decode_accounting(q, k, v, total_len, 0, kvp=1,
                                             prune=prune)
    page = page_positions(1, 16)
    mp = -(-s // page)
    pool = jax.ShapeDtypeStruct((1 + b * mp, kh, page, hsz), jnp.float32)
    out["paged"] = flash_decode_accounting(
        q, pool, pool, total_len, 0, kvp=1, prune=True,
        block_tables=np.zeros((b, mp), np.int32))
    return out


def run(backends=("ref", "pallas-interpret"), s_values=(1024, 4096),
        fills=(1.0, 0.25), b: int = 4, qh: int = 32, kh: int = 8,
        hsz: int = 128, iters: int = 10, fused: bool = True,
        prune: bool = True, json_path: str | None = "BENCH_decode.json"):
    """Sweep ``backends`` (plus their fused-append variants) over KV
    capacities ``s_values`` x cache fills ``fills``; prints a table, records
    block/bytes accounting, and writes ``json_path``.  Returns the rows as
    ``[(s, fill, total_len, {label: seconds}, accounting)]``."""
    dev = jax.devices()[0].platform
    variants = [(be, False) for be in backends]
    if fused:
        variants += [(be, True) for be in backends if be != "ref"]
    labels = [be + ("+fused" if fz else "") for be, fz in variants]
    print(f"[bench_decode_kernel] device={dev} B={b} Qh={qh} Kh={kh} "
          f"hsz={hsz} iters={iters} prune={prune} "
          f"(append + attend per step)")
    header = f"{'S':>8s} {'fill':>5s} " \
        + "".join(f"{lb:>24s}" for lb in labels) \
        + f"{'KV read (pruned/dense)':>26s}"
    print(header)
    rows = []
    for s in s_values:
        for fill in fills:
            total_len = max(int(s * fill), 1)
            times = {lb: bench_one(be, b=b, qh=qh, kh=kh, s=s, hsz=hsz,
                                   iters=iters, total_len=total_len,
                                   fused=fz, prune=prune)
                     for lb, (be, fz) in zip(labels, variants)}
            acc = _accounting(b, qh, kh, s, hsz, total_len)
            row = f"{s:>8d} {fill:>5.2f} " \
                + "".join(f"{times[lb] * 1e3:>21.2f} ms" for lb in labels) \
                + (f"{acc['pruned']['bytes_read'] / 2**20:>12.1f}"
                   f" /{acc['dense']['bytes_total'] / 2**20:>9.1f} Mi")
            print(row)
            rows.append((s, fill, total_len, times, acc))
    if json_path:
        payload = {
            "meta": {"device": dev, "b": b, "qh": qh, "kh": kh, "hsz": hsz,
                     "iters": iters, "unit": "ms", "prune": prune,
                     "step": "append_kv + decode attention"},
            "rows": [{"s": s, "fill": fill, "total_len": total_len,
                      "timings_ms": {lb: t * 1e3 for lb, t in times.items()},
                      "accounting": acc}
                     for s, fill, total_len, times, acc in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[bench_decode_kernel] wrote {json_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", nargs="+",
                    default=["ref", "pallas-interpret"],
                    choices=["ref", "pallas-interpret", "pallas"])
    ap.add_argument("--s", nargs="+", type=int, default=[1024, 4096])
    ap.add_argument("--fill", nargs="+", type=float, default=[1.0, 0.25],
                    help="cache occupancy fractions (total_len = fill * S)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--qh", type=int, default=32)
    ap.add_argument("--kh", type=int, default=8)
    ap.add_argument("--hsz", type=int, default=128)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused KV-append epilogue variants")
    ap.add_argument("--no-prune", action="store_true",
                    help="run the Pallas kernel without block pruning")
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()
    run(backends=tuple(args.backends), s_values=tuple(args.s),
        fills=tuple(args.fill), b=args.batch, qh=args.qh, kh=args.kh,
        hsz=args.hsz, iters=args.iters, fused=not args.no_fused,
        prune=not args.no_prune, json_path=args.json or None)


if __name__ == "__main__":
    main()
