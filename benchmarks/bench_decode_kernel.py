"""Decode-attention microbenchmark: ref (pure jnp) vs the Pallas
flash-decode kernel, swept over KV length S — including the fused KV-append
epilogue vs the separate append_kv pass.

  PYTHONPATH=src python benchmarks/bench_decode_kernel.py \
      [--backends ref pallas-interpret] [--s 4096 16384 65536] \
      [--batch 4] [--iters 20] [--json BENCH_decode.json] [--no-fused]

Each measured step is one *full decode attention step including the KV
append* (that is what serve_step pays per layer): append_kv + attention for
the unfused rows, the in-kernel append epilogue for the ``+fused`` rows.

Results are also written as machine-readable JSON (default
``BENCH_decode.json``) so the perf trajectory is tracked across PRs:

  {"meta": {device, b, qh, kh, hsz, iters}, "rows":
   [{"s": 4096, "timings_ms": {"ref": 33.2, "pallas-interpret": ...,
                               "pallas-interpret+fused": ...}}]}

On CPU only `ref` and `pallas-interpret` are available; the interpreter's
wall-clock is NOT kernel performance (it executes the kernel body step by
step) — its purpose here is exercising the exact code path.  On a TPU host
pass ``--backends ref pallas`` for real numbers: the kernel streams the KV
shard HBM->VMEM once, which is the §2.1 DRAM-bound regime the paper's TTL
model assumes, and the fused epilogue additionally drops the append pass's
cache round-trip.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.helix import append_kv
from repro.kernels.flash_decode import flash_decode, flash_decode_ref


def _mk(b, qh, kh, s, hsz):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (b, qh, hsz))
    k = jax.random.normal(ks[1], (b, kh, s, hsz))
    v = jax.random.normal(ks[2], (b, kh, s, hsz))
    kn = jax.random.normal(ks[3], (b, kh, hsz))
    vn = jax.random.normal(ks[4], (b, kh, hsz))
    return q, k, v, kn, vn


def bench_one(backend: str, *, b: int, qh: int, kh: int, s: int, hsz: int,
              iters: int, fused: bool = False, warmup: int = 3) -> float:
    """Mean seconds per decode-attention step (append + attend) at KV
    length ``s``.  ``fused=True`` uses the in-kernel append epilogue
    (Pallas backends only)."""
    q, k, v, kn, vn = _mk(b, qh, kh, s, hsz)
    total_len = s  # fully-populated cache: worst-case read volume
    interpret = backend != "pallas"

    if fused:
        assert backend != "ref"

        def step(q, k, v, kn, vn):
            out, _, kc, vc = flash_decode(q, k, v, total_len, 0, kvp=1,
                                          k_new=kn, v_new=vn,
                                          interpret=interpret)
            return out, kc, vc
    elif backend == "ref":
        def step(q, k, v, kn, vn):
            kc, vc = append_kv(k, v, kn, vn, total_len, kvp=1, rr_block=16)
            out, _ = flash_decode_ref(q, kc, vc, total_len, 0, kvp=1)
            return out, kc, vc
    else:
        def step(q, k, v, kn, vn):
            kc, vc = append_kv(k, v, kn, vn, total_len, kvp=1, rr_block=16)
            out, _ = flash_decode(q, kc, vc, total_len, 0, kvp=1,
                                  interpret=interpret)
            return out, kc, vc

    fn = jax.jit(step)
    out = fn(q, k, v, kn, vn)[0]
    out.block_until_ready()
    for _ in range(warmup - 1):
        fn(q, k, v, kn, vn)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(q, k, v, kn, vn)[0]
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(backends=("ref", "pallas-interpret"), s_values=(1024, 4096),
        b: int = 4, qh: int = 32, kh: int = 8, hsz: int = 128,
        iters: int = 10, fused: bool = True,
        json_path: str | None = "BENCH_decode.json"):
    """Sweep ``backends`` (plus their fused-append variants) over KV lengths
    ``s_values``; prints a table and writes ``json_path``.  Returns the rows
    as ``[(s, {label: seconds})]``."""
    dev = jax.devices()[0].platform
    variants = [(be, False) for be in backends]
    if fused:
        variants += [(be, True) for be in backends if be != "ref"]
    labels = [be + ("+fused" if fz else "") for be, fz in variants]
    print(f"[bench_decode_kernel] device={dev} B={b} Qh={qh} Kh={kh} "
          f"hsz={hsz} iters={iters} (append + attend per step)")
    kv_bytes = lambda s: 2 * b * kh * s * hsz * 4   # f32 K+V read volume
    header = f"{'S':>8s} " + "".join(f"{lb:>24s}" for lb in labels) \
        + f"{'KV bytes':>12s}"
    print(header)
    rows = []
    for s in s_values:
        times = {lb: bench_one(be, b=b, qh=qh, kh=kh, s=s, hsz=hsz,
                               iters=iters, fused=fz)
                 for lb, (be, fz) in zip(labels, variants)}
        row = f"{s:>8d} " + "".join(f"{times[lb] * 1e3:>21.2f} ms"
                                    for lb in labels) \
            + f"{kv_bytes(s) / 2**20:>10.1f} Mi"
        print(row)
        rows.append((s, times))
    if json_path:
        payload = {
            "meta": {"device": dev, "b": b, "qh": qh, "kh": kh, "hsz": hsz,
                     "iters": iters, "unit": "ms",
                     "step": "append_kv + decode attention"},
            "rows": [{"s": s,
                      "timings_ms": {lb: t * 1e3 for lb, t in times.items()}}
                     for s, times in rows],
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[bench_decode_kernel] wrote {json_path}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backends", nargs="+",
                    default=["ref", "pallas-interpret"],
                    choices=["ref", "pallas-interpret", "pallas"])
    ap.add_argument("--s", nargs="+", type=int, default=[1024, 4096])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--qh", type=int, default=32)
    ap.add_argument("--kh", type=int, default=8)
    ap.add_argument("--hsz", type=int, default=128)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused KV-append epilogue variants")
    ap.add_argument("--json", default="BENCH_decode.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()
    run(backends=tuple(args.backends), s_values=tuple(args.s), b=args.batch,
        qh=args.qh, kh=args.kh, hsz=args.hsz, iters=args.iters,
        fused=not args.no_fused, json_path=args.json or None)


if __name__ == "__main__":
    main()
