"""Paper Figure 1: DRAM-read roofline from the Appendix-A formulas.

Dense LLM, B=8, Q=128 query heads, K=8 KV heads, Hsz=128, F=65536, FP4,
MemBW=8000 GB/s (the paper's stated assumptions).  Three panels:
  (left)   KV+weight read time vs TP width           -> plateau beyond TP=K
  (middle) read time vs context length S             -> attention dominates
  (right)  read time vs KVP width (helix)            -> sublinear KV scaling
"""
from __future__ import annotations

import math

BYTES = 0.5           # FP4
MEMBW = 8.0e12        # 8000 GB/s
B, Q, K, HSZ, F = 8, 128, 8, 128, 65_536
H = Q * HSZ


def kv_read_us(S, tpa=1, kvp=1):
    """Appendix A: B*2*ceil(K/TPA)*Hsz*(S/KVP)*bytes / MemBW  (per layer)."""
    return (B * 2 * math.ceil(K / tpa) * HSZ * (S / kvp) * BYTES) / MEMBW * 1e6


def weight_read_us(tpa=1, tpf=1):
    """Appendix A: ((2H*Q/TPA*Hsz)+(2H*ceil(K/TPA)*Hsz)+3HF/TPF)*bytes/BW."""
    w = ((2 * H * (Q / tpa) * HSZ)
         + (2 * H * math.ceil(K / tpa) * HSZ)
         + (3 * H * F / tpf)) * BYTES
    return w / MEMBW * 1e6


def panel_left(S=1_000_000):
    """Read time vs TP width: KV read plateaus once TP > K."""
    rows = []
    for tp in (1, 2, 4, 8, 16, 32, 64):
        rows.append({"tp": tp,
                     "kv_read_us": kv_read_us(S, tpa=tp),
                     "weight_read_us": weight_read_us(tpa=min(tp, K), tpf=tp)})
    return rows


def panel_middle(tp=8):
    rows = []
    for s in (65_536, 131_072, 262_144, 524_288, 1_048_576, 2_097_152,
              4_194_304):
        rows.append({"S": s, "kv_read_us": kv_read_us(s, tpa=tp),
                     "weight_read_us": weight_read_us(tpa=tp, tpf=tp)})
    return rows


def panel_right(S=1_000_000, tpa=8):
    rows = []
    for kvp in (1, 2, 4, 8, 16, 32, 64):
        n = kvp * tpa
        rows.append({"kvp": kvp,
                     "kv_read_us": kv_read_us(S, tpa=tpa, kvp=kvp),
                     "weight_read_us": weight_read_us(tpa=tpa, tpf=n)})
    return rows


def run(log=print):
    log("# fig1-left: read time vs TP width (S=1M) — plateau beyond TP=K=8")
    log("tp,kv_read_us,weight_read_us")
    for r in panel_left():
        log(f"{r['tp']},{r['kv_read_us']:.1f},{r['weight_read_us']:.1f}")
    log("# fig1-middle: read time vs S (TP=8)")
    log("S,kv_read_us,weight_read_us")
    for r in panel_middle():
        log(f"{r['S']},{r['kv_read_us']:.1f},{r['weight_read_us']:.1f}")
    log("# fig1-right: read time vs KVP width (S=1M, TPA=8, TPF=N)")
    log("kvp,kv_read_us,weight_read_us")
    for r in panel_right():
        log(f"{r['kvp']},{r['kv_read_us']:.1f},{r['weight_read_us']:.1f}")

    # the paper's two qualitative facts, asserted:
    left = panel_left()
    plateau = [r["kv_read_us"] for r in left if r["tp"] >= K]
    assert max(plateau) - min(plateau) < 1e-9, "KV read must plateau past K"
    right = panel_right()
    assert right[-1]["kv_read_us"] * 63 < right[0]["kv_read_us"] * 1.01, \
        "KVP must scale KV reads ~1/KVP"
    return {"left": left, "middle": panel_middle(), "right": right}


if __name__ == "__main__":
    run()
