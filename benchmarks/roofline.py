"""§Roofline: three-term analysis per (arch x shape) from dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() is per-device (verified during derisk), so no ÷chips.
MODEL_FLOPS = 6·N·D (train) or 2·N_active·D + attention/KV terms (serve);
the ratio MODEL_FLOPS / (HLO_FLOPs x chips) exposes remat/padding waste.

Usage: python -m benchmarks.roofline [--dir runs/dryrun] [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.models.transformer import layer_windows

# TPU v5e target (single chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape: str) -> float:
    """Useful (algorithmic) FLOPs for one step of this cell, whole system."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b = cell.global_batch
    n_active = cfg.active_params()
    wins = layer_windows(cfg)

    if cell.kind == "train":
        toks = b * cell.seq_len
        attn = 0.0
        if cfg.has_attention:
            for w in wins:
                s_eff = min(cell.seq_len, int(w) or cell.seq_len)
                attn += 2 * b * cell.seq_len * s_eff * cfg.q_dim
        return 6 * n_active * toks + 3 * attn
    if cell.kind == "prefill":
        toks = b * cell.seq_len
        attn = 0.0
        if cfg.has_attention:
            for w in wins:
                s_eff = min(cell.seq_len, int(w) or cell.seq_len)
                attn += 2 * b * cell.seq_len * s_eff * cfg.q_dim
        return 2 * n_active * toks + attn
    # decode: one token, KV history of seq_len
    flops = 2 * n_active * b
    if cfg.has_attention:
        for w in wins:
            s_eff = min(cell.seq_len, int(w) or cell.seq_len)
            flops += 4 * b * s_eff * cfg.q_dim
    if cfg.has_ssm:
        flops += 6 * b * cfg.n_layers * cfg.ssm_heads * cfg.ssm_headdim \
            * cfg.ssm_state
    return flops


def analytic_decode_bytes(arch: str, shape: str, chips: int) -> float:
    """Steady-state HBM bytes/device for one decode step (what a fused TPU
    backend actually moves): replicated QKV weight reads (the paper's §2.1.1
    design), sharded wo/FFN/MoE weight reads, KV shard read+append, head.

    The HLO 'bytes accessed' from the CPU-backend cost model over-counts
    dtype converts / layout copies that TPU fuses; both are reported."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    bp = 2.0                                       # bf16
    h = cfg.d_model
    per_layer = 0.0
    if cfg.has_attention:
        per_layer += (h * cfg.q_dim + 2 * h * cfg.kv_dim) * bp  # repl. QKV
        per_layer += cfg.q_dim * h * bp / chips                 # wo (TP=N)
        wins = layer_windows(cfg)
        s_eff = [min(s, int(w) or s) for w in wins]
        kv = sum(b * 2 * cfg.n_kv_heads * cfg.hsz * se * bp / chips
                 for se in s_eff) / cfg.n_layers
        per_layer += kv                                         # KV shard read
    if cfg.d_ff:
        per_layer += 3 * h * cfg.d_ff * bp / chips              # dense TPF=N
    if cfg.moe:
        m = cfg.moe
        ep = min(16, m.n_experts)
        active = min(m.n_experts / ep, b * m.topk)
        per_layer += active * 3 * h * m.d_ff * bp / (chips / ep)
        per_layer += h * m.n_experts * 4 / chips                # router f32
    if cfg.has_ssm:
        per_layer += (h * (2 * cfg.d_inner + 2 * cfg.ssm_ngroups
                           * cfg.ssm_state + cfg.ssm_heads)
                      + cfg.d_inner * h) * bp / 16              # model-axis TP
        per_layer += (b / min(b, 16)) * cfg.ssm_heads * cfg.ssm_headdim \
            * cfg.ssm_state * 4 * 2 / 16 * min(b, 16)           # state r/w
    total = cfg.n_layers * per_layer
    total += h * cfg.padded_vocab * bp / (16 if cfg.tie_embeddings else chips)
    total += b * h * bp * 4 * cfg.n_layers                      # activations
    return total


def analyze_record(rec: dict) -> dict:
    cost = rec.get("cost", {})
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_dev = sum(rec.get("collectives", {}).values())
    chips = CHIPS[rec["mesh"]]
    cell = SHAPES[rec["shape"]]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    t_mem_analytic = None
    if cell.kind == "decode":
        t_mem_analytic = analytic_decode_bytes(
            rec["arch"], rec["shape"], chips) / HBM_BW
        # fused-backend estimate replaces the unfused upper bound for the
        # dominant-term decision on decode cells
        t_memory_eff = t_mem_analytic
    else:
        t_memory_eff = t_memory
    terms = {"compute": t_compute, "memory": t_memory_eff,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    ratio = mf / max(flops_dev * chips, 1.0)
    # roofline fraction: useful-compute time over the bound term
    t_useful = mf / chips / PEAK_FLOPS
    frac = t_useful / max(t_bound, 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_analytic_s": t_mem_analytic,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_per_dev": flops_dev,
        "useful_ratio": ratio, "roofline_fraction": frac,
    }


def suggestion(row: dict) -> str:
    d = row["dominant"]
    if d == "memory":
        return ("memory-bound: cut bytes/step — weight quantization (w8a16 "
                "kernel), fp8/bf16 cache, or more TPF sharding of weight reads")
    if d == "collective":
        return ("collective-bound: shrink or overlap comm — HOP-B chunks, "
                "smaller a2a payload dtype, reduce-scatter instead of AR")
    return ("compute-bound: raise MXU utilization — larger effective tiles, "
            "fewer pad-lane FLOPs (useful_ratio), fuse elementwise chains")


def load(dir_: Path, mesh: str) -> list[dict]:
    rows = []
    for f in sorted(dir_.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "ok":
            rows.append(analyze_record(rec))
    return rows


def run(dir_="runs/dryrun", mesh="16x16", log=print):
    rows = load(Path(dir_), mesh)
    if not rows:
        log(f"# no dry-run artifacts under {dir_} for mesh {mesh}; "
            f"run repro.launch.dryrun first")
        return []
    log("arch,shape,compute_s,memory_hlo_s,memory_analytic_s,collective_s,"
        "dominant,useful_ratio,roofline_fraction")
    for r in rows:
        ma = r["t_memory_analytic_s"]
        log(f"{r['arch']},{r['shape']},{r['t_compute_s']:.3e},"
            f"{r['t_memory_s']:.3e},"
            f"{'' if ma is None else format(ma, '.3e')},"
            f"{r['t_collective_s']:.3e},"
            f"{r['dominant']},{r['useful_ratio']:.3f},"
            f"{r['roofline_fraction']:.3f}")
    log("# per-cell next lever (dominant-term):")
    seen = set()
    for r in rows:
        key = (r["dominant"],)
        if key in seen:
            continue
        seen.add(key)
        log(f"#   [{r['dominant']}] e.g. {r['arch']}/{r['shape']}: "
            f"{suggestion(r)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="16x16", choices=list(CHIPS))
    a = ap.parse_args()
    run(a.dir, a.mesh)
