"""Serving load sweep: TTFT / TTL / throughput per (load, chunk_tokens).

  PYTHONPATH=src python benchmarks/bench_serving.py \
      [--arch granite-3-2b] [--loads 0.25 1.0] [--chunks 0 8 32] \
      [--requests 16] [--prompt-len 48] [--max-new 8] \
      [--json BENCH_serving.json] [--smoke]

Replays a synthetic Poisson arrival process (``load`` = mean requests per
engine step) through the scheduler-driven continuous-batching engine
(serving/engine.py) once per (load, chunk_tokens) cell and records the
per-request latency summary — the numbers the paper is about: TTL (decode
token-to-token gap) must hold steady while prompts prefill concurrently.
``chunk_tokens = 0`` is the monolithic one-shot prefill baseline: every
in-flight decode stream stalls for the whole prompt, which shows up
directly in ``ttl_p95``.  Chunked rows bound that stall at one chunk.

Results land in machine-readable JSON (default ``BENCH_serving.json``;
schema asserted by ``scripts/check_bench_schema.py`` in CI so rows can't
silently drift):

  {"meta": {arch, device, requests, prompt_len, max_new, max_batch},
   "rows": [{"load": 1.0, "chunk_tokens": 8, "sched_policy": "fcfs",
             "ttft_p50_s": ..., "ttft_p95_s": ..., "ttl_p50_s": ...,
             "ttl_p95_s": ..., "queue_wait_p50_s": ...,
             "throughput_tok_s": ..., "n_finished": ...,
             "paged_kv": false, "pool_occupancy_peak": ...,
             "pool_frag_mean": ..., "capacity_retired": ...}]}

``--paged-kv`` doubles the sweep with shared-pool paged rows: the pool
columns record peak page occupancy and mean internal fragmentation of
allocated pages (zeros on fixed-cap rows) plus capacity retirements
(real count on both layouts — the paged/fixed token streams themselves
are bit-identical, which ``scripts/paged_smoke.py`` asserts in CI).

``--turns T`` appends a multi-turn row pair (history re-prefilled vs
``--session-kv`` host-tier restore): the ``turn2_ttft_s`` /
``restore_p95_ms`` / ``spills`` / ``restores`` columns quantify the host
KV tier, and the session row's ``resume_reprefill_chunks`` stays 0 —
turn>=2 prefill work is the fresh turn only, independent of history
length (asserted in ``--smoke``).

Every row is **trace-addressed**: the ``trace`` column is the
serving/workload.py ``trace_id`` of the exact workload the cell replayed
(``--trace FILE`` replays a saved trace instead of generating one), so a
measurement always names its load.  ``--tenants
"name[:weight[:slo[:share]]],..."`` benches a multi-tenant mix — each
cell then emits its aggregate row (tenant ``"*"``) plus one row per
tenant with that tenant's TTFT/TTL/goodput split — and ``--slo-ttl-ms``
arms the TTL governor (deterministic virtual clock), recording
``goodput_tok_s`` / ``ttl_target_miss_rate`` / ``governor_sheds`` per
row.

``--decode-window N`` runs every cell with N decode steps per device
dispatch (``--sampling`` picks the on-device sampling kind); each row's
``decode_window`` / ``syncs_per_token`` / ``sampling`` columns record the
measured host-sync rate (1.0 single-step, ~1/N windowed), and ``--smoke``
appends a window-1 vs window-4 row pair asserting the rate actually
dropped on the same workload.

On CPU the absolute times are dominated by XLA dispatch, not kernel work —
the *relative* one-shot-vs-chunked TTL spread is the signal tracked across
PRs; rerun on TPU for real latencies.  ``--smoke`` runs one tiny cell per
chunk setting (CI: proves the harness + schema end to end).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.launch.serve import serve_demo

ROW_SCHEMA = {
    "load": float, "chunk_tokens": int, "sched_policy": str,
    "ttft_p50_s": float, "ttft_p95_s": float,
    "ttl_p50_s": float, "ttl_p95_s": float,
    "queue_wait_p50_s": float, "throughput_tok_s": float,
    "n_finished": int, "n_tokens": int,
    # shared-pool paged KV cache health: peak pool occupancy and mean
    # internal fragmentation of allocated pages (zeros on fixed-cap rows),
    # plus how many requests were capacity-retired (real count on both
    # layouts)
    "paged_kv": bool, "pool_occupancy_peak": float,
    "pool_frag_mean": float, "capacity_retired": int,
    # prefix sharing: share of chunked admissions that matched a cached
    # prefix, and the peak count of pages mapped by >1 request (zeros on
    # rows without --prefix-share)
    "prefix_share": bool, "prefix_hit_rate": float,
    "pages_shared_peak": int,
    # host KV tier (--turns / --session-kv): turn count, spill/restore
    # totals, restore-latency p95, and mean TTFT of turn>=2 requests —
    # with session_kv it tracks the fresh turn length, not the growing
    # history (zeros on single-turn rows without a host store)
    "turns": int, "session_kv": bool,
    "spills": int, "restores": int, "restore_p95_ms": float,
    "resume_reprefill_chunks": int, "turn2_ttft_s": float,
    # multi-tenant SLO columns: the workload's trace_id (every row names
    # its exact load), which tenant/SLO-class slice the row aggregates
    # ("*" = all), SLO-goodput + interactive TTL-target miss rate, the
    # governor's TTL target (0 = unarmed) and how many batch slots it
    # shed to spill
    "trace": str, "tenant": str, "slo_class": str,
    "goodput_tok_s": float, "ttl_target_miss_rate": float,
    "slo_ttl_ms": float, "governor_sheds": int,
    # windowed decode + on-device sampling: decode steps per device
    # dispatch, blocking host syncs per decoded token (1.0 single-step,
    # ~1/N under --decode-window N) and the sampling kind ("greedy" =
    # the device argmax default)
    "decode_window": int, "syncs_per_token": float, "sampling": str,
}


def _latency_cols(agg: dict) -> dict:
    """ROW_SCHEMA latency/volume columns from one metrics aggregate
    (the whole-run summary or one per-tenant split)."""
    return {
        "ttft_p50_s": agg["ttft_s"]["p50"],
        "ttft_p95_s": agg["ttft_s"]["p95"],
        "ttl_p50_s": agg["ttl_s"]["p50"],
        "ttl_p95_s": agg["ttl_s"]["p95"],
        "queue_wait_p50_s": agg["queue_wait_s"]["p50"],
        "throughput_tok_s": agg["throughput_tok_s"],
        "goodput_tok_s": float(agg["goodput_tok_s"]),
        "ttl_target_miss_rate": float(agg["ttl_target_miss_rate"]),
        "n_finished": agg["n_finished"],
        "n_tokens": agg["n_tokens"],
    }


def bench_cell(arch: str, *, load: float, chunk_tokens: int,
               sched_policy: str, requests: int, prompt_len: int,
               max_new: int, max_batch: int, seed: int = 0,
               paged_kv: bool = False, prefix_share: bool = False,
               shared_prefix_len: int = 0, turns: int = 1,
               session_kv: bool = False, trace=None, tenants=None,
               slo_ttl_ms: float = 0.0, host_pages: int = 0,
               virtual_clock: bool = False, decode_window: int = 1,
               sampling: str | None = None) -> list[dict]:
    """One sweep cell -> ROW_SCHEMA rows: the aggregate row (tenant
    ``"*"``) first, then one per-tenant split row when the cell ran a
    multi-tenant mix — all addressed by the workload's ``trace_id``."""
    finished, summary = serve_demo(
        arch, reduced=True, n_requests=requests, prompt_len=prompt_len,
        max_new=max_new, max_batch=max_batch, chunk_tokens=chunk_tokens,
        sched_policy=sched_policy, traffic="poisson", arrival_rate=load,
        paged_kv=True if paged_kv else None, prefix_share=prefix_share,
        shared_prefix_len=shared_prefix_len,
        turns=turns, session_kv=session_kv,
        trace=trace, tenants=tenants, slo_ttl_ms=slo_ttl_ms,
        host_pages=host_pages, virtual_clock=virtual_clock,
        decode_window=decode_window, sampling=sampling,
        seed=seed, log=lambda s: None)
    base = {
        "load": float(load),
        "chunk_tokens": int(chunk_tokens),
        "sched_policy": sched_policy,
        **_latency_cols(summary),
        "paged_kv": bool(summary["paged_kv"]),
        "pool_occupancy_peak": float(summary["pool_occupancy_peak"]),
        "pool_frag_mean": float(summary["pool_frag_mean"]),
        "capacity_retired": int(summary["capacity_retired"]),
        "prefix_share": bool(prefix_share),
        "prefix_hit_rate": float(summary["prefix_hit_rate"]),
        "pages_shared_peak": int(summary["pages_shared_peak"]),
        "turns": int(turns),
        "session_kv": bool(session_kv),
        "spills": int(summary["spills"]),
        "restores": int(summary["restores"]),
        "restore_p95_ms": float(summary["restore_s"]["p95"] * 1e3),
        "resume_reprefill_chunks": int(summary["resume_reprefill_chunks"]),
        "turn2_ttft_s": float(summary["turn2_ttft_s"]),
        "trace": str(summary["trace_id"]),
        "tenant": "*",
        "slo_class": "*",
        "slo_ttl_ms": float(slo_ttl_ms),
        "governor_sheds": int(summary["governor_sheds"]),
        "decode_window": int(summary["decode_window"]),
        "syncs_per_token": float(summary["syncs_per_token"]),
        "sampling": str(sampling or "greedy"),
    }
    rows = [base]
    if tenants:
        # per-tenant split rows: same cell, same trace, one tenant's slice
        slo_of = {r.tenant: r.slo_class for r in finished}
        for name, agg in sorted(summary["per_tenant"].items()):
            if not agg["n_finished"]:
                continue
            rows.append({**base, **_latency_cols(agg), "tenant": name,
                         "slo_class": slo_of.get(name, "*")})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--loads", type=float, nargs="+", default=[0.25, 1.0])
    ap.add_argument("--chunks", type=int, nargs="+", default=[0, 8, 32],
                    help="chunk_tokens settings (0 = one-shot prefill)")
    ap.add_argument("--sched-policy", default="fcfs")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--paged-kv", action="store_true",
                    help="also sweep every cell with the shared-pool paged "
                         "KV cache (records pool occupancy / fragmentation "
                         "/ capacity retirements per row)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="also sweep paged+chunked cells with the prefix "
                         "index + copy-on-write page sharing on a "
                         "shared-prefix workload (records prefix_hit_rate "
                         "and pages_shared_peak per row)")
    ap.add_argument("--shared-prefix-len", type=int, default=32,
                    help="prefix-share rows: common leading tokens per "
                         "prompt")
    ap.add_argument("--turns", type=int, default=1,
                    help="multi-turn rows: each request is a session "
                         "resubmitting its full context plus fresh tokens "
                         "every turn (adds a session_kv off/on row pair)")
    ap.add_argument("--session-kv", action="store_true",
                    help="with --turns: also sweep the multi-turn rows with "
                         "host-tier session KV, so turn>=2 restores history "
                         "instead of re-prefilling it (turn2_ttft_s / "
                         "spills / restores columns)")
    ap.add_argument("--trace", default=None,
                    help="replay a saved serving/workload.py JSONL trace in "
                         "every cell instead of generating per-cell poisson "
                         "load (rows stay trace-addressed either way)")
    ap.add_argument("--tenants", default=None,
                    help="tenant mix 'name[:weight[:slo[:share]]],...' for "
                         "every cell; adds one split row per tenant next to "
                         "each aggregate row")
    ap.add_argument("--slo-ttl-ms", type=float, default=0.0,
                    help="arm the TTL governor in a dedicated 2-tenant "
                         "interactive+batch cell (virtual clock, host-tier "
                         "spill) with this interactive TTL p95 target")
    ap.add_argument("--decode-window", type=int, default=1,
                    help="decode steps per device dispatch for every sweep "
                         "cell (rows record it with their measured "
                         "syncs_per_token)")
    ap.add_argument("--sampling", default=None,
                    help="on-device sampling kind for every sweep cell "
                         "(greedy|temperature|top_k|top_p; default device "
                         "argmax)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI cell: one load, 4 requests, short prompts"
                         " (includes one paged + one prefix-share row, a"
                         " session-KV multi-turn row pair, a 2-tenant"
                         " TTL-governor cell with per-tenant split rows and"
                         " a decode-window-4 sampling row pair)")
    args = ap.parse_args()

    if args.smoke:
        args.loads, args.chunks = [1.0], [0, 4]
        args.requests, args.prompt_len, args.max_new = 4, 20, 8
        args.max_batch = 2
        args.paged_kv = True
        # the shared prefix must span >= 1 full page (kvp * rr_block = 16
        # positions here) for whole-page sharing — shorter prefixes only
        # exercise the KV-restore path and pages_shared_peak stays 0 —
        # and followers must arrive while the registrant still decodes
        # (max_new stretches its lifetime past the arrival gaps)
        args.prefix_share, args.shared_prefix_len = True, 16
        args.turns, args.session_kv = 3, True

    rows = []
    for load in args.loads:
        for chunk in args.chunks:
            for paged in ((False, True) if args.paged_kv else (False,)):
                shares = ((False, True)
                          if args.prefix_share and paged and chunk
                          else (False,))
                for share in shares:
                    cell = bench_cell(
                        args.arch, load=load, chunk_tokens=chunk,
                        sched_policy=args.sched_policy,
                        requests=args.requests,
                        prompt_len=args.prompt_len,
                        max_new=args.max_new,
                        max_batch=args.max_batch, paged_kv=paged,
                        prefix_share=share,
                        shared_prefix_len=(args.shared_prefix_len
                                           if share else 0),
                        trace=args.trace, tenants=args.tenants,
                        decode_window=args.decode_window,
                        sampling=args.sampling)
                    rows.extend(cell)
                    row = cell[0]
                    print(f"load={load:<5} chunk={chunk:<4} "
                          f"paged={int(paged)} share={int(share)} "
                          f"ttft_p95={row['ttft_p95_s']*1e3:8.1f}ms "
                          f"ttl_p95={row['ttl_p95_s']*1e3:8.1f}ms "
                          f"tput={row['throughput_tok_s']:7.1f} tok/s "
                          f"pool_occ={row['pool_occupancy_peak']:.2f} "
                          f"hit={row['prefix_hit_rate']:.2f}")

    if args.turns > 1:
        # multi-turn pair: history re-prefilled every turn vs restored from
        # the host tier — same workload, so the turn2_ttft_s delta (and the
        # session row's zero resume_reprefill_chunks) is the tier's win
        chunk = next((c for c in args.chunks if c), 8)
        for skv in ((False, True) if args.session_kv else (False,)):
            row = bench_cell(
                args.arch, load=args.loads[0], chunk_tokens=chunk,
                sched_policy=args.sched_policy, requests=args.requests,
                prompt_len=args.prompt_len, max_new=args.max_new,
                max_batch=args.max_batch, paged_kv=True,
                turns=args.turns, session_kv=skv)[0]
            rows.append(row)
            print(f"turns={args.turns} session_kv={int(skv)} "
                  f"chunk={chunk:<4} "
                  f"turn2_ttft={row['turn2_ttft_s']*1e3:8.1f}ms "
                  f"spills={row['spills']} restores={row['restores']} "
                  f"restore_p95={row['restore_p95_ms']:.1f}ms "
                  f"reprefill_chunks={row['resume_reprefill_chunks']}")
            if args.smoke and skv:
                # the independence-of-history proof, counted not timed:
                # every turn>=2 restored its history (no faults injected),
                # so prefill work per turn is the fresh tokens only
                assert row["restores"] > 0, row
                assert row["resume_reprefill_chunks"] == 0, row

    if args.slo_ttl_ms or args.smoke:
        # governor cell: a saturating 2-tenant interactive+batch mix under
        # the deterministic virtual clock — sheds batch slots to spill
        # (zero re-prefill) to hold the interactive TTL target; emits the
        # aggregate row plus one split row per tenant
        tenants = args.tenants or "chat:3:interactive,jobs:1:batch:3"
        slo_ms = args.slo_ttl_ms or 2.2
        cell = bench_cell(
            args.arch, load=2.0, chunk_tokens=4,
            sched_policy=args.sched_policy,
            requests=max(args.requests, 10), prompt_len=args.prompt_len,
            max_new=max(args.max_new, 6), max_batch=max(args.max_batch, 4),
            paged_kv=True, host_pages=64, tenants=tenants,
            slo_ttl_ms=slo_ms, virtual_clock=True, trace=args.trace)
        rows.extend(cell)
        row = cell[0]
        print(f"governor slo_ttl={slo_ms}ms tenants={tenants}: "
              f"sheds={row['governor_sheds']} "
              f"goodput={row['goodput_tok_s']:.1f} tok/s "
              f"miss={row['ttl_target_miss_rate']:.2f} "
              f"reprefill_chunks={row['resume_reprefill_chunks']}")
        if args.smoke:
            # the SLO story, counted not timed: pressure sheds batch work
            # through the spill tier (never re-prefilled), and both
            # tenants' split rows made it out
            assert row["governor_sheds"] >= 1, row
            assert row["resume_reprefill_chunks"] == 0, row
            assert {r["tenant"] for r in cell} >= {"*", "chat", "jobs"}, cell

    if args.smoke or args.decode_window > 1:
        # windowed-decode pair: the same sampled workload single-step and
        # with N steps per dispatch — columns carry the sync-rate story
        # (1.0 vs ~1/N); stream identity itself is asserted token-by-token
        # in scripts/decode_window_smoke.py
        win = args.decode_window if args.decode_window > 1 else 4
        pair = []
        for w in (1, win):
            row = bench_cell(
                args.arch, load=args.loads[0], chunk_tokens=4,
                sched_policy=args.sched_policy, requests=args.requests,
                prompt_len=args.prompt_len, max_new=args.max_new,
                max_batch=args.max_batch, decode_window=w,
                sampling=args.sampling or "top_p")[0]
            pair.append(row)
            rows.append(row)
            print(f"decode_window={w} sampling={row['sampling']}: "
                  f"syncs_per_token={row['syncs_per_token']:.3f} "
                  f"ttl_p95={row['ttl_p95_s']*1e3:8.1f}ms "
                  f"tput={row['throughput_tok_s']:7.1f} tok/s")
        if args.smoke:
            # same workload, same token volume, strictly fewer host syncs
            assert pair[0]["n_tokens"] == pair[1]["n_tokens"], pair
            assert pair[1]["syncs_per_token"] < pair[0]["syncs_per_token"], \
                pair
            assert pair[1]["decode_window"] == win, pair

    out = {"meta": {"arch": args.arch, "device": jax.devices()[0].platform,
                    "requests": args.requests, "prompt_len": args.prompt_len,
                    "max_new": args.max_new, "max_batch": args.max_batch,
                    "smoke": bool(args.smoke)},
           "rows": rows}
    with open(args.json, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[bench_serving] wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
