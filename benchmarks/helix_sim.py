"""GB200 NVL72 decode simulator — reproduces the paper's evaluation (§3).

An analytical performance model of one decode step (one token per request,
batch B, KV history S) for every sharding strategy in the paper's search
space:

  * TP            — Megatron tensor parallelism (KV duplication when TP>K)
  * TP x PP       — pipeline over layers (capacity, not TTL)
  * EP            — data-parallel attention + expert-parallel FFN (the
                    production DeepSeek-R1 recipe)
  * vanilla KVP   — Medha-style: KVP x TP attention, FFN tied to the TP
                    group only, all communication exposed
  * Helix (+HOP-B)— KVP x TPA attention -> TPF x EP FFN on the *same* N
                    GPUs; the all-to-all overlaps attention compute
                    batch-wise when HOP-B is on (§2.1.3)

Each component is a roofline term max(bytes/membw, flops/tflops) plus
explicit link terms for collectives; Appendix-A formulas are used verbatim
for the KV/weight read times (fig1 reproduces the paper's Figure 1 from
them).  All results are reported normalized to the best baseline, matching
the paper's protocol ("All performance numbers are normalized to that of
the baseline").
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


# --------------------------------------------------------------- hardware
@dataclass(frozen=True)
class HW:
    name: str = "GB200-NVL72-FP4"
    flops: float = 9e15            # dense FP4 FLOP/s per GPU
    membw: float = 8.0e12          # paper Fig1: 8000 GB/s HBM per GPU
    link_bw: float = 0.9e12        # NVLink per-GPU unidirectional B/s
    link_lat: float = 5e-6         # collective launch+switch latency
    #   (calibrated so the normalized trends match the paper's Figs 5-7;
    #    the paper's own simulator is in-house and unpublished)
    hbm_bytes: float = 186e9       # usable HBM per GPU
    bytes_param: float = 0.5       # FP4 weights & KV
    max_gpus: int = 64             # paper: 1-64 GPUs within one NVL72


GB200 = HW()


# ----------------------------------------------------------------- models
@dataclass(frozen=True)
class SimModel:
    name: str
    layers: int
    d_model: int
    q_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int                     # dense FFN (or shared-expert) intermediate
    n_experts: int = 0
    topk: int = 0
    expert_ff: int = 0
    vocab: int = 128_256
    # MLA overrides: latent KV stores ONE vector per token (factor 1, width
    # head_dim) and attention projections are low-rank (attn_params_1e6)
    kv_factor: int = 2            # 2 = separate K and V; 1 = shared latent
    attn_params_m: float = 0.0    # per-layer attn params (1e6); 0 = derive

    @property
    def q_dim(self):
        return self.q_heads * self.head_dim

    def attn_params_per_layer(self, tpa: int) -> float:
        """QKV (+out) projection params per GPU during attention."""
        if self.attn_params_m:
            return self.attn_params_m * 1e6 / tpa
        h, hsz = self.d_model, self.head_dim
        return (h * self.q_dim / tpa
                + self.kv_factor * h * math.ceil(self.kv_heads / tpa) * hsz)

    def total_params(self):
        h = self.d_model
        if self.attn_params_m:
            attn = self.attn_params_m * 1e6 + self.q_dim * h
        else:
            attn = (h * self.q_dim
                    + self.kv_factor * h * self.kv_heads * self.head_dim
                    + self.q_dim * h)
        per = attn + 3 * h * self.d_ff \
            + self.n_experts * 3 * h * self.expert_ff
        return self.layers * per + 2 * self.vocab * h


# paper §3.1 evaluation models
LLAMA_405B = SimModel("llama-405b", layers=126, d_model=16_384, q_heads=128,
                      kv_heads=8, head_dim=128, d_ff=53_248)
# DeepSeek-R1 with MLA at decode: a single 576-wide latent per token shared
# by all 128 query heads (paper §3.1); low-rank q/kv projections ~187M/layer;
# shared expert = dense d_ff 2048*9 approximates the 1 shared + routing mix.
DEEPSEEK_R1 = SimModel("deepseek-r1", layers=61, d_model=7_168, q_heads=128,
                       kv_heads=1, head_dim=576, d_ff=2_048,
                       n_experts=256, topk=8, expert_ff=2_048,
                       vocab=129_280, kv_factor=1, attn_params_m=187.0)


# ------------------------------------------------------- appendix A terms
def kv_read_time(m: SimModel, hw: HW, B, S, tpa, kvp):
    """Appendix A: B x f x ceil(K/TPA) x Hsz x (S/KVP) x bytes / MemBW
    (f = 2 for separate K/V heads, 1 for an MLA shared latent)."""
    return (B * m.kv_factor * math.ceil(m.kv_heads / tpa) * m.head_dim
            * (S / kvp) * hw.bytes_param) / hw.membw


def weight_read_time(m: SimModel, hw: HW, tpa, tpf):
    """Appendix A: ((2 H Q Hsz/TPA) + (2 H ceil(K/TPA) Hsz) + 3 H F / TPF)."""
    h, hsz = m.d_model, m.head_dim
    wbytes = ((2 * h * (m.q_heads / tpa) * hsz)
              + (2 * h * math.ceil(m.kv_heads / tpa) * hsz)
              + (3 * h * m.d_ff / tpf)) * hw.bytes_param
    return wbytes / hw.membw


# ---------------------------------------------------------- config space
@dataclass(frozen=True)
class ShardCfg:
    strategy: str                  # tp | tp_pp | ep | kvp_medha | helix
    tp: int = 1                    # TPA for helix/medha, plain TP otherwise
    kvp: int = 1
    tpf: int = 1                   # helix FFN TP width
    ep: int = 1
    pp: int = 1
    hopb: bool = True

    @property
    def n_gpus(self):
        if self.strategy == "helix":
            return self.kvp * self.tp * self.pp
        if self.strategy == "kvp_medha":
            return self.kvp * self.tp * self.pp
        if self.strategy == "ep":
            return self.ep * self.tp * self.pp
        return self.tp * self.pp


def _roof(hw: HW, bytes_, flops_):
    return max(bytes_ / hw.membw, flops_ / hw.flops)


def _ar_time(hw: HW, bytes_, width):
    """ring all-reduce: 2 (w-1)/w x bytes over the link + flat NVSwitch lat."""
    if width <= 1:
        return 0.0
    return 2 * bytes_ * (width - 1) / width / hw.link_bw + hw.link_lat


def _a2a_time(hw: HW, bytes_, width):
    """NVL72 NVSwitch: single-hop all-to-all, flat latency."""
    if width <= 1:
        return 0.0
    return bytes_ * (width - 1) / width / hw.link_bw + hw.link_lat


# ------------------------------------------------------------- decode TTL
def decode_ttl(m: SimModel, hw: HW, cfg: ShardCfg, B: int, S: int):
    """One-token TTL (s) and per-GPU memory (bytes); math.inf if infeasible."""
    bp = hw.bytes_param
    h, hsz = m.d_model, m.head_dim
    n = cfg.n_gpus
    if n > hw.max_gpus or B < 1:
        return math.inf, math.inf
    layers_per_stage = m.layers / cfg.pp

    # --- attention phase shards
    if cfg.strategy in ("helix", "kvp_medha"):
        tpa, kvp = cfg.tp, cfg.kvp
        if tpa > m.kv_heads:            # helix caps TPA at K by design
            return math.inf, math.inf
    elif cfg.strategy == "ep":
        tpa, kvp = cfg.tp, 1            # attention data-parallel over ep
    else:
        tpa, kvp = cfg.tp, 1

    # per-request batch handled per GPU during attention:
    if cfg.strategy == "ep":
        b_attn = math.ceil(B / cfg.ep)  # DP attention
    else:
        b_attn = B                      # full batch per rank (paper §2.1.1)

    # qkv projection (replicated across KVP ranks in helix/medha)
    qkv_params = m.attn_params_per_layer(tpa)
    t_qkv = _roof(hw, qkv_params * bp, 2 * b_attn * qkv_params)

    # kv read (+ attention flops)
    kv_heads_eff = math.ceil(m.kv_heads / tpa)
    t_kv = (b_attn * m.kv_factor * kv_heads_eff * hsz * (S / kvp) * bp) \
        / hw.membw
    attn_flops = 4 * b_attn * (m.q_dim / tpa) * (S / kvp)
    t_attn = max(t_kv, attn_flops / hw.flops)

    # helix / medha all-to-all (volume independent of S, §2.1.2; partial
    # outputs + LSE travel in bf16 regardless of the FP4 weight format)
    t_comm_attn = 0.0
    if cfg.strategy in ("helix", "kvp_medha") and kvp > 1:
        t_comm_attn = _a2a_time(hw, b_attn * (h / tpa) * 2.0, kvp)

    if cfg.strategy == "helix" and cfg.hopb and t_comm_attn > 0 \
            and b_attn > 1:
        # HOP-B (§2.1.3, Fig 3): requests pipeline — while request i's
        # all-to-all is in flight, request i+1 computes attention.  The span
        # is max(compute, comm) plus one exposed chunk of the other.
        per_req_comm = t_comm_attn / b_attn
        per_req_attn = t_attn / b_attn
        t_attn_phase = t_qkv + max(t_attn + per_req_comm,
                                   t_comm_attn + per_req_attn)
    else:
        t_attn_phase = t_qkv + t_attn + t_comm_attn

    # --- post-attention projection + FFN phase
    if cfg.strategy == "helix":
        tpo = cfg.kvp * cfg.tp          # out-proj TP = N (§2.2)
        tpf, ep = cfg.tpf, cfg.ep
        b_ffn = B
    elif cfg.strategy == "kvp_medha":
        tpo = cfg.tp                    # FFN tied to the TP group only
        tpf, ep = cfg.tp, 1
        b_ffn = B
    elif cfg.strategy == "ep":
        tpo = cfg.tp
        tpf, ep = cfg.tp, cfg.ep
        b_ffn = B                       # tokens all-to-all'd to experts
    else:
        tpo = cfg.tp
        tpf, ep = cfg.tp, 1
        b_ffn = B

    oproj_params = m.q_dim * h / tpo
    t_oproj = _roof(hw, oproj_params * bp, 2 * b_ffn * oproj_params) \
        + _ar_time(hw, b_ffn * h * bp, tpo)

    # dense/shared FFN
    ffn_params = 3 * h * m.d_ff / tpf
    t_ffn = _roof(hw, ffn_params * bp, 2 * b_ffn * ffn_params) \
        + _ar_time(hw, b_ffn * h * bp, tpf)

    # MoE experts
    t_moe = 0.0
    if m.n_experts:
        local_e = m.n_experts / ep
        active = min(local_e, b_ffn * m.topk / 1)    # distinct experts read
        moe_read = active * 3 * h * m.expert_ff / tpf * bp
        moe_flops = 2 * b_ffn * m.topk * 3 * h * m.expert_ff / (tpf * ep)
        t_moe = _roof(hw, moe_read, moe_flops)
        if ep > 1:                                    # dispatch/return a2a
            t_moe += 2 * _a2a_time(hw, b_ffn * h * m.topk / ep * bp, ep)

    t_layer = t_attn_phase + t_oproj + t_ffn + t_moe
    ttl = t_layer * layers_per_stage * cfg.pp        # token crosses stages
    ttl += _roof(hw, m.vocab * h / n * bp, 2 * B * m.vocab * h / n)  # lm head

    # --- memory feasibility per GPU
    kvf = m.kv_factor
    if cfg.strategy in ("helix", "kvp_medha"):
        kv_bytes = B * kvf * kv_heads_eff * hsz * (S / kvp) * bp \
            * layers_per_stage
    elif cfg.strategy == "ep":
        kv_bytes = math.ceil(B / cfg.ep) * kvf * kv_heads_eff * hsz * S * bp \
            * layers_per_stage
    else:
        kv_bytes = B * kvf * kv_heads_eff * hsz * S * bp * layers_per_stage
    mem = kv_bytes + m.total_params() / n * bp
    if mem > hw.hbm_bytes:
        return math.inf, mem
    return ttl, mem


# ------------------------------------------------------------ pareto sweep
def _pow2(limit):
    v = 1
    while v <= limit:
        yield v
        v *= 2


def sweep(m: SimModel, hw: HW, S: int, strategies, batches=None):
    """Yield (cfg, B, ttl, tok_s_user, tok_s_gpu)."""
    batches = batches or [1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
    for strat in strategies:
        for cfg in _configs(m, hw, strat):
            for b in batches:
                ttl, _ = decode_ttl(m, hw, cfg, b, S)
                if not math.isfinite(ttl):
                    continue
                yield (cfg, b, ttl, 1.0 / ttl, b / ttl / cfg.n_gpus)


def _configs(m: SimModel, hw: HW, strat: str):
    if strat == "tp":
        for tp in _pow2(hw.max_gpus):
            yield ShardCfg("tp", tp=tp)
    elif strat == "tp_pp":
        for tp in _pow2(hw.max_gpus):
            for pp in _pow2(hw.max_gpus // tp):
                yield ShardCfg("tp_pp", tp=tp, pp=pp)
    elif strat == "ep" and m.n_experts:
        for tp in _pow2(hw.max_gpus):
            for ep in _pow2(hw.max_gpus // tp):
                yield ShardCfg("ep", tp=tp, ep=ep)
    elif strat == "kvp_medha":
        for tp in _pow2(min(m.kv_heads, hw.max_gpus)):
            for kvp in _pow2(hw.max_gpus // tp):
                yield ShardCfg("kvp_medha", tp=tp, kvp=kvp)
    elif strat == "helix":
        for tp in _pow2(min(m.kv_heads, hw.max_gpus)):
            for kvp in _pow2(hw.max_gpus // tp):
                n = tp * kvp
                for ep in (_pow2(n) if m.n_experts else [1]):
                    if n % ep:
                        continue
                    tpf = n // ep
                    for hopb in (True,):
                        yield ShardCfg("helix", tp=tp, kvp=kvp, tpf=tpf,
                                       ep=ep, hopb=hopb)


def pareto(points):
    """points: iterable of (x=tok/s/user, y=tok/s/gpu, payload) — maximize."""
    pts = sorted(points, key=lambda p: (-p[0], -p[1]))
    front, best_y = [], -math.inf
    for x, y, payload in pts:
        if y > best_y:
            front.append((x, y, payload))
            best_y = y
    return front


def frontier(m: SimModel, hw: HW, S: int, strategies, hopb=True,
             batches=None):
    pts = []
    for cfg, b, ttl, tsu, tsg in sweep(m, hw, S, strategies, batches):
        if cfg.strategy == "helix" and not hopb:
            cfg = dataclasses.replace(cfg, hopb=False)
            ttl, _ = decode_ttl(m, hw, cfg, b, S)
            if not math.isfinite(ttl):
                continue
            tsu, tsg = 1.0 / ttl, b / ttl / cfg.n_gpus
        pts.append((tsu, tsg, (cfg, b)))
    return pareto(pts)


# ----------------------------------------------------------- paper claims
BASELINES = ("tp", "tp_pp", "ep", "kvp_medha")


def max_interactivity_gain(m: SimModel, hw: HW, S: int):
    """Helix max tok/s/user vs best baseline (paper: 1.5x DSR1, 1.13x Llama)."""
    base = frontier(m, hw, S, BASELINES)
    hx = frontier(m, hw, S, ("helix",))
    return max(x for x, _, _ in hx) / max(x for x, _, _ in base)


def batch_gain_at_fixed_ttl(m: SimModel, hw: HW, S: int):
    """"Up to Nx more concurrent users / higher Tokens/s/GPU under the same
    latency budget": max over TTL budgets of the throughput ratio between the
    Helix and best-baseline frontiers (paper: 32x DSR1, 4x Llama)."""
    base = frontier(m, hw, S, BASELINES)
    hx = frontier(m, hw, S, ("helix",))
    budgets = sorted({x for x, _, _ in base})
    best = 1.0
    for budget in budgets:
        best_b = max((y for x, y, _ in base if x >= budget), default=None)
        best_h = max((y for x, y, _ in hx if x >= budget), default=None)
        if best_b and best_h:
            best = max(best, best_h / best_b)
    return best


def hopb_tsu_drop(m: SimModel, hw: HW, S: int):
    """Tokens/s/user loss when HOP-B is turned off at the *same* operating
    point (config, batch) along the Helix frontier (Fig 7).

    Returns (max_drop, throughput_end_drop): the paper quotes the max for
    Llama-405B ("up to 12%") and the throughput end for DeepSeek-R1 ("~1%",
    where multi-expert GEMMs dominate and the all-to-all is amortized).
    """
    on = frontier(m, hw, S, ("helix",), hopb=True)
    drops = []
    for x_on, y_on, (cfg, b) in on:
        ttl_off, _ = decode_ttl(m, hw, dataclasses.replace(cfg, hopb=False),
                                b, S)
        if math.isfinite(ttl_off):
            drops.append((y_on, 1.0 - (1.0 / ttl_off) / x_on))
    if not drops:
        return 0.0, 0.0
    max_drop = max(d for _, d in drops)
    end_drop = max(drops, key=lambda t: t[0])[1]   # at max tok/s/gpu
    return max_drop, end_drop
