"""gemma3-12b [dense] — 5:1 local:global attention, 128k ctx.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.  head_dim=256
(gemma3 uses an explicit head_dim larger than d_model/n_heads).
Local layers use a 1024-token sliding window; every 6th layer is global.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab=262_144,
    act="gelu_gated",    # geglu
    local_window=1024,
    local_ratio=5,       # 5 local : 1 global
    tie_embeddings=True,
    softcap=30.0,
    supports_long_context=True,   # 5/6 of layers are O(window) at decode
)
