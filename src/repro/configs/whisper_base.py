"""whisper-base [audio] — enc-dec, arXiv:2212.04356.

6L (each side) d_model=512 8H (kv=8, MHA) d_ff=2048 vocab=51865.
Conv audio frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, S_enc, d_model]; the encoder is the transformer stack only.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51_865,
    act="gelu",
    use_rope=False,      # whisper: learned/sinusoidal positions
    is_encdec=True,
    enc_layers=6,
    enc_seq_ratio=1,
)
