"""mamba2-780m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1536, attention-free, d_ff=0 (the gated MLP lives inside the
mamba2 block's expand), vocab=50280, ssm_state=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_conv=4,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    use_rope=False,
    tie_embeddings=True,
    supports_long_context=True,   # O(1)-state decode
)
