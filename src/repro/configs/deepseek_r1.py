"""deepseek-r1 — the paper's MoE+MLA evaluation model (Fig 5).

Modeled for the simulator with MLA treated as K=1 latent attention
(the paper: "a single latent representation of both K and V for all 128
query heads").  61L d_model=7168, 128 query heads, 256 experts top-8 +
1 shared expert, expert d_ff=2048.  Simulator-only: we model MLA as GQA
with kv=1 and head_dim=576 (512 latent + 64 rope), which matches its
decode-time KV-cache footprint and read volume.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-r1",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=1,        # MLA latent: single shared KV representation
    head_dim=576,        # 512 latent + 64 decoupled-rope, decode-time
    d_ff=2048,           # shared expert (dense residual)
    vocab=129_280,
    moe=MoEConfig(n_experts=256, topk=8, d_ff=2048),
)
