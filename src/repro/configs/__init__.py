"""Architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, MoEConfig, ShapeCell, SHAPES,
                                cell_applicable)

# the 10 assigned architectures (40 shape-cells) + the paper's own two models
ASSIGNED = [
    "mamba2-780m",
    "hymba-1.5b",
    "granite-3-2b",
    "starcoder2-15b",
    "gemma3-12b",
    "granite-8b",
    "whisper-base",
    "granite-moe-1b-a400m",
    "arctic-480b",
    "phi-3-vision-4.2b",
]
PAPER_MODELS = ["llama-405b", "deepseek-r1"]


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    if name not in ASSIGNED + PAPER_MODELS:
        raise KeyError(f"unknown arch {name!r}; known: {ASSIGNED + PAPER_MODELS}")
    return importlib.import_module(_module_name(name)).CONFIG


def list_archs(include_paper: bool = False) -> list[str]:
    return list(ASSIGNED) + (list(PAPER_MODELS) if include_paper else [])


__all__ = ["ArchConfig", "MoEConfig", "ShapeCell", "SHAPES", "cell_applicable",
           "get_config", "list_archs", "ASSIGNED", "PAPER_MODELS"]
