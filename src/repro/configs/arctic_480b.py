"""arctic-480b [moe] — 128 experts top-2 + dense residual FFN.

35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000.
hf:Snowflake/snowflake-arctic-base.  Each layer runs a dense residual MLP
(d_ff=4864) in parallel with the routed MoE.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,           # dense residual MLP (parallel with MoE)
    vocab=32_000,
    moe=MoEConfig(n_experts=128, topk=2, d_ff=4864),
)
