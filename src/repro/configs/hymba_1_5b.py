"""hymba-1.5b [hybrid] — parallel attn+mamba heads, arXiv:2411.13676.

32L d_model=1600, 25 query heads (GQA kv=5), d_ff=5504, vocab=32001,
ssm_state=16.  Q=25/K=5 are padded to 32/8 logical heads for sharding
(DESIGN.md §5); zero rows in the out-projection make padding exact.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,        # 1600 / 25
    d_ff=5504,
    vocab=32_001,
    ssm_state=16,
    ssm_conv=4,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_ngroups=1,
    supports_long_context=True,   # hybrid: ssm path is O(1); attn uses KVP
)
