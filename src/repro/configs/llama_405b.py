"""llama-405b — the paper's dense GQA evaluation model (Fig 6).

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.  Used by the
GB200 simulator benchmarks and available as a full config for the dry-run
machinery (not part of the 40 assigned cells).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53_248,
    vocab=128_256,
)
