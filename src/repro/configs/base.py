"""ArchConfig: the single source of truth for every supported architecture.

Every assigned architecture (plus the paper's own Llama-405B / DeepSeek-R1
configs used by the simulator) is expressed as one frozen ``ArchConfig``.
The same config drives:

  * param init + the reference (GSPMD/train/prefill) forward pass,
  * the explicit-SPMD Helix decode path,
  * the dry-run input_specs / sharding policies,
  * the reduced smoke-test variant (``.reduced()``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.utils import round_up


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    topk: int
    d_ff: int                      # per-expert intermediate dim
    capacity_factor: float = 1.25  # train-time capacity factor
    decode_capacity_factor: float = 4.0
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # dense FFN intermediate (0 for pure-ssm)
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "silu"           # silu (gated) | gelu (ungated)
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    softcap: float = 0.0        # final-logit softcapping (gemma-style); 0=off

    # --- SSM (mamba2) ---
    ssm_state: int = 0          # dstate; 0 -> no ssm path
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1

    # --- local/global attention mix (gemma3) ---
    local_window: int = 0       # sliding window for local layers; 0=all global
    local_ratio: int = 0        # N local layers per 1 global (e.g. 5)

    # --- encoder-decoder (whisper) ---
    is_encdec: bool = False
    enc_layers: int = 0
    enc_seq_ratio: int = 1      # encoder frames per decoder token in shapes

    # --- vlm stub frontend ---
    vision_patches: int = 0     # patch embeds merged into prefix positions

    moe: MoEConfig | None = None

    # shape-cell applicability
    supports_long_context: bool = False  # sub-quadratic decode => long_500k runs

    # ------------------------------------------------------------------
    @property
    def hsz(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hsz

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hsz

    @property
    def padded_vocab(self) -> int:
        # 512 = max mesh size; keeps vocab-parallel shards even everywhere
        return round_up(self.vocab, 512)

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0 and self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        # mamba2: conv acts on (x, B, C) channels
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        h, f = self.d_model, self.d_ff
        per_layer = 0
        if self.has_attention:
            per_layer += h * self.q_dim + 2 * h * self.kv_dim + self.q_dim * h
        if self.has_ssm:
            per_layer += h * (2 * self.d_inner + 2 * self.ssm_ngroups * self.ssm_state
                              + self.ssm_heads)
            per_layer += self.conv_dim * self.ssm_conv
            per_layer += self.d_inner * h + 2 * self.ssm_heads  # out_proj, A, D
        if f:
            mult = 3 if self.act == "silu" else 2
            per_layer += mult * h * f
        if self.moe:
            m = self.moe
            per_layer += h * m.n_experts + m.n_experts * 3 * h * m.d_ff
        total = self.n_layers * per_layer
        if self.is_encdec:
            enc = self.enc_layers * (2 * (h * self.q_dim + 2 * h * self.kv_dim
                                          + self.q_dim * h) // 2 + 2 * h * f)
            cross = self.n_layers * (h * self.q_dim + 2 * h * self.kv_dim
                                     + self.q_dim * h)
            total += enc + cross
        total += self.vocab * h * (1 if self.tie_embeddings else 2)
        return total

    def active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.n_params()
        m = self.moe
        dense = self.n_params() - self.n_layers * m.n_experts * 3 * self.d_model * m.d_ff
        return dense + self.n_layers * m.topk * 3 * self.d_model * m.d_ff

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            # one full local:global period for windowed archs (decode scans
            # over periods), else 2 layers
            n_layers=(self.local_ratio + 1) if self.local_ratio
            else min(self.n_layers, 2),
            d_model=128,
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.has_ssm else self.ssm_headdim,
            enc_layers=min(self.enc_layers, 2),
            vision_patches=min(self.vision_patches, 8),
            local_window=min(self.local_window, 32) if self.local_window else 0,
        )
        if self.moe:
            # capacity_factor high enough that reduced configs never drop
            # tokens: keeps grouped/ungrouped/decode MoE layouts bitwise
            # comparable in equivalence tests (dropping has dedicated tests)
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 8),
                topk=min(self.moe.topk, 2), d_ff=64, capacity_factor=8.0)
        if self.family == "vlm":
            kw["n_kv_heads"] = kw["n_heads"]  # MHA family preserved
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Input-shape cells (assignment block). decode_*/long_* lower serve_step.
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeCell("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeCell("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeCell("long_500k",   524_288, 1,   "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped per assignment"
    return True, ""
