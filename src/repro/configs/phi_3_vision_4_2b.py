"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP (stubbed).

32L d_model=3072 32H (MHA, kv=32) d_ff=8192 vocab=32064.
The CLIP vision frontend is a STUB: input_specs() provides precomputed patch
embeddings [B, P, d_model] merged into the prefix token positions.

K=32 >= 16 means this arch supports the 2-D Helix mode (TPA=model, KVP=rest).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_064,
    vision_patches=256,
)
