"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) expert d_ff=512 vocab=49155.
hf:ibm-granite/granite-3.0-1b-a400m-base.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=0,              # FFN is fully MoE
    vocab=49_155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, topk=8, d_ff=512),
)
