"""starcoder2-15b [dense] — GQA + RoPE, arXiv:2402.19173.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24_576,
    vocab=49_152,
    act="gelu",          # starcoder2 uses a non-gated gelu MLP (4x)
)
