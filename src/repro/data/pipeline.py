"""Deterministic, step-indexed, shard-aware token pipeline.

Every batch is a pure function of (seed, step): restart/elastic-rescale
resumes bitwise-identically with zero pipeline state to checkpoint (only the
step counter, which lives in the optimizer state).  This is the property
1000-node fault tolerance needs — a restarted pod asks for step N and gets
exactly the batch every other pod computes.

Two sources:
  * synthetic  — structured pseudo-text (Zipf-ish unigram + short-range
                 copy patterns) so tiny-LM training visibly learns;
  * memmap     — fixed-shape binary token file (np.memmap), strided access.

``host_batch(step, host_id, num_hosts)`` returns only this host's rows —
shard-aware loading for multi-host (each host feeds its local devices via
jax.make_array_from_process_local_data at real scale).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"           # synthetic | memmap
    memmap_path: str | None = None


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "memmap":
            assert cfg.memmap_path, "memmap source needs memmap_path"
            self._data = np.memmap(Path(cfg.memmap_path), dtype=np.int32,
                                   mode="r")
            self._ntok = self._data.shape[0]

    # ---------------------------------------------------------------- core
    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Full global batch for ``step``: {tokens, labels} [B, T] int32."""
        cfg = self.cfg
        if cfg.source == "synthetic":
            toks = self._synthetic(step)
        else:
            toks = self._from_memmap(step)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def host_batch(self, step: int, host_id: int,
                   num_hosts: int) -> dict[str, np.ndarray]:
        b = self.batch(step)
        rows = self.cfg.global_batch // num_hosts
        sl = slice(host_id * rows, (host_id + 1) * rows)
        return {k: v[sl] for k, v in b.items()}

    # ------------------------------------------------------------- sources
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))

    def _synthetic(self, step: int) -> np.ndarray:
        """Zipf unigrams + copy motif: position t repeats t-gap with p=0.5."""
        cfg = self.cfg
        rng = self._rng(step)
        b, t1 = cfg.global_batch, cfg.seq_len + 1
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(cfg.vocab, size=(b, t1), p=probs)
        gap = 7
        copy_mask = rng.random((b, t1)) < 0.5
        copy_mask[:, :gap] = False
        idx = np.arange(t1)
        shifted = toks[:, np.maximum(idx - gap, 0)]
        return np.where(copy_mask, shifted, toks)

    def _from_memmap(self, step: int) -> np.ndarray:
        cfg = self.cfg
        b, t1 = cfg.global_batch, cfg.seq_len + 1
        span = b * t1
        start = (step * span) % max(self._ntok - span, 1)
        return np.asarray(self._data[start:start + span]).reshape(b, t1)
