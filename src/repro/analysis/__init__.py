"""Helix static contract checker (see docs/analysis.md).

Three analysis layers, each emitting ``Finding``s into one ``Report``:

  index_audit  — enumerates every grid step of every kernel-family
                 ``KernelContract`` and host-evaluates the real index_map
                 callables: in-bounds access (incl. paged table
                 indirection), the DMA-elision invariant of pruned steps,
                 and alias-race freedom of the fused-append row windows.
  jaxpr_audit  — traces the serving step functions and walks the jaxpr:
                 exactly one KVP combine (all_to_all + all_gather) per
                 attention layer, collectives only over mesh axes, no
                 fp64 upcasts, decode-state dtypes preserved.
  host_sync    — AST lint over ``serving/``/``launch/`` flagging
                 per-token device->host syncs (``int()``/``.item()`` on
                 device arrays, ``np.asarray`` in loops,
                 ``block_until_ready``), with a baseline for the
                 intentional batched transfer.

``scripts/analyze.py`` is the CLI front-end (gated in CI via
``scripts/ci.sh`` / ``make analyze``).
"""
from repro.analysis.findings import (CHECKS, Finding, Report,
                                     load_baseline)  # noqa: F401
from repro.analysis.host_sync import lint_paths, lint_source  # noqa: F401
from repro.analysis.index_audit import (audit_contract,
                                        run_index_audit)  # noqa: F401
from repro.analysis.jaxpr_audit import (audit_step_fn, collect_collectives,
                                        run_jaxpr_audit)  # noqa: F401
