"""Jaxpr collective/dtype auditor for the serving hot path.

Traces the decode/prefill step builders (``jax.make_jaxpr`` on a 1x1 mesh —
collective equations are recorded even at axis size 1) and walks every
equation, recursing through ``scan``/``shard_map``/``pjit`` sub-jaxprs, to
assert the HOP-B dataflow of §3 of the paper:

  collective.count  exactly one KVP combine per attention layer — one
                    ``all_to_all`` (the TPA resharding of output fragments)
                    plus one ``all_gather`` (the LSE exchange) over the KVP
                    axes, and no stray ``psum`` over them.  A duplicated
                    combine doubles the per-token communication the paper's
                    TTL model budgets; a missing one is a miscompile.
  collective.axis   every collective names only mesh axes, and the
                    attention combines run over exactly the KVP axes.
  dtype.upcast      no fp64 values anywhere in the traced step, and the
                    decode-state leaves (KV cache, SSM state) keep their
                    dtypes through the step (``jax.eval_shape``) — a silent
                    int8 -> f32 cache upcast would 4x the paper's KV-cache
                    DRAM term.

``run_jaxpr_audit`` applies this to the real serving graphs:
``build_serve_step`` (decode, expects combines == attention sublayers per
scan period) and ``make_prefill_step`` (expects zero collectives — prefill
shards KV-free over data/model via GSPMD constraints only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding, Report

# psum traced inside shard_map lowers to the ``psum2`` primitive (with an
# ``axes`` param instead of ``axis_name``) — normalized back to "psum" in
# collect_collectives so expected-count specs stay primitive-name based
_COMBINE_PRIMS = ("all_to_all", "all_gather", "psum", "psum2")


def _axis_tuple(val) -> tuple:
    if val is None:
        return ()
    if isinstance(val, (tuple, list)):
        return tuple(val)
    return (val,)


def collect_collectives(jaxpr, path="") -> list[dict]:
    """Flatten every collective equation in ``jaxpr`` (recursing through
    scan/shard_map/pjit/custom-call sub-jaxprs).

    Returns dicts ``{"prim", "axes", "path"}`` — ``axes`` the normalized
    axis-name tuple, ``path`` the equation trail (e.g.
    ``scan/shard_map/all_to_all``) for findings messages.
    """
    out = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        here = f"{path}/{name}" if path else name
        if name in _COMBINE_PRIMS or name == "axis_index":
            axes = _axis_tuple(eqn.params.get("axis_name",
                                              eqn.params.get("axes")))
            prim = "psum" if name == "psum2" else name
            out.append({"prim": prim, "axes": axes, "path": here})
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                out.extend(collect_collectives(sub, here))
            elif hasattr(v, "eqns"):
                out.extend(collect_collectives(v, here))
    return out


def _walk_dtypes(jaxpr, bad, path=""):
    for eqn in jaxpr.eqns:
        here = f"{path}/{eqn.primitive.name}" if path else eqn.primitive.name
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is not None and dt in (jnp.float64, np.complex128):
                bad.append((here, str(dt)))
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None and hasattr(sub, "eqns"):
                _walk_dtypes(sub, bad, here)
            elif hasattr(v, "eqns"):
                _walk_dtypes(v, bad, here)


def audit_step_fn(fn, args, *, kvp_axes, mesh_axes, expected, where,
                  symbol) -> list[Finding]:
    """Audit one traced step function.

    ``expected`` maps combine primitive -> required count over the KVP
    axes (e.g. ``{"all_to_all": 1, "all_gather": 1, "psum": 0}``).
    ``kvp_axes``/``mesh_axes`` are axis-name tuples; ``where``/``symbol``
    locate the findings.  Returns the findings (empty = clean).
    """
    findings = []
    try:
        jaxpr = jax.make_jaxpr(fn)(*args)
    except Exception as e:
        return [Finding(check="collective.count", path=where, symbol=symbol,
                        message=f"step function failed to trace: {e!r}")]
    colls = collect_collectives(jaxpr.jaxpr)
    kvp = set(kvp_axes)
    mesh = set(mesh_axes)

    for c in colls:
        unknown = set(c["axes"]) - mesh
        if unknown:
            findings.append(Finding(
                check="collective.axis", path=where, symbol=symbol,
                message=f"{c['path']}: collective over non-mesh axes "
                        f"{sorted(unknown)} (mesh: {sorted(mesh)})"))
        elif (c["prim"] in ("all_to_all", "all_gather")
              and not set(c["axes"]) <= kvp):
            findings.append(Finding(
                check="collective.axis", path=where, symbol=symbol,
                message=f"{c['path']}: combine collective over "
                        f"{c['axes']} — the KVP combine must run over "
                        f"the KVP axes {sorted(kvp)} only"))

    for prim, want in expected.items():
        got = [c for c in colls
               if c["prim"] == prim and set(c["axes"]) & kvp]
        if len(got) != want:
            trail = [c["path"] for c in got[:3]]
            findings.append(Finding(
                check="collective.count", path=where, symbol=symbol,
                message=f"{len(got)} {prim} over KVP axes "
                        f"{sorted(kvp)}, expected {want} "
                        f"(one combine per attention layer): {trail}"))

    bad = []
    _walk_dtypes(jaxpr.jaxpr, bad)
    if bad:
        findings.append(Finding(
            check="dtype.upcast", path=where, symbol=symbol,
            message=f"fp64/complex128 values in the traced step: "
                    f"{bad[:3]}"))
    return findings


def check_state_dtypes(fn, args, state_index, where, symbol) -> list[Finding]:
    """Decode-state dtype preservation via ``jax.eval_shape``.

    ``args[state_index]`` is the state pytree the step returns updated;
    every leaf's dtype must survive the step (int8 caches stay int8).
    """
    try:
        out = jax.eval_shape(fn, *args)
    except Exception as e:
        return [Finding(check="dtype.upcast", path=where, symbol=symbol,
                        message=f"eval_shape failed: {e!r}")]
    in_state = args[state_index]
    out_state = None
    for leaf_tree in (out if isinstance(out, tuple) else (out,)):
        paths = jax.tree_util.tree_structure(leaf_tree)
        if paths == jax.tree_util.tree_structure(in_state):
            out_state = leaf_tree
            break
    if out_state is None:
        return []               # step does not return the state pytree
    bad = []
    ins = jax.tree_util.tree_leaves_with_path(in_state)
    outs = jax.tree_util.tree_leaves_with_path(out_state)
    for (p, a), (_, b) in zip(ins, outs):
        if a.dtype != b.dtype:
            bad.append((jax.tree_util.keystr(p), str(a.dtype),
                        str(b.dtype)))
    if bad:
        return [Finding(
            check="dtype.upcast", path=where, symbol=symbol,
            message=f"decode-state leaves change dtype through the step "
                    f"(silent cache upcast): {bad[:3]}")]
    return []


def _decode_expected_combines(cfg) -> int:
    """Attention sublayers per scan period == KVP combines in the jaxpr.

    ``build_serve_step`` scans over layer periods; the scan body holds
    ``p = local_ratio + 1`` sublayers (or 1 without a local/global split),
    each running one ``helix_attention`` == one all_to_all + all_gather.
    The scan body is traced once, so the jaxpr records exactly ``p``
    combines for attention archs and 0 for pure-SSM archs.
    """
    if not getattr(cfg, "has_attention", True):
        return 0
    p = (cfg.local_ratio + 1) if getattr(cfg, "local_ratio", 0) else 1
    return p


def run_jaxpr_audit(report: Report, arch: str = "granite-3-2b") -> None:
    """Trace the real serving step graphs for ``arch`` and audit them.

    Uses the reduced config on a 1x1 ("data", "model") mesh with
    ``kvp_axes=("data",)`` and ``hopb_chunks=1`` — collective equations
    are recorded inside shard_map even at axis size 1, so the HOP-B
    dataflow is checked without multi-device hardware.
    """
    import functools

    from repro.configs import get_config
    from repro.core.sharding import HelixConfig
    from repro.models.model_zoo import build_serve_step, make_prefill_step
    from repro.models.transformer import init_params
    from repro.utils import make_mesh

    cfg = get_config(arch).reduced()
    mesh = make_mesh((1, 1), ("data", "model"))
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    where = "src/repro/models/decode_model.py"

    # shapes only — eval_shape keeps the audit allocation-free
    params = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    b, s_cap = 2, 32
    toks = jax.ShapeDtypeStruct((b, 8), jnp.int32)
    prefill_step = make_prefill_step(cfg, mesh, hx, s_cap=s_cap)
    _, state = jax.eval_shape(prefill_step, params, {"tokens": toks})
    cur = jax.ShapeDtypeStruct((b,), jnp.int32)

    serve_step = build_serve_step(cfg, mesh, hx, hopb_chunks=1)
    p = _decode_expected_combines(cfg)
    expected = {"all_to_all": p, "all_gather": p, "psum": 0}
    report.extend(audit_step_fn(
        serve_step, (params, state, cur),
        kvp_axes=("data",), mesh_axes=mesh.axis_names, expected=expected,
        where=where, symbol=f"build_serve_step[{arch}]"))
    report.extend(check_state_dtypes(
        serve_step, (params, state, cur), state_index=1,
        where=where, symbol=f"build_serve_step[{arch}]"))

    report.extend(audit_step_fn(
        prefill_step, (params, {"tokens": toks}),
        kvp_axes=("data",), mesh_axes=mesh.axis_names,
        expected={"all_to_all": 0, "all_gather": 0, "psum": 0},
        where="src/repro/models/model_zoo.py",
        symbol=f"make_prefill_step[{arch}]"))
    report.mark_run("jaxpr")
