"""Host-sync lint: AST pass flagging per-token device->host transfers.

The serving hot loop must touch the host exactly once per step (the batched
``np.asarray`` of the sampled tokens) — any extra device->host sync
serializes the TPU pipeline and shows up directly in the paper's TTL.  This
pass walks the ``serving/`` and ``launch/`` sources and flags:

  sync.scalar-cast        ``int(...)``/``float(...)`` on a device value —
                          a blocking scalar transfer per call
  sync.item               ``.item()`` on a device value — same
  sync.asarray            ``np.asarray``/``np.array`` of a device value —
                          a device->host copy; the intentional one batched
                          transfer per step lives in the baseline file
  sync.asarray-loop       the same inside a ``for``/``while`` body — the
                          per-slot transfer anti-pattern
  sync.block-until-ready  ``.block_until_ready()`` anywhere in serving code
  sync.device-get         ``jax.device_get(...)`` — a D2H transfer; the
                          sanctioned batched spill sites (serving/tier.py's
                          one-transfer-per-spill contract) live in the
                          baseline file
  sync.device-get-loop    the same inside a loop body — the per-page spill
                          anti-pattern (N blocking transfers where one
                          batched tree transfer works)
  sync.per-token          any of the above inside a multi-step decode
                          window hot function (``WINDOW_HOT_FNS`` — the
                          engine's ``_decode_window``): the whole point of
                          ``--decode-window N`` is ONE host sync per
                          window, so each transfer there additionally
                          gets an ordinal-stamped ``fn#k`` finding.  The
                          baseline pins exactly ``_decode_window#1`` (the
                          batched [B, N] token-block read); a second
                          transfer lands as ``#2``, matches nothing, and
                          fails ``--strict``

Device provenance is tracked per function with a small forward dataflow:
values returned by ``jnp.*``/``jax.*`` calls, by names bound to
``jax.jit(...)`` anywhere in the module (including ``self.attr = jax.jit``),
and values derived from those by indexing/attribute access are DEVICE;
``np.*`` results and unknown names default to HOST (so numpy-only metric
code stays quiet).  The lint is source-level — it runs on checked-in files,
not live objects — which is what lets CI gate it without building a model.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.findings import Finding

DEFAULT_LINT_ROOTS = ("src/repro/serving", "src/repro/launch")

# Functions forming the multi-step decode window's host side: every
# blocking transfer inside them gets an ordinal-stamped ``sync.per-token``
# finding on top of its base check, so the baseline can pin the exact
# transfer *count* (one per window), not just the set of transfer sites.
WINDOW_HOT_FNS = ("_decode_window",)


def _attr_root(node):
    """Leftmost name of a dotted expression (``jnp.argmax`` -> ``jnp``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _collect_device_fns(tree) -> tuple[set, set]:
    """Names / ``self.<attr>``s bound to ``jax.jit(...)`` in the module."""
    names, attrs = set(), set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        v = node.value
        if not (isinstance(v, ast.Call) and _attr_root(v.func) == "jax"):
            continue
        # jax.jit(...) or jax.jit(...)(...) style wrappers
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                names.add(tgt.id)
            elif (isinstance(tgt, ast.Attribute)
                  and isinstance(tgt.value, ast.Name)
                  and tgt.value.id == "self"):
                attrs.add(tgt.attr)
    return names, attrs


class _FnLinter(ast.NodeVisitor):
    """Lint one function body with DEVICE/HOST name tracking."""

    def __init__(self, path, fn_name, device_fns, device_attrs):
        self.path = path
        self.fn = fn_name
        self.device_fns = device_fns
        self.device_attrs = device_attrs
        self.device_names: set[str] = set()
        self.loop_depth = 0
        self.findings: list[Finding] = []
        self.window_hot = fn_name in WINDOW_HOT_FNS
        self._transfers = 0  # per-token ordinal within a window-hot fn

    # --- provenance ---------------------------------------------------

    def _is_device(self, node) -> bool:
        """Does evaluating ``node`` yield (or contain) a device value?"""
        if isinstance(node, ast.Name):
            return node.id in self.device_names
        if isinstance(node, (ast.Attribute, ast.Subscript)):
            root = _attr_root(node) if isinstance(node, ast.Attribute) \
                else None
            if root in ("np", "numpy"):
                return False
            inner = node.value
            return self._is_device(inner)
        if isinstance(node, ast.Call):
            root = _attr_root(node.func)
            if root in ("jnp", "jax"):
                return True
            if root in ("np", "numpy"):
                return False
            if isinstance(node.func, ast.Name) \
                    and node.func.id in self.device_fns:
                return True
            if (isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                    and node.func.attr in self.device_attrs):
                return True
            # a call of unknown origin: device if any argument is
            return any(self._is_device(a) for a in node.args)
        if isinstance(node, (ast.BinOp,)):
            return self._is_device(node.left) or self._is_device(node.right)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self._is_device(e) for e in node.elts)
        return False

    def _bind(self, target, device: bool):
        if isinstance(target, ast.Name):
            (self.device_names.add if device
             else self.device_names.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, device)

    # --- statements ---------------------------------------------------

    def visit_Assign(self, node):
        self.visit(node.value)
        device = self._is_device(node.value)
        # np.asarray(device) yields a HOST value (and is flagged below)
        if (isinstance(node.value, ast.Call)
                and _attr_root(node.value.func) in ("np", "numpy")):
            device = False
        for tgt in node.targets:
            self._bind(tgt, device)

    def visit_For(self, node):
        self.visit(node.iter)
        self._bind(node.target, self._is_device(node.iter))
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_While(self, node):
        self.visit(node.test)
        self.loop_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.loop_depth -= 1
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_FunctionDef(self, node):
        # nested defs are linted as their own scope by the module pass
        return

    visit_AsyncFunctionDef = visit_FunctionDef

    # --- flag rules ---------------------------------------------------

    def _flag(self, check, node, message):
        self.findings.append(Finding(
            check=check, path=self.path, symbol=self.fn,
            line=node.lineno, message=message))
        if self.window_hot:
            # ordinal-stamped symbol: the baseline names the exact k-th
            # transfer, so ADDING a transfer to the window hot path makes
            # a fresh, unbaselined finding instead of silently matching
            self._transfers += 1
            self.findings.append(Finding(
                check="sync.per-token", path=self.path,
                symbol=f"{self.fn}#{self._transfers}", line=node.lineno,
                message=f"blocking transfer #{self._transfers} inside the "
                        f"multi-step decode window ({check}); the window "
                        f"contract is ONE host sync per {self.fn} call"))

    def visit_Call(self, node):
        func = node.func
        if (isinstance(func, ast.Name) and func.id in ("int", "float")
                and node.args and self._is_device(node.args[0])):
            self._flag("sync.scalar-cast", node,
                       f"{func.id}() on a device value blocks on a "
                       f"per-call device->host scalar transfer")
        elif isinstance(func, ast.Attribute) and func.attr == "item" \
                and self._is_device(func.value):
            self._flag("sync.item", node,
                       ".item() on a device value blocks on a scalar "
                       "transfer")
        elif isinstance(func, ast.Attribute) \
                and func.attr == "block_until_ready":
            self._flag("sync.block-until-ready", node,
                       "block_until_ready() stalls the dispatch pipeline "
                       "in serving code")
        elif (isinstance(func, ast.Attribute)
              and func.attr == "device_get"
              and _attr_root(func) == "jax"):
            # jax.device_get is always a D2H transfer; no provenance check
            # needed.  In a loop it is the per-page spill anti-pattern
            # (N blocking transfers where one batched tree transfer works —
            # the sanctioned spill sites do exactly that and live in the
            # baseline).
            if self.loop_depth:
                self._flag("sync.device-get-loop", node,
                           "jax.device_get inside a loop — per-page D2H "
                           "transfers; gather pages on device and issue "
                           "ONE batched device_get instead")
            else:
                self._flag("sync.device-get", node,
                           "device->host transfer (jax.device_get); "
                           "sanctioned batched spill sites belong in the "
                           "baseline")
        elif (_attr_root(func) in ("np", "numpy")
              and isinstance(func, ast.Attribute)
              and func.attr in ("asarray", "array")
              and node.args and self._is_device(node.args[0])):
            if self.loop_depth:
                self._flag("sync.asarray-loop", node,
                           "np.asarray of a device value inside a loop — "
                           "per-slot transfers; batch one transfer per "
                           "step instead")
            else:
                self._flag("sync.asarray", node,
                           "device->host transfer (np.asarray); intended "
                           "batched transfers belong in the baseline")
        self.generic_visit(node)


def lint_source(src: str, path: str) -> list[Finding]:
    """Lint one python source string; ``path`` labels the findings."""
    tree = ast.parse(src)
    device_fns, device_attrs = _collect_device_fns(tree)
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            linter = _FnLinter(path, node.name, device_fns, device_attrs)
            # seed: self-method calls of jitted attrs make results device;
            # parameters are unknown -> HOST (conservative for noise)
            for stmt in node.body:
                linter.visit(stmt)
            findings.extend(linter.findings)
    return findings


def lint_paths(roots=DEFAULT_LINT_ROOTS, repo_root=".") -> list[Finding]:
    """Lint every ``.py`` file under the serving/launch roots."""
    findings = []
    for root in roots:
        base = os.path.join(repo_root, root)
        for dirpath, _, files in os.walk(base):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, repo_root)
                with open(full) as f:
                    findings.extend(lint_source(f.read(), rel))
    return findings
