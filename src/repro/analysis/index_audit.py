"""Index-space auditor: host-evaluates kernel index_maps over the full grid.

For every ``KernelContract`` a family exposes (``registry.contract_suite``),
the auditor enumerates the grid and evaluates each operand's *real*
index_map callable (the one ``pallas_call`` runs) with ``jax.vmap`` over the
stacked grid coordinates, then checks three properties on the resulting
block-index table:

  bounds    every returned block index lies in ``[0, ceil(shape/block))``
            per axis — ``bounds.page`` for the table-indirected pool axis
            (an out-of-range page id reads foreign memory), ``bounds.block``
            elsewhere.  Paged contracts additionally get every table entry
            range-checked against the pool and cross-request page overlap
            checked (two requests sharing a non-sink page is a write race
            waiting to happen).
  dma.elision  for streamed operands of pruned contracts: every grid step
            the contract's ``active`` predicate marks pruned must address
            the *same* block as the previous step along the stream axis —
            that identity is what lets Pallas TPU elide the HBM->VMEM DMA,
            so a violation silently re-streams dead blocks.
  alias.race   fused-append aliased output windows must (a) stay fixed
            across stream steps (they are rewritten idempotently), (b) be
            pairwise disjoint across grid groups (one writer per window),
            (c) address exactly the row the in-kernel VMEM substitution
            targets (``KernelContract.expected_row``), and (d) overlap a
            same-step streamed K/V read only at that expected row.

All checks are exhaustive over the contract's toy grid — no sampling — and
rely on the index_map purity requirement documented in
``kernels/pruning.py``.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding, Report
from repro.kernels import registry
from repro.kernels.contract import KernelContract, Operand

# findings location convention for kernel contracts: the family's ops
# module, symbol "<family>[<case>]/<operand>"
_FAMILY_PATHS = {
    "flash_decode": "src/repro/kernels/flash_decode/kernel.py",
    "flash_prefill": "src/repro/kernels/flash_prefill/kernel.py",
    "ssd_prefill": "src/repro/kernels/ssd_prefill/kernel.py",
    "w8a16_matmul": "src/repro/kernels/w8a16_matmul/kernel.py",
}

_MAX_DETAIL = 3     # grid steps quoted per finding message


def _symbol(contract: KernelContract, op_name: str | None = None) -> str:
    base = f"{contract.family}[{contract.case}]"
    return f"{base}/{op_name}" if op_name else base


def _path(contract: KernelContract) -> str:
    return _FAMILY_PATHS.get(contract.family, contract.family)


def eval_index_table(contract: KernelContract, op: Operand) -> np.ndarray:
    """Evaluate ``op.index_map`` at every grid step.

    Returns an int array of shape ``grid + (ndim,)`` — the block-index
    tuple per grid coordinate.  One vmapped evaluation over the stacked
    coordinates; the prefetch operands are closed over as whole arrays
    (a contract index_map indexes them exactly like the Pallas scalar-
    prefetch refs).
    """
    grid = contract.grid
    coords = np.stack(np.meshgrid(*[np.arange(n) for n in grid],
                                  indexing="ij"), axis=-1)
    flat = coords.reshape(-1, len(grid)).astype(np.int32)
    prefetch = tuple(jnp.asarray(p) for p in contract.prefetch)

    def one(c):
        idx = op.index_map(*[c[i] for i in range(len(grid))], *prefetch)
        return jnp.stack([jnp.asarray(v, jnp.int32) for v in idx])

    table = np.asarray(jax.vmap(one)(jnp.asarray(flat)))
    return table.reshape(grid + (table.shape[-1],))


def _fmt_steps(steps) -> str:
    head = [tuple(int(x) for x in s) for s in steps[:_MAX_DETAIL]]
    more = f" (+{len(steps) - _MAX_DETAIL} more)" \
        if len(steps) > _MAX_DETAIL else ""
    return f"{head}{more}"


def _check_bounds(contract, op, table) -> list[Finding]:
    limits = op.grid_limits()
    findings = []
    for axis, lim in enumerate(limits):
        bad = np.argwhere((table[..., axis] < 0) | (table[..., axis] >= lim))
        if bad.size:
            check = ("bounds.page" if axis == op.paged_axis
                     else "bounds.block")
            what = ("pool page id" if axis == op.paged_axis
                    else f"axis-{axis} block index")
            vals = table[..., axis][tuple(bad[:_MAX_DETAIL].T)]
            findings.append(Finding(
                check=check, path=_path(contract),
                symbol=_symbol(contract, op.name),
                message=f"{what} out of [0, {lim}) at grid steps "
                        f"{_fmt_steps(bad)} -> {vals.tolist()}"))
    return findings


def _check_table(contract) -> list[Finding]:
    """Paged block-table sanity: pool-range + cross-request overlap.

    A non-sink page mapped by two request rows is an alias race by default.
    Contracts carrying the ``shared_ok`` note (refcounted prefix sharing
    with copy-on-write — serving/pool.py) may share *read-only* pages
    across rows; pages a fused-append row window writes
    (``contract.expected_row``) must stay exclusive even then, since the
    engine's CoW guard guarantees an appended page has refcount 1.
    """
    findings = []
    table = np.asarray(contract.table)
    n_pool = contract.n_pool
    bad = np.argwhere((table < 0) | (table >= n_pool))
    if bad.size:
        findings.append(Finding(
            check="bounds.page", path=_path(contract),
            symbol=_symbol(contract, "block_table"),
            message=f"table entries outside pool [0, {n_pool}) at "
                    f"{_fmt_steps(bad)} -> "
                    f"{table[tuple(bad[:_MAX_DETAIL].T)].tolist()}"))
        return findings
    shared_ok = bool(contract.notes.get("shared_ok"))
    write_pages: set[int] = set()
    if shared_ok and contract.expected_row is not None:
        kh = contract.grid[1] if len(contract.grid) > 1 else 1
        for bi in range(table.shape[0]):
            for h in range(kh):
                write_pages.add(int(contract.expected_row(bi, h)[0]))
    seen: dict[int, int] = {}
    for b in range(table.shape[0]):
        for p in table[b]:
            p = int(p)
            if p == 0:
                continue        # shared sink page: duplicates intended
            if p in seen and seen[p] != b:
                if shared_ok and p not in write_pages:
                    continue    # read-only refcounted prefix page
                what = ("append-target page shared across requests"
                        if shared_ok else "shared writable page")
                findings.append(Finding(
                    check="alias.race", path=_path(contract),
                    symbol=_symbol(contract, "block_table"),
                    message=f"non-sink pool page {p} mapped by requests "
                            f"{seen[p]} and {b} — {what}"))
            seen[p] = b
    return findings


def _stream_groups(grid, stream_axis):
    """Iterate (group_coords, slicer) pairs — all grid points that differ
    only in the stream coordinate."""
    other = [i for i in range(len(grid)) if i != stream_axis]
    for combo in itertools.product(*[range(grid[i]) for i in other]):
        full = [slice(None)] * len(grid)
        coords = {}
        for i, c in zip(other, combo):
            full[i] = c
            coords[i] = c
        yield coords, tuple(full)


def _grid_coords(group, stream_axis, s, ndim):
    out = [0] * ndim
    for i, c in group.items():
        out[i] = c
    out[stream_axis] = s
    return tuple(out)


def _check_elision(contract, op, table) -> list[Finding]:
    """Pruned steps must re-address the previous step's block."""
    ax = contract.stream_axis
    n_steps = contract.grid[ax]
    bad = []
    for group, slicer in _stream_groups(contract.grid, ax):
        rows = table[slicer]                       # [n_steps, ndim]
        for s in range(1, n_steps):
            c = _grid_coords(group, ax, s, len(contract.grid))
            if contract.active(*c):
                continue
            if not np.array_equal(rows[s], rows[s - 1]):
                bad.append((c, rows[s - 1].tolist(), rows[s].tolist()))
    if bad:
        steps = [c for c, _, _ in bad]
        was, now = bad[0][1], bad[0][2]
        return [Finding(
            check="dma.elision", path=_path(contract),
            symbol=_symbol(contract, op.name),
            message=f"pruned grid steps fetch a new block (DMA not "
                    f"elided) at {_fmt_steps(steps)}: step block {now} "
                    f"!= previous {was}")]
    return []


def _windows_overlap(idx_a, block_a, idx_b, block_b) -> bool:
    """Element-range intersection of two block windows of one array."""
    for ia, ba, ib, bb in zip(idx_a, block_a, idx_b, block_b):
        lo_a, hi_a = ia * ba, (ia + 1) * ba
        lo_b, hi_b = ib * bb, (ib + 1) * bb
        if hi_a <= lo_b or hi_b <= lo_a:
            return False
    return True


def _check_alias_races(contract, ops_by_name, tables) -> list[Finding]:
    """Fused-append aliased output windows: fixed, unique, expected,
    and disjoint from same-step streamed reads except at the target row."""
    findings = []
    ax = contract.stream_axis
    ndim = len(contract.grid)
    out_aliased = [op for op in contract.operands
                   if op.kind == "out" and op.alias_of]
    for op in out_aliased:
        table = tables[op.name]
        # (a) constant along the stream axis (idempotent rewrite)
        moved = []
        groups = {}
        for group, slicer in _stream_groups(contract.grid, ax):
            rows = table[slicer]
            if not (rows == rows[0]).all():
                moved.append(_grid_coords(group, ax, 0, ndim))
            groups[tuple(sorted(group.items()))] = rows[0]
        if moved:
            findings.append(Finding(
                check="alias.race", path=_path(contract),
                symbol=_symbol(contract, op.name),
                message=f"aliased output window moves across stream steps "
                        f"for groups {_fmt_steps(moved)} — the idempotent "
                        f"rewrite would scatter"))
        # (b) one writer per window across groups
        seen = {}
        for key, row in groups.items():
            t = tuple(int(x) for x in row)
            if t in seen and seen[t] != key:
                findings.append(Finding(
                    check="alias.race", path=_path(contract),
                    symbol=_symbol(contract, op.name),
                    message=f"two grid groups {dict(seen[t])} and "
                            f"{dict(key)} write the same window {t}"))
                break
            seen[t] = key
        # (c) window == the row the in-kernel VMEM substitution targets
        wrong = []
        if contract.expected_row is not None:
            for group, slicer in _stream_groups(contract.grid, ax):
                got = tuple(int(x) for x in table[slicer][0])
                bi = group.get(0, 0)
                h = group.get(1, 0)
                want = tuple(contract.expected_row(bi, h))[:len(got)]
                if got != want:
                    wrong.append((bi, h, got, want))
            if wrong:
                bi, h, got, want = wrong[0]
                findings.append(Finding(
                    check="alias.race", path=_path(contract),
                    symbol=_symbol(contract, op.name),
                    message=f"aliased window diverges from the in-kernel "
                            f"append slot: (b={bi}, h={h}) writes {got}, "
                            f"VMEM substitution targets {want} "
                            f"(+{len(wrong) - 1} more)"))
        # (d) overlap with a same-step streamed read of the aliased buffer
        # only at the expected row (the substituted one) — anywhere else
        # the write clobbers K/V data the attention still reads
        src = ops_by_name.get(op.alias_of)
        if src is None or contract.expected_row is None or wrong:
            continue
        clashes = []
        for group, slicer in _stream_groups(contract.grid, ax):
            wrow = tuple(int(x) for x in tables[op.name][slicer][0])
            bi, h = group.get(0, 0), group.get(1, 0)
            want = tuple(contract.expected_row(bi, h))[:len(wrow)]
            if wrow == want:
                continue        # matching windows handled by (c)
            for s in range(contract.grid[ax]):
                rrow = tuple(int(x)
                             for x in tables[src.name][slicer][s])
                if _windows_overlap(wrow, op.block, rrow, src.block):
                    clashes.append(_grid_coords(group, ax, s, ndim))
                    break
        if clashes:
            findings.append(Finding(
                check="alias.race", path=_path(contract),
                symbol=_symbol(contract, op.name),
                message=f"aliased write window overlaps same-step "
                        f"{src.name} reads away from the append row at "
                        f"{_fmt_steps(clashes)}"))
    return findings


def audit_contract(contract: KernelContract) -> list[Finding]:
    """Run every index-space check over one contract; returns findings."""
    findings = []
    tables = {}
    for op in contract.operands:
        try:
            table = eval_index_table(contract, op)
        except Exception as e:                    # impure / broken map
            findings.append(Finding(
                check="bounds.block", path=_path(contract),
                symbol=_symbol(contract, op.name),
                message=f"index_map failed host evaluation (purity "
                        f"violation? see kernels/pruning.py): {e!r}"))
            continue
        tables[op.name] = table
        findings.extend(_check_bounds(contract, op, table))
        if (op.streamed and contract.active is not None
                and contract.stream_axis is not None):
            findings.extend(_check_elision(contract, op, table))
    if contract.table is not None:
        findings.extend(_check_table(contract))
    ops_by_name = {op.name: op for op in contract.operands}
    if contract.stream_axis is not None:
        findings.extend(
            _check_alias_races(contract, ops_by_name, tables))
    return findings


def run_index_audit(report: Report, families=None) -> None:
    """Audit every registered family's contract suite into ``report``.

    A family without a contract hook becomes a ``contract.missing`` error —
    loud, not skipped (the ``--strict`` CI contract).
    """
    for name in (families or sorted(registry.FAMILIES)):
        fam = registry.FAMILIES[name]
        if fam.contract is None:
            report.add(Finding(
                check="contract.missing",
                path="src/repro/kernels/registry.py",
                symbol=name,
                message=f"kernel family {name!r} registers no analysis "
                        f"contract hook; add <family>_contract() to its "
                        f"ops module (docs/analysis.md)"))
            continue
        for contract in registry.contract_suite(name):
            report.extend(audit_contract(contract))
    report.mark_run("index")
