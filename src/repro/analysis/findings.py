"""Findings registry for the static contract checker.

A ``Finding`` is one defect or notable pattern located at (path, line,
symbol) with a check id from the catalog below; a ``Report`` collects them,
applies the baseline-suppression file, and serializes the machine-readable
``ANALYSIS.json`` (schema asserted by ``scripts/check_analysis_schema.py``).

Check catalog (id -> default severity); docs/analysis.md documents each:

  contract.missing        error    family has no analysis contract hook
  bounds.block            error    index_map addresses a block out of range
  bounds.page             error    paged table/page indirection out of pool
  dma.elision             error    pruned grid step changes blocks (DMA not
                                   elided -> dead-block HBM traffic)
  alias.race              error    fused-append aliased window races a
                                   same-step read / another writer
  collective.count        error    KVP combine duplicated or missing
  collective.axis         error    collective over a wrong/unknown mesh axis
  dtype.upcast            error    fp64 value in the decode hot path, or a
                                   decode-state leaf changing dtype
  sync.scalar-cast        error    int()/float() on a device value
  sync.item               error    .item() on a device value
  sync.asarray            warning  device->host np.asarray transfer (the
                                   intentional batched ones are baselined)
  sync.asarray-loop       error    per-slot np.asarray inside a loop
  sync.block-until-ready  error    block_until_ready in a step loop
  sync.device-get         warning  jax.device_get D2H transfer (sanctioned
                                   batched spill sites are baselined)
  sync.device-get-loop    error    per-page jax.device_get inside a loop
  sync.per-token          warning  blocking transfer inside a multi-step
                                   decode-window hot function; symbols
                                   carry a ``#ordinal`` so the baseline
                                   pins EXACTLY the one per-window
                                   transfer — a second transfer gets a new
                                   ordinal and fails ``--strict``
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable

CHECKS: dict[str, str] = {
    "contract.missing": "error",
    "bounds.block": "error",
    "bounds.page": "error",
    "dma.elision": "error",
    "alias.race": "error",
    "collective.count": "error",
    "collective.axis": "error",
    "dtype.upcast": "error",
    "sync.scalar-cast": "error",
    "sync.item": "error",
    "sync.asarray": "warning",
    "sync.asarray-loop": "error",
    "sync.block-until-ready": "error",
    "sync.device-get": "warning",
    "sync.device-get-loop": "error",
    "sync.per-token": "warning",
}

SEVERITIES = ("error", "warning")

# field name -> python type of one serialized finding (ANALYSIS.json);
# scripts/check_analysis_schema.py imports this as the source of truth.
FINDING_FIELDS = {
    "check": str,
    "severity": str,
    "path": str,
    "line": int,
    "symbol": str,
    "message": str,
    "suppressed": bool,
}


@dataclasses.dataclass
class Finding:
    """One analyzer finding: a check id located at (path, line, symbol).

    ``symbol`` is the enclosing function / kernel case / step-fn name —
    baseline suppressions match on (check, path, symbol), never on line
    numbers, so they survive unrelated edits.  ``severity`` defaults from
    the ``CHECKS`` catalog.
    """

    check: str
    path: str
    symbol: str
    message: str
    line: int = 0
    severity: str = ""
    suppressed: bool = False

    def __post_init__(self):
        if self.check not in CHECKS:
            raise ValueError(f"unknown check id {self.check!r}; "
                             f"catalog: {sorted(CHECKS)}")
        if not self.severity:
            self.severity = CHECKS[self.check]
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def key(self) -> tuple:
        """Line-independent identity used by baseline suppression."""
        return (self.check, self.path, self.symbol)

    def to_dict(self) -> dict:
        """Serialized finding — exactly the ``FINDING_FIELDS`` columns."""
        return {k: getattr(self, k) for k in FINDING_FIELDS}


def load_baseline(path) -> list[dict]:
    """Parse a baseline file -> list of suppress entries.

    Format (``ANALYSIS_BASELINE.json``)::

        {"suppress": [{"check": ..., "path": ..., "symbol": ...,
                       "reason": ...}, ...]}

    Every entry must carry all four keys; ``reason`` documents *why* the
    finding is intentional (e.g. the one batched device->host transfer per
    decode step).
    """
    with open(path) as f:
        data = json.load(f)
    entries = data.get("suppress", [])
    for e in entries:
        missing = {"check", "path", "symbol", "reason"} - set(e)
        if missing:
            raise ValueError(f"baseline entry {e!r} missing keys {missing}")
        if e["check"] not in CHECKS:
            raise ValueError(f"baseline entry {e!r}: unknown check id")
    return entries


class Report:
    """Collects findings across the analysis layers and renders results.

    ``apply_baseline`` marks findings matching a suppress entry (on the
    line-independent ``Finding.key``) as suppressed and reports stale
    entries that no longer match anything — a baseline should shrink as
    true positives get fixed, not accumulate dead weight.
    """

    def __init__(self):
        self.findings: list[Finding] = []
        self.checks_run: list[str] = []

    def add(self, finding: Finding):
        """Record one finding."""
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]):
        """Record a batch of findings (one layer's output)."""
        self.findings.extend(findings)

    def mark_run(self, layer: str):
        """Note that an analysis layer (index/jaxpr/sync) completed."""
        if layer not in self.checks_run:
            self.checks_run.append(layer)

    def apply_baseline(self, entries: list[dict]) -> list[dict]:
        """Suppress matching findings; returns the *stale* entries."""
        keys = {(e["check"], e["path"], e["symbol"]): e for e in entries}
        hit = set()
        for f in self.findings:
            e = keys.get(f.key())
            if e is not None:
                f.suppressed = True
                hit.add(f.key())
        return [e for k, e in keys.items() if k not in hit]

    def unsuppressed(self, severity: str | None = None) -> list[Finding]:
        """Findings not covered by the baseline, optionally by severity."""
        return [f for f in self.findings if not f.suppressed
                and (severity is None or f.severity == severity)]

    def summary(self) -> dict:
        """Counts for ANALYSIS.json: total/errors/warnings/suppressed."""
        return {
            "total": len(self.findings),
            "errors": len(self.unsuppressed("error")),
            "warnings": len(self.unsuppressed("warning")),
            "suppressed": sum(f.suppressed for f in self.findings),
        }

    def to_dict(self, meta: dict | None = None) -> dict:
        """The ANALYSIS.json payload (see check_analysis_schema.py)."""
        return {
            "meta": dict(meta or {}, checks_run=list(self.checks_run)),
            "summary": self.summary(),
            "findings": [f.to_dict() for f in self.findings],
        }

    def render(self) -> str:
        """Human-readable listing, errors first, suppressed last."""
        order = {"error": 0, "warning": 1}
        lines = []
        for f in sorted(self.findings,
                        key=lambda f: (f.suppressed, order[f.severity],
                                       f.path, f.line)):
            tag = "suppressed" if f.suppressed else f.severity
            loc = f"{f.path}:{f.line}" if f.line else f.path
            lines.append(f"[{tag:<10s}] {f.check:<22s} {loc} "
                         f"({f.symbol}): {f.message}")
        s = self.summary()
        lines.append(f"{s['errors']} error(s), {s['warnings']} warning(s), "
                     f"{s['suppressed']} suppressed "
                     f"(layers: {', '.join(self.checks_run) or 'none'})")
        return "\n".join(lines)
