"""Test-only helpers importable from the test suite.

``optional_hypothesis()`` lets a test module use hypothesis when it is
installed and degrade to *skipped property tests* (never collection errors)
when it is not — the deterministic tests in the same module keep running.

    from repro.testing import optional_hypothesis
    given, settings, st = optional_hypothesis()

Dev dependencies (including hypothesis) are declared in requirements-dev.txt
/ pyproject.toml; ``make test`` installs them when the environment allows.
"""
from __future__ import annotations


def optional_hypothesis():
    """Returns (given, settings, st) — real if installed, else skip stubs.

    The stubs are safe at collection time: ``st.<anything>(...)`` returns a
    placeholder, ``@settings(...)`` is identity, and ``@given(...)`` replaces
    the test with a pytest skip marker.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        import pytest

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        def given(*_a, **_k):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*_a, **_k):
            return lambda fn: fn

        return given, settings, _Strategies()
