import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the production mesh from 512
# placeholder CPU devices; smoke tests / benches see 1 device.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent (no mismatched
specs, no unsupported collective, fits memory at compile time) and extracts
the roofline raw terms:

  * cost_analysis()  — per-device HLO FLOPs / bytes accessed
  * compiled HLO     — per-collective bytes (all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute)
  * memory_analysis()— per-device buffer sizes (where the backend supports it)

Artifacts are dumped as JSON under --out (default runs/dryrun) and consumed
by benchmarks/roofline.py (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape decode_32k [--multi-pod] [--out runs/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, cell_applicable, get_config
from repro.core.kvcache import decode_state_shapes, decode_state_specs
from repro.core.sharding import (default_helix_config, helix_param_specs,
                                 to_shardings, train_param_specs)
from repro.launch.mesh import make_production_mesh
from repro.utils import set_mesh
from repro.models.model_zoo import (build_serve_step, data_partition_specs,
                                    data_specs, make_prefill_step,
                                    make_train_step)
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from jax.sharding import NamedSharding, PartitionSpec as P

OPS = ("all-reduce", "all-gather", "all-to-all", "reduce-scatter",
       "collective-permute")
_LINE_RE = re.compile(
    r"=\s*(?P<type>[^=]*?)\s+"
    r"(?P<kind>all-reduce|all-gather|all-to-all|reduce-scatter|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Per-device collective buffer bytes by op kind, from compiled HLO.

    Handles tuple-result collectives (XLA fuses several arrays into one
    all-to-all/all-reduce: ``(bf16[..], bf16[..]) all-to-all(...)``) by
    summing every shape in the result type.  -start ops are counted,
    -done ops are skipped (same buffers)."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or f"{m.group('kind')}-done" in line:
            continue
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group("type")):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * DTYPE_BYTES[dt]
        out[m.group("kind")] = out.get(m.group("kind"), 0.0) + total
    return out


def _params_sds(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))


def build_cell(cfg, shape: str, mesh, optcfg=None, unroll: bool = False,
               qkv_shard: bool = False, kv_bits: int = 16):
    """Returns (step_fn, args_sds tuple, in_shardings tuple).

    unroll=True emits layer/chunk loops inline (for cost extraction on
    shallow variants); unroll=False keeps scans (the production graph).
    qkv_shard / kv_bits: §Perf beyond-paper knobs (decode cells)."""
    import dataclasses
    cell = SHAPES[shape]
    hx = dataclasses.replace(default_helix_config(cfg, mesh),
                             qkv_shard=qkv_shard, kv_cache_bits=kv_bits)
    params_sds = _params_sds(cfg)
    p_specs_train = train_param_specs(cfg, params_sds, mesh)
    p_specs_helix = helix_param_specs(cfg, params_sds, hx, mesh)
    d_sds = data_specs(cfg, cell)
    d_specs = data_partition_specs(cfg, cell, mesh)
    chunk_q = 2048 if unroll else 512

    if cell.kind == "train":
        optcfg = optcfg or AdamWConfig(moment_dtype=jnp.bfloat16)
        fn = make_train_step(cfg, mesh, optcfg, chunk_q=chunk_q,
                             unroll=unroll)
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, optcfg), params_sds)
        opt_specs = {"m": p_specs_train, "v": p_specs_train, "step": P()}
        args = (params_sds, opt_sds, d_sds)
        shardings = (to_shardings(mesh, p_specs_train),
                     to_shardings(mesh, opt_specs),
                     to_shardings(mesh, d_specs))
    elif cell.kind == "prefill":
        fn = make_prefill_step(cfg, mesh, hx, chunk_q=chunk_q, unroll=unroll)
        args = (params_sds, d_sds)
        shardings = (to_shardings(mesh, p_specs_train),
                     to_shardings(mesh, d_specs))
    else:  # decode
        fn = build_serve_step(cfg, mesh, hx, unroll=unroll)
        st_sds = decode_state_shapes(cfg, cell.global_batch, cell.seq_len,
                                     hx.kvp(mesh), hx.rr_block,
                                     kv_bits=kv_bits)
        st_specs = decode_state_specs(cfg, hx, batch=cell.global_batch,
                                      mesh=mesh)
        args = (params_sds, st_sds, d_sds["tokens"])
        shardings = (to_shardings(mesh, p_specs_helix),
                     to_shardings(mesh, st_specs),
                     NamedSharding(mesh, P(None)))
    return fn, args, shardings


def _layer_period(cfg) -> int:
    """Smallest repeating layer group (gemma3: 5 local + 1 global)."""
    return (cfg.local_ratio + 1) if cfg.local_ratio else 1


def _shallow(cfg, periods: int):
    """cfg with n_layers = periods x period (enc scaled too for enc-dec)."""
    import dataclasses
    p = _layer_period(cfg)
    kw = {"n_layers": periods * p}
    if cfg.is_encdec:
        kw["enc_layers"] = periods * p
    return dataclasses.replace(cfg, **kw)


def _cost_of(cfg, shape, mesh, **knobs):
    fn, args, shardings = build_cell(cfg, shape, mesh, unroll=True, **knobs)
    with set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older JAX: one dict per program
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    return flops, bytes_, colls


def extract_costs(cfg, shape: str, mesh, **knobs) -> dict:
    """Per-device FLOPs/bytes/collectives for the FULL-depth step via 2-point
    layer extrapolation: cost_analysis counts scan bodies once, and fully
    unrolling the production depth is intractable for the SPMD partitioner,
    so we lower 1-period and 2-period shallow variants with all loops
    unrolled; layers are identical within a period, making

        total = c(1p) + (n_periods - 1) * (c(2p) - c(1p))

    exact (embedding/head costs live in the base term)."""
    p = _layer_period(cfg)
    n_periods = cfg.n_layers // p
    f1, b1, c1 = _cost_of(_shallow(cfg, 1), shape, mesh, **knobs)
    if n_periods == 1:
        return {"flops": f1, "bytes accessed": b1, "collectives": c1}
    f2, b2, c2 = _cost_of(_shallow(cfg, 2), shape, mesh, **knobs)
    k = n_periods - 1
    colls = {key: c1.get(key, 0.0) + k * (c2.get(key, 0.0) - c1.get(key, 0.0))
             for key in set(c1) | set(c2)}
    return {"flops": f1 + k * (f2 - f1),
            "bytes accessed": b1 + k * (b2 - b1),
            "collectives": colls}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             tag: str = "", **knobs) -> dict:
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if tag:
        rec["variant"] = tag
        rec["knobs"] = dict(knobs)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg_full = get_config(arch)
    # 1) production graph (scans): THE compile check + memory analysis
    t0 = time.time()
    fn, args, shardings = build_cell(cfg_full, shape, mesh, unroll=False,
                                     **knobs)
    with set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # CPU backend may not support it
        rec["memory"] = {"error": str(e)}

    # 2) cost extraction via shallow-unrolled 2-point extrapolation.
    #    The §Roofline table is single-pod only (spec) — multi-pod cells are
    #    a sharding/compile check, so skip the expensive extraction there.
    t_cost = 0.0
    if not multi_pod:
        t0 = time.time()
        costs = extract_costs(cfg_full, shape, mesh, **knobs)
        t_cost = time.time() - t0
        rec["cost"] = {"flops": costs["flops"],
                       "bytes accessed": costs["bytes accessed"]}
        rec["collectives"] = costs["collectives"]
        rec["cost_method"] = ("2-point layer extrapolation over shallow "
                              "fully-unrolled variants (scan bodies are "
                              "counted once by cost_analysis)")
    else:
        rec["cost"] = {}
        rec["collectives"] = {}
        rec["cost_method"] = "skipped (roofline table is single-pod only)"
    rec["timings"] = {"lower_s": round(t_lower, 2),
                      "compile_s": round(t_compile, 2),
                      "cost_extract_s": round(t_cost, 2)}
    rec["status"] = "ok"

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--tag", default="", help="variant tag for §Perf runs")
    ap.add_argument("--qkv-shard", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=16, choices=(8, 16))
    args = ap.parse_args()
    out = Path(args.out)
    knobs = {"qkv_shard": args.qkv_shard, "kv_bits": args.kv_bits}

    archs = ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch} x {shape} x {mesh_name}"
                if args.skip_existing and \
                        (out / f"{arch}__{shape}__{mesh_name}.json").exists():
                    print(f"[keep] {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape, mp, out, tag=args.tag,
                                   **knobs)
                except Exception:
                    print(f"[FAIL] {tag}")
                    traceback.print_exc()
                    failures += 1
                    continue
                if rec["status"] == "skipped":
                    print(f"[skip] {tag}: {rec['reason']}")
                else:
                    c = rec["cost"]
                    print(f"[ ok ] {tag}: flops/dev={c.get('flops', 0):.3e} "
                          f"bytes/dev={c.get('bytes accessed', 0):.3e} "
                          f"compile={rec['timings']['compile_s']}s")
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
