"""Serving driver: batched decode with the Helix engine.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 8 --prompt-len 32 --max-new 16 --chunk-tokens 8

Kernel backends (kernels/registry.py) are selectable per family:
``--attn-backend`` routes the decode attention (flash_decode),
``--prefill-backend`` the full-sequence prefill attention (flash_prefill),
``--ssd-backend`` the Mamba2 SSD scan core (ssd_prefill),
``--matmul-backend`` the w8a16 int8-weight matmul (with ``--lm-head-w8``
quantizing the lm_head onto it); ``--no-fuse-append`` opts out of the fused
KV-append kernel epilogue and ``--no-prune-blocks`` of the length/causality-
aware K/V block pruning (both bit-exact).  ``--list-backends`` prints the
per-family availability matrix and exits (CI smoke target).

Serving scheduler (docs/serving.md): ``--chunk-tokens N`` prefills prompts
in N-token slices interleaved with decode steps (0 = monolithic one-shot
prefill), ``--sched-policy`` picks the admission order (fcfs | sjf), and
``--traffic poisson --arrival-rate R`` replays a synthetic Poisson arrival
process (R requests per engine step on average) instead of submitting
everything up front; ``--metrics`` prints the TTFT/TTL/queue-wait summary.
``--paged-kv`` switches to the shared-pool paged KV cache (``--pool-blocks``
sizes the pool): one global page pool + per-request block tables instead of
worst-case per-slot reservations, so admission gates on the global free-page
count — token streams stay bit-exact vs the fixed layout.

Host KV tier (docs/serving.md): ``--host-pages N`` spills preempted
requests' live pages to a host store so resume runs zero re-prefill
chunks, ``--session-kv`` persists retired requests' pages per session so
``--turns T`` multi-turn conversations restore their history, and
``--fault-plan 'k=v,...'`` deterministically injects the tier's failure
modes (every one degrades to re-prefill, never to divergent tokens —
scripts/chaos_smoke.py asserts this in CI).

Multi-tenant SLO front end (docs/serving.md): every run is driven by a
serving/workload.py **trace** — ``--trace FILE`` replays a saved JSONL
trace, otherwise one is generated from ``--traffic batch|poisson|bursty``
(``--arrival-rate``, ``--burst``) and the ``--tenants
"name[:weight[:slo[:share]]],..."`` mix.  ``--tenants`` arms
deficit-weighted-fair admission across tenants; ``--slo-ttl-ms`` arms the
TTL governor, which sheds batch-class slots through the spill path when
the interactive TTL p95 drifts past target; ``--virtual-clock`` swaps the
metrics clock for the deterministic cost model so two replays of the same
trace produce identical latency summaries (scripts/trace_smoke.py asserts
this in CI).

On-device sampling + multi-step decode (docs/serving.md): ``--sampling
greedy|temperature|top_k|top_p`` (with ``--temperature``, ``--top-k``,
``--top-p``, ``--seed``) moves token selection onto the device as a fused
epilogue over the lm_head logits, and ``--decode-window N`` runs N decode
steps per device dispatch via a ``lax.scan`` so the host blocks on ONE
[batch, N] token-block transfer per window instead of one sync per token
— token streams stay bit-identical to ``--decode-window 1``
(scripts/decode_window_smoke.py asserts streams and the 1/N sync rate in
CI); the summary gains ``engine.sync_stats()``'s ``syncs_per_token``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.kernels.registry import BACKENDS, backend_table
from repro.models.model_zoo import (build_serve_multistep, build_serve_step,
                                    chunked_prefill_supported,
                                    make_chunk_prefill_step, make_prefill_step)
from repro.models.transformer import init_params
from repro.serving import DecodeEngine, Request
from repro.serving.sampling import SAMPLING_KINDS, SamplingParams
from repro.serving.metrics import VirtualClock
from repro.serving.scheduler import POLICIES
# poisson_arrival_steps moved to (and is re-exported from) the workload
# module so serve and bench replay the exact same arrival processes
from repro.serving.workload import (TenantSpec, generate_trace, load_trace,
                                    parse_tenants, poisson_arrival_steps,
                                    requests_from_trace, trace_id)
from repro.utils import make_mesh


def serve_demo(arch: str, *, reduced: bool, n_requests: int, prompt_len: int,
               max_new: int, max_batch: int = 8, mesh=None, hx=None,
               attn_backend: str | None = None,
               prefill_backend: str | None = None,
               ssd_backend: str | None = None,
               matmul_backend: str | None = None,
               fuse_append: bool | None = None,
               prune_blocks: bool | None = None,
               lm_head_w8: bool | None = None,
               paged_kv: bool | None = None,
               pool_blocks: int | None = None,
               prefix_share: bool = False,
               grouped_decode: bool | None = None,
               shared_prefix_len: int = 0,
               host_pages: int = 0, session_kv: bool = False,
               fault_plan=None, turns: int = 1,
               chunk_tokens: int = 0, sched_policy: str = "fcfs",
               traffic: str = "batch", arrival_rate: float = 0.5,
               burst: int = 4, trace=None, tenants=None,
               slo_ttl_ms: float = 0.0, virtual_clock=False,
               decode_window: int = 1, sampling: str | None = None,
               temperature: float = 1.0, top_k: int = 0, top_p: float = 1.0,
               seed: int = 0, log=print):
    """Run ``n_requests`` synthetic prompts through the continuous-batching
    engine and report throughput.  Returns (finished ``Request`` list,
    metrics summary dict — with the engine's ``pool_stats()`` merged in).

    The ``*_backend`` arguments override the corresponding ``hx`` fields
    (``None`` keeps the ``HelixConfig`` defaults); see kernels/registry.py.
    ``chunk_tokens`` > 0 enables chunked prefill (scheduler path);
    ``traffic="poisson"`` staggers submissions over engine steps with
    ``arrival_rate`` requests/step on average.  ``paged_kv`` switches the
    KV cache to the shared-pool paged layout (``pool_blocks`` pages of
    ``kvp * rr_block`` positions; default = the fixed layout's HBM), making
    cache pressure a global admission signal — bit-exact token streams
    either way (scripts/paged_smoke.py asserts this in CI).

    ``shared_prefix_len`` makes every synthetic prompt start with the same
    ``shared_prefix_len`` tokens (distinct random suffixes fill the rest);
    ``prefix_share`` turns on the engine's prefix index + refcounted
    copy-on-write page sharing over it (needs ``paged_kv`` + chunked
    prefill), and ``grouped_decode`` additionally decodes each shared
    prefix once per *group* of requests instead of once per request
    (``HelixConfig.grouped_decode``) — all bit-exact vs the unshared run
    (scripts/prefix_smoke.py asserts this in CI).

    Host KV tier (docs/serving.md): ``host_pages`` sizes the
    ``HostPageStore`` so preemptions spill live pages and resume with zero
    re-prefill chunks; ``session_kv`` persists retired requests' pages per
    session id; ``fault_plan`` (a ``serving/faults.FaultPlan`` or its
    ``"k=v,..."`` spec string) deterministically injects the tier's
    failure modes.  ``turns`` > 1 runs a multi-turn conversation workload:
    each request is a session whose turn t+1 prompt is its full turn-t
    context plus ``prompt_len`` fresh tokens, submitted the step turn t
    finishes — the summary's ``turn2_ttft_s`` isolates what the session
    restore buys (with ``session_kv`` it tracks the *new* turn length, not
    the ever-growing history).

    Workload/tenancy (serving/workload.py, docs/serving.md): the run is
    always trace-driven — ``trace`` (a path or a ``TraceRow`` list)
    replays a saved workload, otherwise one is generated from ``traffic``
    ("batch" | "poisson" | "bursty"), ``arrival_rate``/``burst`` and the
    ``tenants`` mix (a ``parse_tenants`` spec string or ``TenantSpec``s);
    the summary's ``trace_id`` names the exact workload either way.
    ``tenants`` also arms weighted-fair admission, ``slo_ttl_ms`` > 0
    arms the TTL governor (shed batch-to-spill when the interactive TTL
    p95 exceeds the target), and ``virtual_clock`` (True or a
    ``VirtualClock``) makes every latency in the summary deterministic.

    ``sampling`` (a ``SAMPLING_KINDS`` name) arms the engine's on-device
    sampler — token selection happens on device with per-request PRNG
    streams (``serving/sampling.py``; ``temperature``/``top_k``/``top_p``
    parameterize it, ``seed`` keys the streams) — and ``decode_window``
    > 1 runs that many decode steps per device dispatch
    (``build_serve_multistep``), syncing one [batch, N] token block per
    window; streams are bit-identical to ``decode_window=1`` and the
    summary reports ``syncs_per_token``.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    if hx is None:
        # single-device default; on a real mesh the caller supplies hx
        hx = HelixConfig(kvp_axes=("data",) if mesh is None else (),
                         tpa_axis=None)
    overrides = {k: v for k, v in [("attn_backend", attn_backend),
                                   ("prefill_backend", prefill_backend),
                                   ("ssd_backend", ssd_backend),
                                   ("matmul_backend", matmul_backend),
                                   ("fuse_append", fuse_append),
                                   ("prune_blocks", prune_blocks),
                                   ("lm_head_w8", lm_head_w8),
                                   ("paged_kv", paged_kv),
                                   ("grouped_decode", grouped_decode)]
                 if v is not None}
    if overrides:
        hx = dataclasses.replace(hx, **overrides)
    kvp = hx.kvp(mesh) if mesh else 1

    if mesh is None:
        # single-device: 1x1 trivial mesh keeps one code path
        mesh = make_mesh((1, 1), ("data", "model"))
    sp = None
    if sampling is not None:
        sp = SamplingParams(kind=sampling, temperature=temperature,
                            top_k=top_k, top_p=top_p, seed=seed)
    serve_step = build_serve_step(cfg, mesh, hx)
    multistep = (build_serve_multistep(cfg, mesh, hx, window=decode_window)
                 if decode_window > 1 else None)
    prefill_step = make_prefill_step(cfg, mesh, hx)
    chunked = chunk_tokens > 0 and chunked_prefill_supported(cfg)
    chunk_step = (make_chunk_prefill_step(
        cfg, mesh, hx, return_last_logits=sp is not None)
        if chunked else None)
    if chunk_tokens > 0 and not chunked:
        log(f"[serve] {cfg.name}: chunked prefill unsupported for this "
            "family; falling back to one-shot prefill")

    if isinstance(fault_plan, str):
        from repro.serving.faults import FaultPlan
        fault_plan = FaultPlan.parse(fault_plan)
    if isinstance(tenants, str):
        tenants = parse_tenants(tenants)
    if trace is not None:
        rows = load_trace(trace) if isinstance(trace, str) else list(trace)
    else:
        rows = generate_trace(n_requests, arrival=traffic, rate=arrival_rate,
                              burst=burst,
                              tenants=tuple(tenants) if tenants
                              else (TenantSpec("default"),),
                              prompt_len=prompt_len, max_tokens=max_new,
                              seed=seed)
    rows = sorted(rows, key=lambda r: (r.arrival_step, r.rid))
    p_max = max((r.prompt_len for r in rows), default=prompt_len)
    m_max = max((r.max_tokens for r in rows), default=max_new)
    max_seq = p_max + m_max + 1
    # a multi-turn workload without history reuse still grows context per
    # turn (each later turn adds ``prompt_len`` fresh tokens + its reply);
    # max_seq must cover the final turn's full conversation
    turn_seq = (p_max + m_max) + (turns - 1) * (prompt_len + m_max) + 1
    if virtual_clock is True:
        virtual_clock = VirtualClock()
    engine = DecodeEngine(cfg, params, serve_step, prefill_step,
                          max_batch=max_batch,
                          max_seq=max(max_seq, turn_seq), kvp=kvp,
                          hx=hx, chunk_tokens=chunk_tokens if chunked else None,
                          chunk_prefill_step=chunk_step,
                          tp_width=mesh.shape["model"],
                          sched_policy=sched_policy,
                          pool_blocks=pool_blocks,
                          prefix_share=prefix_share,
                          host_pages=host_pages, session_kv=session_kv,
                          fault_plan=fault_plan,
                          tenants=({t.name: t.tenant_config()
                                    for t in tenants} if tenants else None),
                          slo_ttl_s=(slo_ttl_ms / 1e3) if slo_ttl_ms else None,
                          clock=virtual_clock or time.monotonic,
                          sampling=sp, decode_window=decode_window,
                          serve_multistep=multistep)
    log(f"[serve] backends: {engine.describe_backends()}")
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, shared_prefix_len).tolist()
    pending = requests_from_trace(rows, cfg.vocab, shared_prefix=shared)
    if turns > 1:
        for r in pending:
            if r.session_id is None:
                r.session_id = f"s{r.rid}"
    arrivals = [r.arrival_step for r in rows]
    turn_of = {r.rid: 1 for r in pending}
    next_rid = max((r.rid for r in pending), default=-1) + 1
    finished: list[Request] = []
    t0 = time.time()
    steps = 0
    while pending or engine.pending():
        while pending and arrivals[0] <= steps:
            engine.submit(pending.pop(0))
            arrivals.pop(0)
        for r in engine.step():
            finished.append(r)
            t = turn_of[r.rid]
            if (turns > 1 and t < turns and r.session_id is not None
                    and r.finish_reason in ("eos", "max_tokens")):
                # next turn: full conversation so far + fresh "user" text;
                # with session_kv the engine restores the history pages
                # and only the fresh tokens ever prefill
                nxt = Request(
                    rid=next_rid,
                    prompt=(list(r.prompt) + list(r.out_tokens)
                            + rng.integers(0, cfg.vocab, prompt_len).tolist()),
                    max_new_tokens=max_new, session_id=r.session_id,
                    tenant=r.tenant, slo_class=r.slo_class)
                turn_of[next_rid] = t + 1
                next_rid += 1
                engine.submit(nxt)
        steps += 1
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in finished)
    summary = engine.metrics.summary()
    summary.update(engine.pool_stats())
    summary.update(engine.tier_stats())
    summary.update(engine.sync_stats())
    summary["trace_id"] = trace_id(rows)
    late = [engine.metrics.requests[r.rid].ttft for r in finished
            if turn_of.get(r.rid, 1) >= 2
            and engine.metrics.requests[r.rid].ttft is not None]
    summary["turn2_ttft_s"] = float(np.mean(late)) if late else 0.0
    log(f"[serve] {len(finished)} requests, {toks} tokens in {dt:.2f}s "
        f"({toks / max(dt, 1e-9):.1f} tok/s, {steps} engine steps)")
    return finished, summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--chunk-tokens", type=int, default=0,
                    help="prefill prompts in this many tokens per engine "
                         "step, interleaved with decode (0 = one-shot "
                         "prefill; bit-exact either way)")
    ap.add_argument("--sched-policy", default="fcfs", choices=POLICIES,
                    help="admission order: fcfs (arrival) or sjf (shortest "
                         "remaining prefill first)")
    ap.add_argument("--traffic", default="batch",
                    choices=("batch", "poisson", "bursty"),
                    help="batch: submit all requests up front; poisson: "
                         "synthetic arrival process over engine steps; "
                         "bursty: closed flash-crowd bursts with poisson "
                         "gaps (serving/workload.py)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="poisson/bursty traffic: mean requests per engine "
                         "step")
    ap.add_argument("--burst", type=int, default=4,
                    help="bursty traffic: simultaneous arrivals per burst")
    ap.add_argument("--trace", default=None,
                    help="replay a saved serving/workload.py JSONL trace "
                         "instead of generating one from --traffic (the "
                         "summary's trace_id names the workload either way)")
    ap.add_argument("--tenants", default=None,
                    help="tenant mix 'name[:weight[:slo[:share]]],...' "
                         "(e.g. 'chat:3:interactive,jobs:1:batch'); arms "
                         "deficit-weighted-fair admission across tenants")
    ap.add_argument("--slo-ttl-ms", type=float, default=0.0,
                    help="interactive TTL p95 target in ms; > 0 arms the "
                         "TTL governor, which sheds batch-class slots "
                         "through the host-tier spill path when the target "
                         "is exceeded (serving/governor.py)")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="use the deterministic cost-model metrics clock "
                         "(VirtualClock) so replaying the same trace "
                         "reproduces the latency summary bit-for-bit")
    ap.add_argument("--metrics", action="store_true",
                    help="print the TTFT/TTL/queue-wait summary JSON")
    ap.add_argument("--attn-backend", default=None, choices=BACKENDS,
                    help="flash_decode backend for decode attention "
                         "(default: HelixConfig's, i.e. 'ref'; 'pallas' "
                         "needs a TPU)")
    ap.add_argument("--prefill-backend", default=None, choices=BACKENDS,
                    help="flash_prefill backend for prompt prefill")
    ap.add_argument("--ssd-backend", default=None, choices=BACKENDS,
                    help="ssd_prefill backend for the Mamba2 SSD scan core")
    ap.add_argument("--matmul-backend", default=None, choices=BACKENDS,
                    help="w8a16_matmul backend for the quantized lm_head "
                         "matmul (only used with --lm-head-w8)")
    ap.add_argument("--lm-head-w8", action="store_true",
                    help="int8-quantize the lm_head weights and route the "
                         "logits matmul through the w8a16_matmul family")
    ap.add_argument("--no-fuse-append", action="store_true",
                    help="disable the fused KV-append kernel epilogue "
                         "(pallas backends append via a separate cache pass)")
    ap.add_argument("--no-prune-blocks", action="store_true",
                    help="disable length/causality-aware K/V block pruning "
                         "in the Pallas attention kernels (dense masked "
                         "sweep; bit-exact either way)")
    ap.add_argument("--paged-kv", action="store_true",
                    help="shared-pool paged KV cache: K/V in pool pages "
                         "with per-request block tables; cache pressure "
                         "becomes a global free-page admission signal "
                         "(bit-exact vs the fixed per-slot layout)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged mode: total pool pages incl. the sink page "
                         "(default: the same HBM the fixed layout reserves)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="prefix index + refcounted copy-on-write page "
                         "sharing: prompts matching a cached prefix map the "
                         "shared pages and prefill only their suffix (needs "
                         "--paged-kv and --chunk-tokens; bit-exact)")
    ap.add_argument("--grouped-decode", action="store_true",
                    help="grouped shared-prefix decode: requests whose "
                         "tables share leading pages read them once per "
                         "group per step instead of once per request "
                         "(needs --paged-kv; bit-exact)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="synthetic workload: every prompt starts with the "
                         "same this-many tokens (exercises --prefix-share)")
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host KV tier capacity in pool pages: preempted "
                         "requests spill their live pages and resume with "
                         "zero re-prefill chunks (needs --paged-kv; 0 = no "
                         "spill tier)")
    ap.add_argument("--session-kv", action="store_true",
                    help="persist retired requests' KV pages in the host "
                         "tier keyed by session id, so the next turn of a "
                         "multi-turn conversation restores its history "
                         "instead of re-prefilling it (needs --paged-kv)")
    ap.add_argument("--fault-plan", default=None,
                    help="inject host-tier faults, 'k=v,...' over seed/"
                         "restore_fail/corrupt/store_full/delay/delay_steps "
                         "(e.g. 'seed=1,restore_fail=0.5,delay=0.2'); every "
                         "injected fault degrades to re-prefill, never to "
                         "divergent tokens")
    ap.add_argument("--turns", type=int, default=1,
                    help="multi-turn workload: each request is a session "
                         "whose turn t+1 resubmits its full context plus "
                         "fresh tokens (pairs with --session-kv; the "
                         "summary's turn2_ttft_s isolates the benefit)")
    ap.add_argument("--decode-window", type=int, default=1,
                    help="decode steps per device dispatch: the lax.scan "
                         "multi-step path syncs ONE [batch, N] token block "
                         "per window instead of one transfer per token "
                         "(streams bit-identical to N=1; "
                         "scripts/decode_window_smoke.py)")
    ap.add_argument("--sampling", default=None, choices=SAMPLING_KINDS,
                    help="on-device token sampling kind (default: host-free "
                         "greedy argmax on device, same as 'greedy'); "
                         "temperature/top_k/top_p read the flags below; "
                         "per-request PRNG streams are keyed by --seed + "
                         "request id (serving/sampling.py)")
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="softmax temperature for --sampling temperature/"
                         "top_k/top_p (> 0; <= 0 would mean greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep the k highest logits before sampling "
                         "(--sampling top_k; 0 = no truncation)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass for --sampling top_p "
                         "(in (0, 1]; 1.0 = no truncation)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base PRNG seed: model init and the per-request "
                         "sampling streams (request rid folds in, so "
                         "streams are independent and replayable)")
    ap.add_argument("--list-backends", action="store_true",
                    help="print the kernel registry's per-family backend "
                         "availability matrix and exit")
    args = ap.parse_args()
    if args.list_backends:
        print(backend_table())
        return
    if not args.arch:
        ap.error("--arch is required (or use --list-backends)")
    _, summary = serve_demo(
        args.arch, reduced=args.reduced, n_requests=args.requests,
        prompt_len=args.prompt_len, max_new=args.max_new,
        max_batch=args.max_batch, attn_backend=args.attn_backend,
        prefill_backend=args.prefill_backend,
        ssd_backend=args.ssd_backend,
        matmul_backend=args.matmul_backend,
        fuse_append=False if args.no_fuse_append else None,
        prune_blocks=False if args.no_prune_blocks else None,
        lm_head_w8=True if args.lm_head_w8 else None,
        paged_kv=True if args.paged_kv else None,
        pool_blocks=args.pool_blocks,
        prefix_share=args.prefix_share,
        grouped_decode=True if args.grouped_decode else None,
        shared_prefix_len=args.shared_prefix_len,
        host_pages=args.host_pages, session_kv=args.session_kv,
        fault_plan=args.fault_plan, turns=args.turns,
        chunk_tokens=args.chunk_tokens, sched_policy=args.sched_policy,
        traffic=args.traffic, arrival_rate=args.arrival_rate,
        burst=args.burst, trace=args.trace, tenants=args.tenants,
        slo_ttl_ms=args.slo_ttl_ms, virtual_clock=args.virtual_clock,
        decode_window=args.decode_window, sampling=args.sampling,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        seed=args.seed)
    if args.metrics:
        print(json.dumps(summary, indent=2, default=float))


if __name__ == "__main__":
    main()
