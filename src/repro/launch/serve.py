"""Serving driver: batched decode with the Helix engine.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --reduced \
      --requests 8 --prompt-len 32 --max-new 16

Kernel backends (kernels/registry.py) are selectable per family:
``--attn-backend`` routes the decode attention (flash_decode),
``--prefill-backend`` the full-sequence prefill attention (flash_prefill),
``--ssd-backend`` the Mamba2 SSD scan core (ssd_prefill),
``--matmul-backend`` the w8a16 int8-weight matmul (with ``--lm-head-w8``
quantizing the lm_head onto it); ``--no-fuse-append`` opts out of the fused
KV-append kernel epilogue and ``--no-prune-blocks`` of the length/causality-
aware K/V block pruning (both bit-exact).  ``--list-backends`` prints the
per-family availability matrix and exits (CI smoke target).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.kernels.registry import BACKENDS, backend_table
from repro.models.model_zoo import (build_serve_step, make_prefill_step)
from repro.models.transformer import init_params
from repro.serving import DecodeEngine, Request
from repro.utils import make_mesh


def serve_demo(arch: str, *, reduced: bool, n_requests: int, prompt_len: int,
               max_new: int, max_batch: int = 8, mesh=None, hx=None,
               attn_backend: str | None = None,
               prefill_backend: str | None = None,
               ssd_backend: str | None = None,
               matmul_backend: str | None = None,
               fuse_append: bool | None = None,
               prune_blocks: bool | None = None,
               lm_head_w8: bool | None = None,
               seed: int = 0, log=print):
    """Run ``n_requests`` synthetic prompts through the continuous-batching
    engine and report throughput.  Returns the finished ``Request`` list.

    The ``*_backend`` arguments override the corresponding ``hx`` fields
    (``None`` keeps the ``HelixConfig`` defaults); see kernels/registry.py.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    if hx is None:
        # single-device default; on a real mesh the caller supplies hx
        hx = HelixConfig(kvp_axes=("data",) if mesh is None else (),
                         tpa_axis=None)
    overrides = {k: v for k, v in [("attn_backend", attn_backend),
                                   ("prefill_backend", prefill_backend),
                                   ("ssd_backend", ssd_backend),
                                   ("matmul_backend", matmul_backend),
                                   ("fuse_append", fuse_append),
                                   ("prune_blocks", prune_blocks),
                                   ("lm_head_w8", lm_head_w8)]
                 if v is not None}
    if overrides:
        hx = dataclasses.replace(hx, **overrides)
    kvp = hx.kvp(mesh) if mesh else 1
    max_seq = prompt_len + max_new + 1

    if mesh is not None:
        serve_step = build_serve_step(cfg, mesh, hx)
        prefill_step = make_prefill_step(cfg, mesh, hx)
    else:
        # single-device: 1x1 trivial mesh keeps one code path
        mesh1 = make_mesh((1, 1), ("data", "model"))
        serve_step = build_serve_step(cfg, mesh1, hx)
        prefill_step = make_prefill_step(cfg, mesh1, hx)

    engine = DecodeEngine(cfg, params, serve_step, prefill_step,
                          max_batch=max_batch, max_seq=max_seq, kvp=kvp,
                          hx=hx)
    log(f"[serve] backends: {engine.describe_backends()}")
    rng = np.random.default_rng(seed)
    pending = [Request(rid=i,
                       prompt=rng.integers(0, cfg.vocab, prompt_len).tolist(),
                       max_new_tokens=max_new)
               for i in range(n_requests)]
    finished: list[Request] = []
    t0 = time.time()
    steps = 0
    while pending or any(engine.slots):
        while pending and engine.add_request(pending[0]):
            pending.pop(0)
        finished += engine.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in finished)
    log(f"[serve] {len(finished)} requests, {toks} tokens in {dt:.2f}s "
        f"({toks / max(dt, 1e-9):.1f} tok/s, {steps} engine steps)")
    return finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--attn-backend", default=None, choices=BACKENDS,
                    help="flash_decode backend for decode attention "
                         "(default: HelixConfig's, i.e. 'ref'; 'pallas' "
                         "needs a TPU)")
    ap.add_argument("--prefill-backend", default=None, choices=BACKENDS,
                    help="flash_prefill backend for prompt prefill")
    ap.add_argument("--ssd-backend", default=None, choices=BACKENDS,
                    help="ssd_prefill backend for the Mamba2 SSD scan core")
    ap.add_argument("--matmul-backend", default=None, choices=BACKENDS,
                    help="w8a16_matmul backend for the quantized lm_head "
                         "matmul (only used with --lm-head-w8)")
    ap.add_argument("--lm-head-w8", action="store_true",
                    help="int8-quantize the lm_head weights and route the "
                         "logits matmul through the w8a16_matmul family")
    ap.add_argument("--no-fuse-append", action="store_true",
                    help="disable the fused KV-append kernel epilogue "
                         "(pallas backends append via a separate cache pass)")
    ap.add_argument("--no-prune-blocks", action="store_true",
                    help="disable length/causality-aware K/V block pruning "
                         "in the Pallas attention kernels (dense masked "
                         "sweep; bit-exact either way)")
    ap.add_argument("--list-backends", action="store_true",
                    help="print the kernel registry's per-family backend "
                         "availability matrix and exit")
    args = ap.parse_args()
    if args.list_backends:
        print(backend_table())
        return
    if not args.arch:
        ap.error("--arch is required (or use --list-backends)")
    serve_demo(args.arch, reduced=args.reduced, n_requests=args.requests,
               prompt_len=args.prompt_len, max_new=args.max_new,
               max_batch=args.max_batch, attn_backend=args.attn_backend,
               prefill_backend=args.prefill_backend,
               ssd_backend=args.ssd_backend,
               matmul_backend=args.matmul_backend,
               fuse_append=False if args.no_fuse_append else None,
               prune_blocks=False if args.no_prune_blocks else None,
               lm_head_w8=True if args.lm_head_w8 else None)


if __name__ == "__main__":
    main()
