"""End-to-end training driver: data pipeline -> train_step (pjit) ->
checkpoint/restart with watchdog.  Runs reduced configs on CPU (examples/
train_tiny_lm.py) and the full mesh on real pods (same code path; the mesh
argument is the only difference).

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir runs/ckpt
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.models.model_zoo import make_train_step
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import StepWatchdog, run_with_retries


def train(arch: str, *, reduced: bool, steps: int, batch: int, seq: int,
          lr: float = 3e-4, ckpt_dir: str | None = None, save_every: int = 50,
          mesh=None, seed: int = 0, log_every: int = 10,
          step_timeout_s: float = 600.0, param_dtype=jnp.float32,
          prefill_backend: str = "ref", ssd_backend: str = "ref",
          prune_blocks: bool = True, log=print):
    """Train ``arch`` for ``steps`` optimizer steps; returns (params,
    opt_state, losses).

    ``prefill_backend`` / ``ssd_backend`` route the attention and SSD-scan
    hotspots through the kernel registry (kernels/registry.py); the pallas
    backends carry a ref-VJP backward, so they compose with value_and_grad.
    """
    # fail fast on unavailable kernel backends (e.g. compiled 'pallas' on a
    # CPU host) instead of dying inside the first jit'd step's lowering
    from repro.kernels import registry
    for family, be in (("flash_prefill", prefill_backend),
                       ("ssd_prefill", ssd_backend)):
        ok, why = registry.available(family, be)
        if not ok:
            raise RuntimeError(f"{family} backend {be!r} unavailable: {why}")
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    optcfg = AdamWConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 5))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                    global_batch=batch, seed=seed))
    params = init_params(cfg, jax.random.PRNGKey(seed), dtype=param_dtype)
    opt_state = adamw_init(params, optcfg)
    step_fn = jax.jit(make_train_step(cfg, mesh, optcfg, chunk_q=min(seq, 512),
                                      prefill_backend=prefill_backend,
                                      ssd_backend=ssd_backend,
                                      prune_blocks=prune_blocks))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start = 0
    if mgr and mgr.latest_step() is not None:
        start = mgr.latest_step()
        params, opt_state = mgr.restore((params, opt_state))
        log(f"[train] resumed from step {start}")

    losses = []

    def body(step, state):
        params, opt_state = state
        batch_np = pipe.batch(step)
        batch_dev = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.vision_patches:
            batch_dev["patch_embeds"] = jnp.zeros(
                (batch, cfg.vision_patches, cfg.d_model), param_dtype)
        if cfg.is_encdec:
            rng = np.random.default_rng((seed, step, 7))
            batch_dev["enc_frames"] = jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)) * 0.02,
                param_dtype)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            log(f"[train] step {step:5d} loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} "
                f"gnorm {float(metrics['grad_norm']):.2f} "
                f"({time.time() - t0:.2f}s)")
        return params, opt_state

    watchdog = StepWatchdog(step_timeout_s)
    save_fn = (lambda s, st: mgr.save(s, st)) if mgr else None
    restore_fn = None
    if mgr:
        def restore_fn():
            s = mgr.latest_step()
            return s, mgr.restore((params, opt_state))

    _, (params, opt_state) = run_with_retries(
        body, (params, opt_state), start_step=start, num_steps=steps - start,
        save_fn=save_fn, restore_fn=restore_fn, save_every=save_every,
        watchdog=watchdog, log=log)
    if mgr:
        mgr.save(steps, (params, opt_state))
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    from repro.kernels.registry import BACKENDS
    ap.add_argument("--prefill-backend", default="ref", choices=BACKENDS,
                    help="flash_prefill backend for full-sequence attention "
                         "(ref-VJP backward on the pallas backends)")
    ap.add_argument("--ssd-backend", default="ref", choices=BACKENDS,
                    help="ssd_prefill backend for the Mamba2 SSD scan core")
    ap.add_argument("--no-prune-blocks", action="store_true",
                    help="disable flash_prefill's causal/window block skip "
                         "(dense masked sweep; bit-exact either way)")
    args = ap.parse_args()
    _, _, losses = train(args.arch, reduced=args.reduced, steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=args.lr,
                         ckpt_dir=args.ckpt_dir,
                         prefill_backend=args.prefill_backend,
                         ssd_backend=args.ssd_backend,
                         prune_blocks=not args.no_prune_blocks)
    print(f"[train] done; first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
