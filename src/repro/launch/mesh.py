"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS *before* first
jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 across two pods (DCN axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(shape))


def device_count_required(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
