"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS *before* first
jax init.  All meshes go through ``repro.utils.make_mesh`` so the
``axis_types`` kwarg is only passed on JAX versions that support it.
"""
from __future__ import annotations

from repro.utils import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 across two pods (DCN axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 fake devices)."""
    return make_mesh(shape, axes)


def device_count_required(multi_pod: bool = False) -> int:
    return 512 if multi_pod else 256
