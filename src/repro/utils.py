"""Small shared utilities: padding, rounding, dtype helpers — plus the
JAX-version compat shims (``make_mesh`` / ``set_mesh`` / ``shard_map``) every
entrypoint must use instead of the raw jax APIs (the installed JAX may predate
``jax.sharding.AxisType``, ``jax.set_mesh`` and ``jax.shard_map``)."""
from __future__ import annotations

import inspect
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf inside kernels (avoids NaN in exp/max)


# ------------------------------------------------------- jax compat shims
def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with Auto axis_types on JAX versions that take them.

    Older JAX (< 0.6) has neither ``jax.sharding.AxisType`` nor the
    ``axis_types=`` kwarg; every axis is implicitly Auto there, so dropping
    the argument is semantics-preserving.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params and hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(tuple(shape))
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def set_mesh(mesh):
    """``jax.set_mesh`` context manager; on older JAX the Mesh object itself
    is the context manager with the same scoping behaviour."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions.

    New JAX spells the replication-check kwarg ``check_vma``; the
    experimental predecessor spells it ``check_rep``.  Semantics match.
    The promotion to ``jax.shard_map`` and the kwarg rename were separate
    changes, so the spelling is keyed off the signature, not the location.
    """
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    kw = ("check_vma" if "check_vma" in inspect.signature(fn).parameters
          else "check_rep")
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check_vma})


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def pad_dim(x, dim: int, multiple: int, value=0.0):
    """Pad dimension `dim` of x up to a multiple of `multiple`."""
    size = x.shape[dim]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[dim] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def unpad_dim(x, dim: int, size: int):
    if x.shape[dim] == size:
        return x
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(0, size)
    return x[tuple(idx)]


def bytes_of(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"
