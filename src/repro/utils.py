"""Small shared utilities: padding, rounding, dtype helpers."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30  # finite stand-in for -inf inside kernels (avoids NaN in exp/max)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def pad_dim(x, dim: int, multiple: int, value=0.0):
    """Pad dimension `dim` of x up to a multiple of `multiple`."""
    size = x.shape[dim]
    target = round_up(size, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[dim] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def unpad_dim(x, dim: int, size: int):
    if x.shape[dim] == size:
        return x
    idx = [slice(None)] * x.ndim
    idx[dim] = slice(0, size)
    return x[tuple(idx)]


def bytes_of(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} PiB"
