"""Trace-driven workload model: schema-versioned request traces + generators.

Serving-system claims only hold up under reproducible, production-shaped
load — not hand-picked request sets.  This module is the single arrival
process for the whole repo (launch/serve.py and benchmarks/bench_serving.py
both route through it): a **trace** is a list of ``TraceRow``s, one per
request, each pinning

    (arrival_step, tenant, slo_class, prompt_len, max_tokens,
     session_id, seed)

so the same file replays bit-identically through any engine configuration
(tests/serving/test_trace_replay.py, scripts/trace_smoke.py).  Prompts are
*materialized* from the per-row ``seed`` (``prompt_tokens``), never stored,
which keeps multi-million-token traces a few bytes per request.

On disk a trace is JSONL: a header line ``{"schema": 1, "kind":
"helix-trace", "meta": {...}}`` followed by one row object per line
(``save_trace`` / ``load_trace``; unknown schema versions refuse to load
rather than misparse).

Generators: ``poisson_arrival_steps`` (exponential inter-arrival gaps —
absorbed from launch/serve.py, which re-exports it) and
``bursty_arrival_steps`` (closed bursts separated by Poisson gaps) shape
arrivals; ``generate_trace`` mixes tenants per ``TenantSpec`` shares and
draws per-row prompt/output lengths from each tenant's ranges.  With the
default single-tenant spec and ``arrival="poisson"`` the arrival steps are
exactly ``poisson_arrival_steps(n, rate, seed)`` — the regression pin that
keeps old ``--traffic poisson --arrival-rate`` behavior reproducible
(tests/serving/test_workload.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from repro.serving.scheduler import (SLO_CLASSES, SLO_INTERACTIVE,
                                     Request, TenantConfig)

TRACE_SCHEMA = 1
TRACE_KIND = "helix-trace"

# row fields in canonical serialization order (schema version 1)
_ROW_FIELDS = ("rid", "arrival_step", "tenant", "slo_class", "prompt_len",
               "max_tokens", "session_id", "seed")


@dataclasses.dataclass(frozen=True)
class TraceRow:
    """One trace request: arrival time (in engine steps), tenancy/SLO
    tags, prompt/output lengths, optional multi-turn session id, and the
    per-row ``seed`` its synthetic prompt tokens are materialized from
    (``prompt_tokens``) — everything a replay needs, nothing more."""
    rid: int
    arrival_step: int
    tenant: str = "default"
    slo_class: str = SLO_INTERACTIVE
    prompt_len: int = 32
    max_tokens: int = 16
    session_id: str | None = None
    seed: int = 0

    def validate(self) -> None:
        """Assert the row is well-formed (schema v1 value constraints)."""
        assert self.rid >= 0, f"rid must be >= 0: {self}"
        assert self.arrival_step >= 0, f"arrival_step must be >= 0: {self}"
        assert self.tenant, f"empty tenant name: {self}"
        assert self.slo_class in SLO_CLASSES, \
            f"slo_class {self.slo_class!r} not in {SLO_CLASSES}"
        assert self.prompt_len >= 1, f"prompt_len must be >= 1: {self}"
        assert self.max_tokens >= 1, f"max_tokens must be >= 1: {self}"
        assert self.seed >= 0, f"seed must be >= 0: {self}"

    def to_json(self) -> str:
        """Canonical one-line JSON for the trace file (fixed key order,
        so byte-identical rows hash identically in ``trace_id``)."""
        return json.dumps({k: getattr(self, k) for k in _ROW_FIELDS})

    @classmethod
    def from_json(cls, line: str) -> "TraceRow":
        """Parse one trace-file row line (inverse of ``to_json``)."""
        d = json.loads(line)
        unknown = set(d) - set(_ROW_FIELDS)
        assert not unknown, f"unknown trace row fields: {sorted(unknown)}"
        row = cls(**d)
        row.validate()
        return row


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's slice of a generated workload: its DWFQ ``weight``,
    SLO class, ``share`` of arrivals, and per-request prompt/output
    length ranges (inclusive; ``None`` = the driver's defaults)."""
    name: str
    weight: float = 1.0
    slo_class: str = SLO_INTERACTIVE
    share: float = 1.0
    prompt_len: tuple[int, int] | None = None
    max_tokens: tuple[int, int] | None = None

    def tenant_config(self) -> TenantConfig:
        """The scheduler-side ``TenantConfig`` this spec implies."""
        return TenantConfig(name=self.name, weight=self.weight)


def parse_tenants(spec: str) -> tuple[TenantSpec, ...]:
    """Parse the CLI tenant-mix spec ``"name[:weight[:slo[:share]]],..."``
    (e.g. ``"chat:3:interactive,jobs:1:batch"``) into ``TenantSpec``s.
    Omitted fields default to weight 1.0, class interactive, share =
    weight (heavier tenants also send proportionally more traffic)."""
    out = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        assert len(bits) <= 4, f"bad tenant spec {part!r}"
        name = bits[0]
        weight = float(bits[1]) if len(bits) > 1 and bits[1] else 1.0
        slo = bits[2] if len(bits) > 2 and bits[2] else SLO_INTERACTIVE
        assert slo in SLO_CLASSES, \
            f"tenant {name!r}: slo {slo!r} not in {SLO_CLASSES}"
        share = float(bits[3]) if len(bits) > 3 and bits[3] else weight
        out.append(TenantSpec(name=name, weight=weight, slo_class=slo,
                              share=share))
    assert out, f"no tenants in spec {spec!r}"
    return tuple(out)


# ------------------------------------------------------------- arrivals
def poisson_arrival_steps(n: int, rate: float, seed: int = 0) -> list[int]:
    """Synthetic Poisson traffic: the engine step at which each of ``n``
    requests arrives, with exponential inter-arrival gaps of mean
    ``1/rate`` steps (``rate`` = average arrivals per engine step)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), size=n)
    return np.floor(np.cumsum(gaps)).astype(int).tolist()


def bursty_arrival_steps(n: int, rate: float, burst: int = 4,
                         seed: int = 0) -> list[int]:
    """Bursty traffic: requests arrive in closed bursts of ``burst``
    simultaneous arrivals, with Poisson gaps between bursts sized so the
    long-run average stays ``rate`` requests per step — the flash-crowd
    shape that stresses admission fairness harder than smooth Poisson."""
    assert burst >= 1, burst
    rng = np.random.default_rng(seed)
    n_bursts = -(-n // burst)
    gaps = rng.exponential(burst / max(rate, 1e-9), size=n_bursts)
    starts = np.floor(np.cumsum(gaps)).astype(int)
    return [int(starts[i // burst]) for i in range(n)]


# ------------------------------------------------------------ generator
def generate_trace(n: int, *, arrival: str = "poisson", rate: float = 0.5,
                   burst: int = 4,
                   tenants: tuple[TenantSpec, ...] = (TenantSpec("default"),),
                   prompt_len: int = 32, max_tokens: int = 16,
                   seed: int = 0) -> list[TraceRow]:
    """Generate an ``n``-request trace: arrivals per ``arrival`` shape
    (``"poisson"`` | ``"bursty"`` | ``"batch"`` — all at step 0), tenants
    assigned by normalized ``share``, and per-row prompt/output lengths
    drawn uniformly from each tenant's ranges (``prompt_len`` /
    ``max_tokens`` fill in for specs that leave them ``None``).

    Arrival steps use the base ``seed`` directly, so a single-tenant
    Poisson trace arrives exactly at ``poisson_arrival_steps(n, rate,
    seed)`` (the old ``--traffic poisson`` behavior); tenant assignment
    and lengths draw from a derived stream so adding tenants never
    perturbs the arrival process."""
    if arrival == "poisson":
        steps = poisson_arrival_steps(n, rate, seed)
    elif arrival == "bursty":
        steps = bursty_arrival_steps(n, rate, burst, seed)
    elif arrival == "batch":
        steps = [0] * n
    else:
        raise ValueError(f"unknown arrival shape {arrival!r}; choose from "
                         "('poisson', 'bursty', 'batch')")
    rng = np.random.default_rng([seed, 0xC0FFEE])
    shares = np.asarray([max(t.share, 0.0) for t in tenants], np.float64)
    assert shares.sum() > 0, "all tenant shares are zero"
    shares = shares / shares.sum()
    rows = []
    for rid in range(n):
        t = tenants[int(rng.choice(len(tenants), p=shares))]
        plo, phi = t.prompt_len or (prompt_len, prompt_len)
        mlo, mhi = t.max_tokens or (max_tokens, max_tokens)
        rows.append(TraceRow(
            rid=rid, arrival_step=int(steps[rid]), tenant=t.name,
            slo_class=t.slo_class,
            prompt_len=int(rng.integers(plo, phi + 1)),
            max_tokens=int(rng.integers(mlo, mhi + 1)),
            seed=int(rng.integers(0, 2**31 - 1))))
    for r in rows:
        r.validate()
    return rows


# ------------------------------------------------------------ trace I/O
def save_trace(path, rows, meta: dict | None = None) -> None:
    """Write ``rows`` as a schema-versioned JSONL trace file: one header
    line (schema version + kind + optional ``meta``) then one canonical
    row object per line."""
    with open(path, "w") as f:
        f.write(json.dumps({"schema": TRACE_SCHEMA, "kind": TRACE_KIND,
                            "meta": meta or {}}) + "\n")
        for r in rows:
            r.validate()
            f.write(r.to_json() + "\n")


def load_trace(path) -> list[TraceRow]:
    """Load a JSONL trace written by ``save_trace``, validating the
    header (kind + supported schema version — unknown versions raise
    instead of misparsing) and every row."""
    with open(path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    assert lines, f"empty trace file: {path}"
    head = json.loads(lines[0])
    if head.get("kind") != TRACE_KIND:
        raise ValueError(f"{path}: not a {TRACE_KIND} file "
                         f"(header {head!r})")
    if head.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"{path}: unsupported trace schema "
                         f"{head.get('schema')!r} (this reader speaks "
                         f"{TRACE_SCHEMA})")
    rows = [TraceRow.from_json(ln) for ln in lines[1:]]
    rids = [r.rid for r in rows]
    assert len(rids) == len(set(rids)), "duplicate rids in trace"
    return rows


def trace_id(rows) -> str:
    """Short stable content hash of a trace (canonical row JSON) — the
    reproducible address bench rows carry so a measurement always names
    the exact workload that produced it."""
    h = hashlib.sha256()
    for r in rows:
        h.update(r.to_json().encode())
        h.update(b"\n")
    return h.hexdigest()[:12]


# ------------------------------------------------------- materialization
def prompt_tokens(row: TraceRow, vocab: int,
                  shared_prefix=()) -> list[int]:
    """Materialize ``row``'s synthetic prompt: the workload-wide
    ``shared_prefix`` (truncated to the row's length) plus a suffix drawn
    deterministically from the row's own ``seed`` — same row, same
    tokens, on every replay."""
    shared = list(shared_prefix)[:row.prompt_len]
    rng = np.random.default_rng(row.seed)
    suffix = rng.integers(0, vocab, row.prompt_len - len(shared)).tolist()
    return shared + suffix


def requests_from_trace(rows, vocab: int, *, eos_id: int | None = None,
                        shared_prefix=()) -> list[Request]:
    """Build engine ``Request``s from trace rows (prompts materialized
    via ``prompt_tokens``), carrying each row's tenant / SLO class /
    session id into the scheduler's tenancy layer."""
    return [Request(rid=r.rid, prompt=prompt_tokens(r, vocab, shared_prefix),
                    max_new_tokens=r.max_tokens, eos_id=eos_id,
                    session_id=r.session_id, tenant=r.tenant,
                    slo_class=r.slo_class)
            for r in rows]
