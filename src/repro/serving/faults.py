"""Deterministic fault injection for the host KV tier (serving/tier.py).

Robustness is only provable if every failure surface can be *driven*: a
``FaultPlan`` is a seeded, declarative description of which host-tier
faults to inject and how often, so a chaos run (scripts/chaos_smoke.py,
tests/serving/test_spill_restore_exact.py) replays the exact same fault
sequence every time.  The four injectable faults mirror the tier's real
failure modes:

  ``restore_fail``  the restore RPC/copy is lost — ``HostPageStore.restore``
                    returns nothing and the engine must fall back to
                    re-prefill;
  ``corrupt``       host memory corruption — one stored page is damaged
                    *after* its checksum was computed (a byte flip) or its
                    generation stamp is bumped, so the restore-time
                    verification detects it;
  ``store_full``    the host tier refuses a save (capacity exhausted
                    upstream) — the spill degrades to the old drop path;
  ``delay``         a slow host tier — the restore's pages arrive only
                    after ``delay_steps`` engine steps, overlapping decode.

Draws are made from one ``numpy`` generator seeded at construction, in a
fixed per-operation order, so a given (seed, op-stream) pair always yields
the same faults — the property the fault-matrix tests pin.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["FaultPlan"]

# injectable fault kinds, in the fixed per-operation draw order
_KINDS = ("store_full", "corrupt", "restore_fail", "delay")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded declarative fault schedule for the host page store.

    Each field is an injection probability in ``[0, 1]`` (0 = never, the
    default — a plan with all-zero rates injects nothing and draws
    nothing observable); ``delay_steps`` is how many engine steps a
    delayed restore withholds its pages.  Construct directly or via
    ``parse("seed=1,restore_fail=0.5,delay=1.0,delay_steps=4")``.
    """

    seed: int = 0
    restore_fail: float = 0.0
    corrupt: float = 0.0
    store_full: float = 0.0
    delay: float = 0.0
    delay_steps: int = 2

    def __post_init__(self):
        for kind in _KINDS:
            p = getattr(self, kind)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{kind} rate {p} outside [0, 1]")
        if self.delay_steps < 0:
            raise ValueError(f"delay_steps {self.delay_steps} < 0")

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a ``k=v,k=v`` CLI spec (``--fault-plan``).

        Keys are the dataclass fields; ``seed``/``delay_steps`` parse as
        int, rates as float.  Empty spec -> the inert default plan."""
        kw: dict[str, float | int] = {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "=" not in part:
                raise ValueError(f"fault-plan field {part!r} is not k=v")
            k, v = (s.strip() for s in part.split("=", 1))
            if k not in {f.name for f in dataclasses.fields(cls)}:
                raise ValueError(f"unknown fault-plan field {k!r}")
            kw[k] = int(v) if k in ("seed", "delay_steps") else float(v)
        return cls(**kw)

    def injector(self) -> "FaultInjector":
        """Fresh stateful draw stream for this plan (one per store)."""
        return FaultInjector(self)


class FaultInjector:
    """The stateful half of a ``FaultPlan``: one seeded draw stream.

    ``draw(kind)`` returns True when the fault fires and tallies it in
    ``injected``.  All-zero plans short-circuit without consuming
    generator state, so "no plan" and "inert plan" behave identically."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._rng = np.random.default_rng(plan.seed)
        self.injected: dict[str, int] = {k: 0 for k in _KINDS}

    @property
    def active(self) -> bool:
        """True when any fault has a non-zero rate."""
        return any(getattr(self.plan, k) > 0 for k in _KINDS)

    def draw(self, kind: str) -> bool:
        """One Bernoulli draw for ``kind``; tallies and returns the hit."""
        p = getattr(self.plan, kind)
        if p <= 0.0:
            return False
        hit = bool(self._rng.random() < p)
        if hit:
            self.injected[kind] += 1
        return hit

    def pick(self, n: int) -> int:
        """Deterministic index draw in ``[0, n)`` (corruption targets)."""
        return int(self._rng.integers(0, max(n, 1)))
