"""Admission scheduling for the continuous-batching engine (pure python).

The ``Scheduler`` owns everything the engine must *decide* (who runs where,
and when) without touching device state: the admission queue, the slot
table, per-slot committed cache lengths, and the cache-pressure gate.  It is
deliberately jax-free so its invariants can be property-tested exhaustively
(tests/serving/test_scheduler_props.py) with simulated request streams —
the ``DecodeEngine`` mirrors its decisions onto the device arrays.

Request lifecycle (docs/serving.md):

    QUEUED --admit--> PREFILL --last chunk--> DECODE --retire--> DONE
       ^                  |                      |
       +----preempt-------+----------preempt-----+

Policies: ``"fcfs"`` (arrival order) and ``"sjf"`` (shortest remaining
prefill first — cheap requests jump the queue, bounding their TTFT under
load).  Both apply the cache-pressure gate: a request whose prefill alone
cannot fit the per-slot cache capacity is rejected up front instead of
being admitted and immediately capacity-retired.  Preempted requests
re-enter at the front of the queue so they resume promptly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

# lifecycle states (plain strings so they serialize/log cleanly)
QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"

POLICIES = ("fcfs", "sjf")


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is the token list to prefill; the engine appends generated
    tokens to ``out_tokens`` and sets ``done``/``finish_reason`` on
    retirement (``"eos"`` | ``"max_tokens"`` | ``"capacity"`` |
    ``"rejected"``).  ``state`` tracks the scheduler lifecycle."""
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = QUEUED
    finish_reason: str | None = None
    preempted: bool = False                   # awaiting resume (front of queue)
    admit_seq: int = -1                       # admission order stamp
    # --- chunked-prefill bookkeeping (engine-internal) ---
    prefill_tokens: list[int] | None = None   # prompt (+ generated on resume)
    prefill_pos: int = 0                      # next chunk offset
    buffers: Any = None                       # K/V carry buffers (device)

    def resume_tokens(self) -> list[int]:
        """Tokens to (re-)prefill: the prompt plus anything already
        generated (preempted requests recompute their full context)."""
        return list(self.prompt) + list(self.out_tokens)


class Scheduler:
    """FCFS/SJF admission queue + slot table with cache-pressure gating.

    ``cap`` is the per-slot KV capacity; a slot's committed length may
    never reach it (the engine retires the request one token earlier —
    ``at_capacity``).  All methods are O(queue) python; the engine calls
    ``admit()`` once per step and mirrors the returned placements.

    **Capacity oracle** (the single authority both admission paths and the
    retirement check consult, so they can never disagree): with ``pool``
    (a ``serving/pool.BlockAllocator`` — the shared-pool paged KV cache)
    capacity is the *global* free-page count — ``fits`` asks whether the
    request could ever hold its prompt + one token in ``max_pages`` pages,
    ``can_admit_now`` whether that many pages are free *now* (otherwise the
    request stays queued instead of being rejected), and
    ``grow_for_next_token`` reserves the next decode token's page on
    demand.  Without ``pool`` the same three methods fall back to the
    per-slot ``cap`` gate (always-admissible once a slot is free)."""

    def __init__(self, max_batch: int, cap: int, policy: str = "fcfs",
                 pool=None, max_pages: int = 0):
        if policy not in POLICIES:
            raise ValueError(f"unknown sched policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.policy = policy
        self.cap = cap
        self.max_batch = max_batch
        self.pool = pool
        self.max_pages = max_pages or (pool.capacity if pool else 0)
        self.queue: list[Request] = []
        self.slot_rids: list[int | None] = [None] * max_batch
        self.slot_len: list[int] = [0] * max_batch
        self.rejected: list[Request] = []
        self._admit_seq = 0

    # ------------------------------------------------------------- queue
    def submit(self, req: Request, front: bool = False) -> None:
        """Enqueue ``req`` (``front=True`` = preemption resume priority)."""
        req.state = QUEUED
        if front:
            self.queue.insert(0, req)
        else:
            self.queue.append(req)

    def _pick(self) -> Request:
        # preempted requests resume first under EVERY policy — their
        # already-spent prefill/decode work must not be stranded behind a
        # stream of fresh short arrivals (they sit at the queue front)
        for r in self.queue:
            if r.preempted:
                return r
        if self.policy == "sjf":
            # min() is stable: earliest-queued wins among equal lengths
            return min(self.queue, key=lambda r: len(r.resume_tokens()))
        return self.queue[0]

    def _stamp(self, req: Request) -> None:
        # first admission only: a preempted request keeps its original
        # stamp, so it also keeps its seniority in the engine's
        # oldest-first prefill-chunk scheduling when it resumes
        if req.admit_seq < 0:
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
        req.preempted = False

    def free_slot(self) -> int | None:
        """Lowest free slot index, or None when the batch is full."""
        try:
            return self.slot_rids.index(None)
        except ValueError:
            return None

    def fits(self, req: Request) -> bool:
        """Cache-pressure gate: could ``req``'s prefill plus one generated
        token *ever* fit — the per-slot capacity (fixed layout), or
        ``max_pages`` of the shared pool (paged)?  False means reject."""
        need = len(req.resume_tokens()) + 1
        if self.pool is None:
            return need <= self.cap
        return self.pool.pages_for(need) <= min(self.pool.capacity,
                                                self.max_pages)

    def can_admit_now(self, req: Request) -> bool:
        """Whether the capacity oracle can grant ``req``'s admission
        reservation *right now*.  Fixed layout: always (the free slot IS
        the reservation).  Paged: the prompt + one token's pages must be on
        the free list; otherwise the request waits in the queue for running
        requests to retire and release pages."""
        if self.pool is None:
            return True
        return (self.pool.pages_for(len(req.resume_tokens()) + 1)
                <= self.pool.free_count)

    def grow_for_next_token(self, slot: int) -> list[int] | None:
        """Reserve whatever the *next* decode token needs for ``slot``.

        Returns the newly granted physical pages ([] when the committed
        length + 1 still fits the reservation — always, in the fixed
        layout, until ``cap``), or None when the request cannot grow:
        per-slot ``cap`` reached, ``max_pages`` reached, or the pool's free
        list is empty — the engine then retires it with
        ``finish_reason="capacity"``.  This is the paged twin of
        ``at_capacity`` with the reservation made atomically, so a
        concurrent admission cannot snatch the page between check and
        commit."""
        if self.pool is None:
            return None if self.slot_len[slot] + 1 >= self.cap else []
        rid = self.slot_rids[slot]
        assert rid is not None, slot
        need = self.pool.pages_for(self.slot_len[slot] + 1)
        have = len(self.pool.pages(rid))
        if need <= have:
            return []
        if need > self.max_pages:
            return None
        return self.pool.extend(rid, need - have)

    def reject(self, req: Request) -> None:
        """Retire ``req`` unplaced with ``finish_reason="rejected"``."""
        req.state = DONE
        req.done = True
        req.finish_reason = "rejected"
        self.rejected.append(req)

    # --------------------------------------------------------- admission
    def admit(self) -> list[tuple[Request, int]]:
        """Admit queued requests into free slots per policy.

        Returns the ``(request, slot)`` placements made this call.  The
        cache-pressure gate rejects requests whose prefill can never fit
        ``cap`` (they land in ``self.rejected`` with state DONE /
        ``finish_reason="rejected"`` and are NOT placed)."""
        placed: list[tuple[Request, int]] = []
        while self.queue:
            slot = self.free_slot()
            if slot is None:
                break
            req = self._pick()
            if not self.fits(req):            # can't even hold one new token
                self.queue.remove(req)
                self.reject(req)
                continue
            if not self.can_admit_now(req):
                # pool pressure: the pick waits (stays queued) for running
                # requests to release pages — no skip-ahead, so a big
                # request can't be starved by a stream of small ones
                break
            self.queue.remove(req)
            need = len(req.resume_tokens())
            if self.pool is not None:
                # reserve prompt + first-token pages up front: the chunked
                # prefill carries K/V in side buffers and commits them to
                # the pool only at finalize, so full reservation here keeps
                # multi-step prefills deadlock-free (no partial holds)
                got = self.pool.alloc(req.rid, self.pool.pages_for(need + 1))
                assert got is not None, "can_admit_now lied"
            req.state = PREFILL
            self._stamp(req)
            self.slot_rids[slot] = req.rid
            self.slot_len[slot] = need
            placed.append((req, slot))
        return placed

    def assign_direct(self, req: Request) -> int | None:
        """Bypass the queue: place ``req`` into a free slot now (the
        engine's legacy one-shot ``add_request`` path).  Returns the slot,
        or None when full — or when the cache-pressure gate rejects the
        request (``req.finish_reason == "rejected"``; same behavior as the
        ``admit()`` path, and it keeps ``slot_len < cap`` invariant-true).
        Both admission paths share the same capacity oracle (``fits`` /
        ``can_admit_now``), so they cannot disagree on what is admissible;
        under pool pressure (paged, pages busy *now*) the request is
        neither placed nor rejected — None, like a full batch."""
        slot = self.free_slot()
        if slot is None:
            return None
        if not self.fits(req):
            self.reject(req)
            return None
        if not self.can_admit_now(req):
            return None
        need = len(req.resume_tokens())
        if self.pool is not None:
            got = self.pool.alloc(req.rid, self.pool.pages_for(need + 1))
            assert got is not None, "can_admit_now lied"
        req.state = PREFILL
        self._stamp(req)
        self.slot_rids[slot] = req.rid
        self.slot_len[slot] = need
        return slot

    # ----------------------------------------------------------- running
    def on_token(self, slot: int) -> None:
        """Record one generated token committed to ``slot``'s cache."""
        self.slot_len[slot] += 1

    def at_capacity(self, slot: int) -> bool:
        """True when ``slot`` cannot hold another token (retire now).
        Read-only twin of ``grow_for_next_token`` — fixed: the per-slot
        ``cap`` is reached; paged: the next token's page can neither be
        covered by the reservation nor granted from the free list."""
        if self.pool is None:
            return self.slot_len[slot] + 1 >= self.cap
        rid = self.slot_rids[slot]
        need = self.pool.pages_for(self.slot_len[slot] + 1)
        have = len(self.pool.pages(rid)) if rid is not None else 0
        return need > have and (need > self.max_pages
                                or need - have > self.pool.free_count)

    def release(self, slot: int) -> None:
        """Free ``slot`` (request retired or preempted); paged mode also
        returns the request's pool pages to the free list — copy-free."""
        rid = self.slot_rids[slot]
        if self.pool is not None and rid is not None:
            self.pool.free(rid)
        self.slot_rids[slot] = None
        self.slot_len[slot] = 0

    def preempt(self, slot: int, req: Request) -> None:
        """Release ``slot`` and requeue ``req`` at the front; ``_pick``
        resumes preempted requests before anything else under every
        policy."""
        assert self.slot_rids[slot] == req.rid, (slot, req.rid)
        self.release(slot)
        req.preempted = True
        self.submit(req, front=True)

    # -------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert the scheduling invariants the property suite pins:
        no rid in two slots, queue and slots disjoint, committed lengths
        within capacity; paged mode additionally checks page conservation
        and that every slot's reservation covers its committed length."""
        live = [r for r in self.slot_rids if r is not None]
        assert len(live) == len(set(live)), f"slot double-assignment: {live}"
        qrids = [r.rid for r in self.queue]
        assert len(qrids) == len(set(qrids)), f"queue duplicates: {qrids}"
        assert not set(qrids) & set(live), "request both queued and placed"
        for s, (rid, ln) in enumerate(zip(self.slot_rids, self.slot_len)):
            if rid is None:
                continue
            if self.pool is None:
                assert 0 < ln < self.cap, \
                    f"slot {s} length {ln} violates capacity {self.cap}"
            else:
                have = len(self.pool.pages(rid))
                assert 0 < ln <= have * self.pool.block_s, \
                    f"slot {s} length {ln} exceeds its {have} pages"
                assert have <= self.max_pages, (s, have, self.max_pages)
        if self.pool is not None:
            self.pool.check_invariants()
            holders = {r for r in self.pool._pages if self.pool.pages(r)}
            assert holders <= set(live), \
                f"pages held by unplaced requests: {holders - set(live)}"
