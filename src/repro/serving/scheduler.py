"""Admission scheduling for the continuous-batching engine (pure python).

The ``Scheduler`` owns everything the engine must *decide* (who runs where,
and when) without touching device state: the admission queue, the slot
table, per-slot committed cache lengths, and the cache-pressure gate.  It is
deliberately jax-free so its invariants can be property-tested exhaustively
(tests/serving/test_scheduler_props.py) with simulated request streams —
the ``DecodeEngine`` mirrors its decisions onto the device arrays.

Request lifecycle (docs/serving.md):

    QUEUED --admit--> PREFILL --last chunk--> DECODE --retire--> DONE
       ^                  |                      |
       +----preempt-------+----------preempt-----+

Policies: ``"fcfs"`` (arrival order) and ``"sjf"`` (shortest remaining
prefill first — cheap requests jump the queue, bounding their TTFT under
load).  Both apply the cache-pressure gate: a request whose prefill alone
cannot fit the per-slot cache capacity is rejected up front instead of
being admitted and immediately capacity-retired.  Preempted requests
re-enter at the front of the queue so they resume promptly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

# lifecycle states (plain strings so they serialize/log cleanly)
QUEUED = "queued"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"

POLICIES = ("fcfs", "sjf")


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is the token list to prefill; the engine appends generated
    tokens to ``out_tokens`` and sets ``done``/``finish_reason`` on
    retirement (``"eos"`` | ``"max_tokens"`` | ``"capacity"`` |
    ``"rejected"``).  ``state`` tracks the scheduler lifecycle."""
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = QUEUED
    finish_reason: str | None = None
    preempted: bool = False                   # awaiting resume (front of queue)
    admit_seq: int = -1                       # admission order stamp
    # --- chunked-prefill bookkeeping (engine-internal) ---
    prefill_tokens: list[int] | None = None   # prompt (+ generated on resume)
    prefill_pos: int = 0                      # next chunk offset
    buffers: Any = None                       # K/V carry buffers (device)

    def resume_tokens(self) -> list[int]:
        """Tokens to (re-)prefill: the prompt plus anything already
        generated (preempted requests recompute their full context)."""
        return list(self.prompt) + list(self.out_tokens)


class Scheduler:
    """FCFS/SJF admission queue + slot table with cache-pressure gating.

    ``cap`` is the per-slot KV capacity; a slot's committed length may
    never reach it (the engine retires the request one token earlier —
    ``at_capacity``).  All methods are O(queue) python; the engine calls
    ``admit()`` once per step and mirrors the returned placements."""

    def __init__(self, max_batch: int, cap: int, policy: str = "fcfs"):
        if policy not in POLICIES:
            raise ValueError(f"unknown sched policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.policy = policy
        self.cap = cap
        self.max_batch = max_batch
        self.queue: list[Request] = []
        self.slot_rids: list[int | None] = [None] * max_batch
        self.slot_len: list[int] = [0] * max_batch
        self.rejected: list[Request] = []
        self._admit_seq = 0

    # ------------------------------------------------------------- queue
    def submit(self, req: Request, front: bool = False) -> None:
        """Enqueue ``req`` (``front=True`` = preemption resume priority)."""
        req.state = QUEUED
        if front:
            self.queue.insert(0, req)
        else:
            self.queue.append(req)

    def _pick(self) -> Request:
        # preempted requests resume first under EVERY policy — their
        # already-spent prefill/decode work must not be stranded behind a
        # stream of fresh short arrivals (they sit at the queue front)
        for r in self.queue:
            if r.preempted:
                return r
        if self.policy == "sjf":
            # min() is stable: earliest-queued wins among equal lengths
            return min(self.queue, key=lambda r: len(r.resume_tokens()))
        return self.queue[0]

    def _stamp(self, req: Request) -> None:
        # first admission only: a preempted request keeps its original
        # stamp, so it also keeps its seniority in the engine's
        # oldest-first prefill-chunk scheduling when it resumes
        if req.admit_seq < 0:
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
        req.preempted = False

    def free_slot(self) -> int | None:
        """Lowest free slot index, or None when the batch is full."""
        try:
            return self.slot_rids.index(None)
        except ValueError:
            return None

    def fits(self, req: Request) -> bool:
        """Cache-pressure gate: can ``req``'s prefill leave room for at
        least one generated token in the per-slot capacity?"""
        return len(req.resume_tokens()) + 1 <= self.cap

    def reject(self, req: Request) -> None:
        """Retire ``req`` unplaced with ``finish_reason="rejected"``."""
        req.state = DONE
        req.done = True
        req.finish_reason = "rejected"
        self.rejected.append(req)

    # --------------------------------------------------------- admission
    def admit(self) -> list[tuple[Request, int]]:
        """Admit queued requests into free slots per policy.

        Returns the ``(request, slot)`` placements made this call.  The
        cache-pressure gate rejects requests whose prefill can never fit
        ``cap`` (they land in ``self.rejected`` with state DONE /
        ``finish_reason="rejected"`` and are NOT placed)."""
        placed: list[tuple[Request, int]] = []
        while self.queue:
            slot = self.free_slot()
            if slot is None:
                break
            req = self._pick()
            self.queue.remove(req)
            if not self.fits(req):            # can't even hold one new token
                self.reject(req)
                continue
            need = len(req.resume_tokens())
            req.state = PREFILL
            self._stamp(req)
            self.slot_rids[slot] = req.rid
            self.slot_len[slot] = need
            placed.append((req, slot))
        return placed

    def assign_direct(self, req: Request) -> int | None:
        """Bypass the queue: place ``req`` into a free slot now (the
        engine's legacy one-shot ``add_request`` path).  Returns the slot,
        or None when full — or when the cache-pressure gate rejects the
        request (``req.finish_reason == "rejected"``; same behavior as the
        ``admit()`` path, and it keeps ``slot_len < cap`` invariant-true)."""
        slot = self.free_slot()
        if slot is None:
            return None
        if not self.fits(req):
            self.reject(req)
            return None
        req.state = PREFILL
        self._stamp(req)
        self.slot_rids[slot] = req.rid
        self.slot_len[slot] = len(req.resume_tokens())
        return slot

    # ----------------------------------------------------------- running
    def on_token(self, slot: int) -> None:
        """Record one generated token committed to ``slot``'s cache."""
        self.slot_len[slot] += 1

    def at_capacity(self, slot: int) -> bool:
        """True when ``slot`` cannot hold another token (retire now)."""
        return self.slot_len[slot] + 1 >= self.cap

    def release(self, slot: int) -> None:
        """Free ``slot`` (request retired or preempted)."""
        self.slot_rids[slot] = None
        self.slot_len[slot] = 0

    def preempt(self, slot: int, req: Request) -> None:
        """Release ``slot`` and requeue ``req`` at the front; ``_pick``
        resumes preempted requests before anything else under every
        policy."""
        assert self.slot_rids[slot] == req.rid, (slot, req.rid)
        self.release(slot)
        req.preempted = True
        self.submit(req, front=True)

    # -------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert the scheduling invariants the property suite pins:
        no rid in two slots, queue and slots disjoint, committed lengths
        within capacity."""
        live = [r for r in self.slot_rids if r is not None]
        assert len(live) == len(set(live)), f"slot double-assignment: {live}"
        qrids = [r.rid for r in self.queue]
        assert len(qrids) == len(set(qrids)), f"queue duplicates: {qrids}"
        assert not set(qrids) & set(live), "request both queued and placed"
        for s, (rid, ln) in enumerate(zip(self.slot_rids, self.slot_len)):
            if rid is not None:
                assert 0 < ln < self.cap, \
                    f"slot {s} length {ln} violates capacity {self.cap}"
