"""Admission scheduling for the continuous-batching engine (pure python).

The ``Scheduler`` owns everything the engine must *decide* (who runs where,
and when) without touching device state: the admission queue, the slot
table, per-slot committed cache lengths, and the cache-pressure gate.  It is
deliberately jax-free so its invariants can be property-tested exhaustively
(tests/serving/test_scheduler_props.py) with simulated request streams —
the ``DecodeEngine`` mirrors its decisions onto the device arrays.

Request lifecycle (docs/serving.md):

    QUEUED --admit--> PREFILL --last chunk--> DECODE --retire--> DONE
       ^                  |                      |
       +----preempt-------+----------preempt-----+

Policies: ``"fcfs"`` (arrival order) and ``"sjf"`` (shortest remaining
prefill first — cheap requests jump the queue, bounding their TTFT under
load).  Both apply the cache-pressure gate: a request whose prefill alone
cannot fit the per-slot cache capacity is rejected up front instead of
being admitted and immediately capacity-retired.  Preempted requests
re-enter at the front of the queue so they resume promptly.

Tenancy (``tenants=`` / ``slo_aware=``, docs/serving.md): every request
carries a ``tenant`` name and an SLO class (``interactive`` — TTL-bound,
``batch`` — throughput-bound).  With tenancy on, ``_pick`` layers a
deficit-weighted-fair-queueing admission filter over the base policy:

  * eligibility — a tenant at its slot quota, or a batch-class request
    while ``batch_cap`` batch slots already run, is skipped (never
    blocking an eligible interactive request behind it);
  * class priority — eligible interactive requests admit before eligible
    batch ones;
  * weighted fairness — among the eligible class, the tenant with the
    least *normalized service* (served tokens / weight) goes first, so
    backlogged tenants' served-token shares converge to their weight
    shares (tests/serving/test_tenant_props.py);
  * bounded credit — a tenant returning from idle has its service floored
    to the least-served active tenant's, so idle time never banks an
    unbounded catch-up burst.

``batch_cap`` (default ``max_batch``) is the dynamic ceiling on running
batch-class slots the TTL governor (serving/governor.py) trades against
interactive latency.  Without tenancy every knob is inert and admission
is byte-for-byte the legacy FCFS/SJF behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

# lifecycle states (plain strings so they serialize/log cleanly)
QUEUED = "queued"
PREFILL = "prefill"
# awaiting a host-tier page restore (serving/tier.py): the slot is held
# and other slots keep decoding, but this one neither prefills nor
# decodes until the (possibly fault-delayed) H2D restore commits
RESTORING = "restoring"
DECODE = "decode"
DONE = "done"

POLICIES = ("fcfs", "sjf")

# SLO classes (serving/workload.py traces tag every request with one):
# interactive work is TTL-bound (the paper's budget), batch work is
# throughput-bound and the first to be shed under TTL pressure
SLO_INTERACTIVE = "interactive"
SLO_BATCH = "batch"
SLO_CLASSES = (SLO_INTERACTIVE, SLO_BATCH)


@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """Per-tenant admission knobs for the DWFQ layer.

    ``weight`` sets the tenant's fair share of served tokens while
    backlogged (normalized service = served / weight; the least-served
    tenant admits first).  ``max_slots`` > 0 caps the tenant's concurrent
    engine slots (0 = no quota)."""
    name: str
    weight: float = 1.0
    max_slots: int = 0


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is the token list to prefill; the engine appends generated
    tokens to ``out_tokens`` and sets ``done``/``finish_reason`` on
    retirement (``"eos"`` | ``"max_tokens"`` | ``"capacity"`` |
    ``"rejected"``).  ``state`` tracks the scheduler lifecycle."""
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    state: str = QUEUED
    finish_reason: str | None = None
    preempted: bool = False                   # awaiting resume (front of queue)
    admit_seq: int = -1                       # admission order stamp
    session_id: str | None = None             # multi-turn session KV key
    tenant: str = "default"                   # DWFQ accounting bucket
    slo_class: str = SLO_INTERACTIVE          # interactive (TTL) | batch
    sampling: Any = None                      # per-request SamplingParams
                                              # (None = engine default)
    # --- chunked-prefill bookkeeping (engine-internal) ---
    prefill_tokens: list[int] | None = None   # prompt (+ generated on resume)
    prefill_pos: int = 0                      # next chunk offset
    buffers: Any = None                       # K/V carry buffers (device)
    # --- prefix-sharing bookkeeping (engine-internal, set at admission) ---
    shared_len: int = 0                       # matched prefix tokens (restore)
    shared_pages: int = 0                     # leading logical pages shared
    shared_kv: Any = None                     # host fp K/V of [0, shared_len)
    # --- host-tier spill/restore bookkeeping (engine-internal) ---
    spill_key: str | None = None              # host store key of spilled pages
    spill_len: int = 0                        # committed tokens when spilled
    forced_tokens: list[int] | None = None    # restore catch-up token queue
    resume_fallback: bool = False             # restore failed -> re-prefill

    def resume_tokens(self) -> list[int]:
        """Tokens to (re-)prefill: the prompt plus anything already
        generated (preempted requests recompute their full context)."""
        return list(self.prompt) + list(self.out_tokens)


def _kv_to_pages(arr, block_s: int):
    """Carry-layout host K/V ``[L, t, Kp, hsz]`` -> page stack
    ``[L, P, block_s, Kp, hsz]`` (zero-padded tail) for the host store."""
    arr = np.asarray(arr)
    l, t = arr.shape[:2]
    p = -(-t // block_s)
    if p * block_s != t:
        pad = [(0, 0)] * arr.ndim
        pad[1] = (0, p * block_s - t)
        arr = np.pad(arr, pad)
    return arr.reshape(l, p, block_s, *arr.shape[2:])


def _pages_to_kv(pages, t: int):
    """Inverse of ``_kv_to_pages``: drop the padding back to ``t`` rows."""
    l, p, bs = pages.shape[:3]
    return pages.reshape(l, p * bs, *pages.shape[3:])[:, :t]


class PrefixIndex:
    """Hash trie over token ids, at page granularity, mapping prompts onto
    already-committed KV prefixes (the prefix-sharing index).

    Registration happens when a request finishes chunked prefill: the engine
    hands over the token sequence, the request's physical page list (with
    the pool's generation stamps), and a **host fp copy** of the carried
    K/V buffers.  An arriving prompt then walks the trie — one node per
    full page of ``block_s`` token ids — to its longest registered prefix:

      * the matched length ``m`` gates *compute*: the engine restores the
        host fp K/V for ``[0, m)`` into the new request's prefill buffers
        and chunk-prefills only the suffix (TTFT ~ suffix-only).  The host
        copy is captured before quantization, so restoration is bit-exact
        for fp and kv8 alike and never needs to invert the pool layout.
      * the entry's still-live leading pages gate *memory*:
        ``valid_leading_pages`` checks refcount + generation per page, and
        the scheduler ``share()``s exactly that many full pages instead of
        charging fresh ones (see ``Scheduler.fits``).

    Entries never go "wrong", only stale: the host K/V is a pure function
    of the token prefix, so a fully-recycled entry still saves prefill
    compute even when no pages are shareable any more.  ``max_entries``
    bounds the entry count with FIFO eviction; with ``store`` (a
    ``serving/tier.HostPageStore``) the K/V blobs themselves live under
    the store's page-capacity LRU instead of inline — an evicted or
    corrupt blob degrades that entry to pages-only sharing (the suffix
    prefill falls back to a full prefill, still bit-exact)."""

    def __init__(self, block_s: int, pool, max_entries: int = 64,
                 store=None):
        assert block_s > 0
        self.block_s = block_s
        self.pool = pool
        self.store = store
        self.max_entries = max_entries
        self._root: dict = {"children": {}, "entries": []}
        self._order: list[dict] = []          # FIFO eviction order
        self._seq = 0
        self.lookups = 0
        self.hits = 0

    def __len__(self) -> int:
        return len(self._order)

    def register(self, tokens, pages, kv=None) -> None:
        """Insert one committed prefix: ``tokens`` (the full prefilled
        sequence), its physical ``pages`` (snapshotted with the pool's
        current generation stamps), and ``kv`` — host fp
        ``(k, v)`` arrays of shape ``[L, len(tokens), Kp, hsz]`` captured
        from the prefill carry buffers before quantization.

        With a host page store, the blob is deposited there under a
        ``prefix:<seq>`` key (page-reshaped, checksummed, LRU-bounded) and
        the entry keeps only the key; a refused save (store-full fault)
        registers the entry pages-only."""
        toks = tuple(int(t) for t in tokens)
        if kv is not None and self.store is not None:
            key = f"prefix:{self._seq}"
            planes = {"k": _kv_to_pages(kv[0], self.block_s),
                      "v": _kv_to_pages(kv[1], self.block_s)}
            kv = key if self.store.put(key, planes, tokens=toks) else None
        entry = {"tokens": toks, "pages": list(pages),
                 "gens": [self.pool.generation(p) for p in pages],
                 "kv": kv, "seq": self._seq, "nodes": []}
        self._seq += 1
        node = self._root
        node["entries"].append(entry)
        entry["nodes"].append(node)
        bs = self.block_s
        for d in range(len(toks) // bs):
            key = toks[d * bs:(d + 1) * bs]
            nxt = node["children"].get(key)
            if nxt is None:
                nxt = {"children": {}, "entries": []}
                node["children"][key] = nxt
            node = nxt
            node["entries"].append(entry)
            entry["nodes"].append(node)
        self._order.append(entry)
        while len(self._order) > self.max_entries:
            old = self._order.pop(0)
            for n in old["nodes"]:
                n["entries"].remove(old)
            if self.store is not None and isinstance(old["kv"], str):
                self.store.drop(old["kv"])

    def match(self, tokens, limit: int) -> tuple[int, dict | None]:
        """Longest registered prefix of ``tokens``: returns ``(m, entry)``
        with ``m <= limit`` matched token ids (0, None on miss).  Walks the
        page-key trie to the deepest node, then extends token-by-token into
        the partial page against that node's entries; equal-length matches
        break toward the entry with the most still-live (shareable) leading
        pages — a retired twin's entry saves the same prefill compute but
        no memory — then toward the earliest-registered (determinism)."""
        self.lookups += 1
        toks = tuple(int(t) for t in tokens)
        bs = self.block_s
        path = [self._root]
        node = self._root
        for d in range(len(toks) // bs):
            nxt = node["children"].get(toks[d * bs:(d + 1) * bs])
            if nxt is None:
                break
            node = nxt
            path.append(node)
        best_m, best, best_key = 0, None, None
        for depth in range(len(path) - 1, -1, -1):
            for e in sorted(path[depth]["entries"], key=lambda e: e["seq"]):
                m = depth * bs
                et = e["tokens"]
                hi = min(len(toks), len(et), limit)
                while m < hi and toks[m] == et[m]:
                    m += 1
                m = min(m, limit)
                key = (m, self.valid_leading_pages(e), -e["seq"])
                if best_key is None or key > best_key:
                    best_m, best, best_key = m, e, key
            if best_m > 0:
                break       # shallower nodes can only match shorter prefixes
        if best_m <= 0:
            return 0, None
        self.hits += 1
        return best_m, best

    def resolve_kv(self, entry: dict):
        """The entry's host fp ``(k, v)`` arrays for a buffer restore, or
        None when unavailable.  Inline blobs return as stored; store-backed
        blobs fetch through the ``HostPageStore`` with integrity
        verification but no injected restore faults (this runs inside the
        admission decision, which must be internally consistent) — an
        evicted or corrupt blob clears the entry's reference and the
        admission proceeds pages-only with a full prefill."""
        kv = entry["kv"]
        if kv is None or not isinstance(kv, str):
            return kv
        planes = None if self.store is None else self.store.fetch(kv)
        if planes is None:
            entry["kv"] = None
            return None
        t = len(entry["tokens"])
        return (_pages_to_kv(planes["k"], t), _pages_to_kv(planes["v"], t))

    def valid_leading_pages(self, entry: dict) -> int:
        """How many of ``entry``'s leading pages are still the same tenancy
        they were at registration (refcount > 0 and unchanged generation) —
        the shareable page span.  Later pages may have been recycled; the
        host K/V stays usable regardless."""
        n = 0
        for p, g in zip(entry["pages"], entry["gens"]):
            if self.pool.refcount(p) <= 0 or self.pool.generation(p) != g:
                break
            n += 1
        return n

    def hit_rate(self) -> float:
        """Fraction of lookups that matched a non-empty prefix."""
        return self.hits / max(self.lookups, 1)


class Scheduler:
    """FCFS/SJF admission queue + slot table with cache-pressure gating.

    ``cap`` is the per-slot KV capacity; a slot's committed length may
    never reach it (the engine retires the request one token earlier —
    ``at_capacity``).  All methods are O(queue) python; the engine calls
    ``admit()`` once per step and mirrors the returned placements.

    **Capacity oracle** (the single authority both admission paths and the
    retirement check consult, so they can never disagree): with ``pool``
    (a ``serving/pool.BlockAllocator`` — the shared-pool paged KV cache)
    capacity is the *global* free-page count — ``fits`` asks whether the
    request could ever hold its prompt + one token in ``max_pages`` pages,
    ``can_admit_now`` whether that many pages are free *now* (otherwise the
    request stays queued instead of being rejected), and
    ``grow_for_next_token`` reserves the next decode token's page on
    demand.  Without ``pool`` the same three methods fall back to the
    per-slot ``cap`` gate (always-admissible once a slot is free)."""

    def __init__(self, max_batch: int, cap: int, policy: str = "fcfs",
                 pool=None, max_pages: int = 0, prefix_index=None,
                 tenants=None, slo_aware: bool | None = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown sched policy {policy!r}; "
                             f"choose from {POLICIES}")
        self.policy = policy
        self.cap = cap
        self.max_batch = max_batch
        self.pool = pool
        self.max_pages = max_pages or (pool.capacity if pool else 0)
        self.prefix_index = prefix_index
        self.queue: list[Request] = []
        self.slot_rids: list[int | None] = [None] * max_batch
        self.slot_len: list[int] = [0] * max_batch
        self.rejected: list[Request] = []
        self._admit_seq = 0
        # --- tenancy / DWFQ state (inert unless slo_aware) ---
        if tenants is not None and not isinstance(tenants, dict):
            tenants = {t.name: t for t in tenants}
        self.tenants: dict[str, TenantConfig] | None = tenants
        # slo_aware turns on class priority + DWFQ + batch_cap in _pick;
        # default: on iff tenants are configured (the TTL governor turns it
        # on without tenant configs — every tenant then weighs 1.0)
        self.slo_aware = bool(tenants) if slo_aware is None else slo_aware
        self.batch_cap = max_batch              # governor-adjusted ceiling
        self.slot_tenant: list[str | None] = [None] * max_batch
        self.slot_slo: list[str | None] = [None] * max_batch
        self.served_tokens: dict[str, int] = {}
        self._service: dict[str, float] = {}    # served / weight per tenant

    # ----------------------------------------------------------- tenancy
    def _weight(self, tenant: str) -> float:
        cfg = (self.tenants or {}).get(tenant)
        return max(cfg.weight, 1e-9) if cfg is not None else 1.0

    def _running(self, tenant: str | None = None,
                 slo_class: str | None = None) -> int:
        return sum(1 for s, r in enumerate(self.slot_rids)
                   if r is not None
                   and (tenant is None or self.slot_tenant[s] == tenant)
                   and (slo_class is None or self.slot_slo[s] == slo_class))

    def _eligible(self, req: Request) -> bool:
        """DWFQ admission filter: the tenant's slot quota and the dynamic
        batch-class cap.  Ineligible requests are *skipped* by ``_pick``
        (they stay queued), never head-of-line blocking eligible work —
        in particular an interactive request is never stuck behind an
        over-cap batch one."""
        if not self.slo_aware:
            return True
        cfg = (self.tenants or {}).get(req.tenant)
        if cfg is not None and cfg.max_slots > 0 \
                and self._running(tenant=req.tenant) >= cfg.max_slots:
            return False
        if req.slo_class == SLO_BATCH \
                and self._running(slo_class=SLO_BATCH) >= self.batch_cap:
            return False
        return True

    def record_served(self, slot: int, n: int = 1) -> None:
        """Charge ``n`` generated tokens to ``slot``'s tenant — the DWFQ
        service accounting ``_pick`` balances against tenant weights."""
        t = self.slot_tenant[slot]
        if t is None:
            return
        self.served_tokens[t] = self.served_tokens.get(t, 0) + n
        self._service[t] = self._service.get(t, 0.0) + n / self._weight(t)

    # ------------------------------------------------------------- queue
    def submit(self, req: Request, front: bool = False) -> None:
        """Enqueue ``req`` (``front=True`` = preemption resume priority).

        With tenancy on, a tenant returning from idle (no queued or
        running work) has its normalized service floored to the
        least-served *active* tenant's: idle time banks no catch-up
        credit, so the returning tenant re-enters the fair rotation at
        the current frontier instead of monopolizing admissions."""
        if self.slo_aware and not req.preempted:
            active = ({r.tenant for r in self.queue}
                      | {t for t in self.slot_tenant if t is not None})
            if req.tenant not in active:
                floor = min((self._service.get(t, 0.0) for t in active),
                            default=0.0)
                self._service[req.tenant] = max(
                    self._service.get(req.tenant, 0.0), floor)
        req.state = QUEUED
        if front:
            self.queue.insert(0, req)
        else:
            self.queue.append(req)

    def _pick(self) -> Request | None:
        # preempted requests resume first under EVERY policy — their
        # already-spent prefill/decode work must not be stranded behind a
        # stream of fresh short arrivals (they sit at the queue front)
        if not self.slo_aware:
            for r in self.queue:
                if r.preempted:
                    return r
            if self.policy == "sjf":
                # min() is stable: earliest-queued wins among equal lengths
                return min(self.queue, key=lambda r: len(r.resume_tokens()))
            return self.queue[0]
        # DWFQ layer: same preempted-first / fcfs / sjf skeleton, but only
        # over *eligible* requests (quota + batch_cap), interactive before
        # batch, and the least-normalized-service tenant first.  None when
        # nothing is eligible (the admit loop stops; queued work waits for
        # slots to free or the governor to raise the cap).
        elig = [r for r in self.queue if self._eligible(r)]
        if not elig:
            return None
        for r in elig:
            if r.preempted:
                return r
        inter = [r for r in elig if r.slo_class != SLO_BATCH]
        pool = inter or elig
        tenant = min({r.tenant for r in pool},
                     key=lambda t: (self._service.get(t, 0.0), t))
        cand = [r for r in pool if r.tenant == tenant]
        if self.policy == "sjf":
            return min(cand, key=lambda r: len(r.resume_tokens()))
        return cand[0]

    def _stamp(self, req: Request) -> None:
        # first admission only: a preempted request keeps its original
        # stamp, so it also keeps its seniority in the engine's
        # oldest-first prefill-chunk scheduling when it resumes
        if req.admit_seq < 0:
            req.admit_seq = self._admit_seq
            self._admit_seq += 1
        req.preempted = False

    def free_slot(self) -> int | None:
        """Lowest free slot index, or None when the batch is full."""
        try:
            return self.slot_rids.index(None)
        except ValueError:
            return None

    def _prefix_plan(self, req: Request) -> tuple[int, dict | None, int, int]:
        """One consistent prefix-share decision for all oracle methods:
        ``(m, entry, shared_full, total_pages)`` — ``m`` matched tokens,
        ``shared_full`` full pages the pool can ``share()`` (still-live
        leading pages of the entry), ``total_pages`` the request's full
        table width (prompt + one token).  With no pool or no index:
        ``(0, None, 0, total)``."""
        need = len(req.resume_tokens())
        if self.pool is None:
            return 0, None, 0, 0
        total = self.pool.pages_for(need + 1)
        if self.prefix_index is None or need < 2:
            return 0, None, 0, total
        m, entry = self.prefix_index.match(req.resume_tokens(),
                                           limit=need - 1)
        if entry is None:
            return 0, None, 0, total
        valid = self.prefix_index.valid_leading_pages(entry)
        shared_full = min(m // self.pool.block_s, valid)
        return m, entry, shared_full, total

    def fits(self, req: Request) -> bool:
        """Cache-pressure gate: could ``req``'s prefill plus one generated
        token *ever* fit — the per-slot capacity (fixed layout), or the
        shared pool (paged)?  False means reject.  Paged admission charges
        only the **unshared suffix**: pages the prefix index can satisfy
        from live shared pages are not counted against the pool (a batch of
        same-prefix requests that exceeds the pool unshared still admits
        shared), while the *full* table width still must respect
        ``max_pages``."""
        need = len(req.resume_tokens()) + 1
        if self.pool is None:
            return need <= self.cap
        _, _, shared_full, total = self._prefix_plan(req)
        return (total <= self.max_pages
                and total - shared_full <= self.pool.capacity)

    def can_admit_now(self, req: Request) -> bool:
        """Whether the capacity oracle can grant ``req``'s admission
        reservation *right now*.  Fixed layout: always (the free slot IS
        the reservation).  Paged: the **unshared** pages — the suffix after
        the prefix index's live shared span — must be on the free list;
        otherwise the request waits in the queue for running requests to
        retire and release pages."""
        if self.pool is None:
            return True
        _, _, shared_full, total = self._prefix_plan(req)
        return total - shared_full <= self.pool.free_count

    def grow_for_next_token(self, slot: int) -> list[int] | None:
        """Reserve whatever the *next* decode token needs for ``slot``.

        Returns the newly granted physical pages ([] when the committed
        length + 1 still fits the reservation — always, in the fixed
        layout, until ``cap``), or None when the request cannot grow:
        per-slot ``cap`` reached, ``max_pages`` reached, or the pool's free
        list is empty — the engine then retires it with
        ``finish_reason="capacity"``.  This is the paged twin of
        ``at_capacity`` with the reservation made atomically, so a
        concurrent admission cannot snatch the page between check and
        commit."""
        if self.pool is None:
            return None if self.slot_len[slot] + 1 >= self.cap else []
        rid = self.slot_rids[slot]
        assert rid is not None, slot
        need = self.pool.pages_for(self.slot_len[slot] + 1)
        have = len(self.pool.pages(rid))
        if need <= have:
            return []
        if need > self.max_pages:
            return None
        return self.pool.extend(rid, need - have)

    def grow_for_window(self, slot: int, want: int) -> int:
        """Reserve capacity for up to ``want`` more decode tokens of
        ``slot`` in one shot — the multi-token twin of
        ``grow_for_next_token`` for the windowed decode path
        (``DecodeEngine --decode-window``).

        Returns the granted step budget ``g <= want`` (0 = the slot cannot
        take a single step; the engine retires it with
        ``finish_reason="capacity"`` before dispatch).  Fixed layout:
        bounded by the per-slot ``cap`` exactly like
        ``grow_for_next_token``'s ``slot_len + 1 >= cap`` retire rule.
        Paged: bounded by ``max_pages`` and the pool free list, with every
        needed page taken in ONE atomic ``extend`` *before* the device
        window launches — no allocation can happen mid-window, so a
        concurrent admission at the next boundary sees an exact free
        list.  A grant ``g < want`` that the in-window EOS / max-tokens
        replay doesn't consume means the request hit capacity, matching
        the single-step engine's retire point to the token."""
        if want <= 0:
            return 0
        if self.pool is None:
            return max(0, min(want, self.cap - 1 - self.slot_len[slot]))
        rid = self.slot_rids[slot]
        assert rid is not None, slot
        have = len(self.pool.pages(rid))
        grantable = min(self.max_pages, have + self.pool.free_count)
        g = min(want, grantable * self.pool.block_s - self.slot_len[slot])
        if g <= 0:
            return 0
        need = self.pool.pages_for(self.slot_len[slot] + g)
        if need > have:
            got = self.pool.extend(rid, need - have)
            assert got is not None, "free_count lied"
        return g

    def _reserve(self, req: Request) -> None:
        """Perform the paged admission reservation ``can_admit_now`` just
        approved: ``share()`` the prefix index's live leading pages,
        ``cow()`` the trailing partial page (the request's first appended
        token diverges right after the shared prefix — resolved before any
        write, so a shared page is never mutated), then ``alloc``/``extend``
        fresh pages for the unshared suffix.  Records the match on the
        request (``shared_len``/``shared_pages``/``shared_kv``) for the
        engine's buffer restore and scatter."""
        if self.pool is None:
            return
        req.shared_len = 0          # stale match from a prior admission
        req.shared_pages = 0
        req.shared_kv = None
        m, entry, shared_full, total = self._prefix_plan(req)
        if entry is None:
            got = self.pool.alloc(req.rid, total)
            assert got is not None, "can_admit_now lied"
            return
        bs = self.pool.block_s
        valid = self.prefix_index.valid_leading_pages(entry)
        partial = (shared_full == m // bs and m % bs != 0
                   and valid > shared_full
                   and len(entry["pages"]) > shared_full)
        take = shared_full + 1 if partial else shared_full
        self.pool.share(req.rid, entry["pages"][:take])
        if partial:
            got = self.pool.cow(req.rid, shared_full)
            assert got is not None, "can_admit_now lied"
        if total > take:
            got = self.pool.extend(req.rid, total - take)
            assert got is not None, "can_admit_now lied"
        req.shared_len = m
        req.shared_pages = shared_full
        req.shared_kv = self.prefix_index.resolve_kv(entry)

    def reject(self, req: Request) -> None:
        """Retire ``req`` unplaced with ``finish_reason="rejected"``."""
        req.state = DONE
        req.done = True
        req.finish_reason = "rejected"
        self.rejected.append(req)

    # --------------------------------------------------------- admission
    def admit(self) -> list[tuple[Request, int]]:
        """Admit queued requests into free slots per policy.

        Returns the ``(request, slot)`` placements made this call.  The
        cache-pressure gate rejects requests whose prefill can never fit
        ``cap`` (they land in ``self.rejected`` with state DONE /
        ``finish_reason="rejected"`` and are NOT placed)."""
        placed: list[tuple[Request, int]] = []
        while self.queue:
            slot = self.free_slot()
            if slot is None:
                break
            req = self._pick()
            if req is None:                   # nothing eligible (DWFQ)
                break
            if not self.fits(req):            # can't even hold one new token
                self.queue.remove(req)
                self.reject(req)
                continue
            if not self.can_admit_now(req):
                # pool pressure: the pick waits (stays queued) for running
                # requests to release pages — no skip-ahead, so a big
                # request can't be starved by a stream of small ones
                break
            self.queue.remove(req)
            need = len(req.resume_tokens())
            # reserve prompt + first-token pages up front (shared prefix
            # pages + fresh suffix pages): the chunked prefill carries K/V
            # in side buffers and commits them to the pool only at
            # finalize, so full reservation here keeps multi-step prefills
            # deadlock-free (no partial holds)
            self._reserve(req)
            req.state = PREFILL
            self._stamp(req)
            self.slot_rids[slot] = req.rid
            self.slot_len[slot] = need
            self.slot_tenant[slot] = req.tenant
            self.slot_slo[slot] = req.slo_class
            placed.append((req, slot))
        return placed

    def assign_direct(self, req: Request) -> int | None:
        """Bypass the queue: place ``req`` into a free slot now (the
        engine's legacy one-shot ``add_request`` path).  Returns the slot,
        or None when full — or when the cache-pressure gate rejects the
        request (``req.finish_reason == "rejected"``; same behavior as the
        ``admit()`` path, and it keeps ``slot_len < cap`` invariant-true).
        Both admission paths share the same capacity oracle (``fits`` /
        ``can_admit_now``), so they cannot disagree on what is admissible;
        under pool pressure (paged, pages busy *now*) the request is
        neither placed nor rejected — None, like a full batch."""
        slot = self.free_slot()
        if slot is None:
            return None
        if not self.fits(req):
            self.reject(req)
            return None
        if not self.can_admit_now(req):
            return None
        need = len(req.resume_tokens())
        self._reserve(req)
        req.state = PREFILL
        self._stamp(req)
        self.slot_rids[slot] = req.rid
        self.slot_len[slot] = need
        self.slot_tenant[slot] = req.tenant
        self.slot_slo[slot] = req.slo_class
        return slot

    # ----------------------------------------------------------- running
    def on_token(self, slot: int) -> None:
        """Record one generated token committed to ``slot``'s cache."""
        self.slot_len[slot] += 1

    def at_capacity(self, slot: int) -> bool:
        """True when ``slot`` cannot hold another token (retire now).
        Read-only twin of ``grow_for_next_token`` — fixed: the per-slot
        ``cap`` is reached; paged: the next token's page can neither be
        covered by the reservation nor granted from the free list."""
        if self.pool is None:
            return self.slot_len[slot] + 1 >= self.cap
        rid = self.slot_rids[slot]
        need = self.pool.pages_for(self.slot_len[slot] + 1)
        have = len(self.pool.pages(rid)) if rid is not None else 0
        return need > have and (need > self.max_pages
                                or need - have > self.pool.free_count)

    def release(self, slot: int) -> None:
        """Free ``slot`` (request retired or preempted); paged mode also
        returns the request's pool pages to the free list — copy-free."""
        rid = self.slot_rids[slot]
        if self.pool is not None and rid is not None:
            self.pool.free(rid)
        self.slot_rids[slot] = None
        self.slot_len[slot] = 0
        self.slot_tenant[slot] = None
        self.slot_slo[slot] = None

    def preempt(self, slot: int, req: Request) -> None:
        """Release ``slot`` and requeue ``req`` at the front; ``_pick``
        resumes preempted requests before anything else under every
        policy."""
        assert self.slot_rids[slot] == req.rid, (slot, req.rid)
        self.release(slot)
        req.preempted = True
        self.submit(req, front=True)

    # -------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert the scheduling invariants the property suite pins:
        no rid in two slots, queue and slots disjoint, committed lengths
        within capacity; paged mode additionally checks page conservation
        and that every slot's reservation covers its committed length;
        tenancy adds slot tenant/SLO tag consistency and non-negative
        service accounting."""
        live = [r for r in self.slot_rids if r is not None]
        assert len(live) == len(set(live)), f"slot double-assignment: {live}"
        for s, rid in enumerate(self.slot_rids):
            assert (rid is None) == (self.slot_tenant[s] is None), \
                f"slot {s} tenant tag out of sync with its rid"
            assert (rid is None) == (self.slot_slo[s] is None), \
                f"slot {s} slo tag out of sync with its rid"
        assert all(v >= 0 for v in self.served_tokens.values()), \
            self.served_tokens
        assert all(v >= 0.0 for v in self._service.values()), self._service
        assert 0 <= self.batch_cap <= self.max_batch, self.batch_cap
        qrids = [r.rid for r in self.queue]
        assert len(qrids) == len(set(qrids)), f"queue duplicates: {qrids}"
        assert not set(qrids) & set(live), "request both queued and placed"
        for s, (rid, ln) in enumerate(zip(self.slot_rids, self.slot_len)):
            if rid is None:
                continue
            if self.pool is None:
                assert 0 < ln < self.cap, \
                    f"slot {s} length {ln} violates capacity {self.cap}"
            else:
                have = len(self.pool.pages(rid))
                assert 0 < ln <= have * self.pool.block_s, \
                    f"slot {s} length {ln} exceeds its {have} pages"
                assert have <= self.max_pages, (s, have, self.max_pages)
        if self.pool is not None:
            self.pool.check_invariants()
            holders = {r for r in self.pool._pages if self.pool.pages(r)}
            assert holders <= set(live), \
                f"pages held by unplaced requests: {holders - set(live)}"
