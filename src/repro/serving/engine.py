"""Batched decode engine: continuous batching over the Helix serve_step.

Slot-based continuous batching: a fixed [max_batch] decode state holds one
request per slot with *per-request* lengths ([B] total_len — the helix
attention mask, rope positions and round-robin appends are all per-request).
New requests prefill into a free slot; finished ones free theirs.  This is
the real serving pattern (vLLM-style) on top of the paper's sharding.

For multi-request prefill we process each prompt through the shared
prefill_step and scatter its caches into the slot.  Per-slot scatter of a
round-robin cache is a pure index update — the layouts match by
construction (same kvp, rr_block).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.kvcache import cache_capacity, init_decode_state
from repro.core.sharding import HelixConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    out_tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class DecodeEngine:
    """Slot-based continuous-batching decode engine over a Helix serve_step.

    Holds a fixed ``[max_batch]`` decode state with per-request lengths;
    ``add_request`` prefills a prompt into a free slot (scattering its
    caches — layouts match by construction), ``step`` advances every active
    slot one token and retires finished requests.  ``hx`` (when given)
    pins the round-robin block size and is validated against the kernel
    registry so unavailable backends fail fast.
    """

    def __init__(self, cfg: ArchConfig, params, serve_step: Callable,
                 prefill_step: Callable, *, max_batch: int, max_seq: int,
                 kvp: int = 1, rr_block: int = 16,
                 hx: HelixConfig | None = None, dtype=jnp.float32):
        # ``hx`` (when given) wins over the bare rr_block arg so engine and
        # serve_step can't disagree on the round-robin block size.  kvp still
        # depends on the mesh (hx.kvp(mesh)), which the engine never sees —
        # that half stays the caller's contract.
        if hx is not None:
            rr_block = hx.rr_block
            # fail fast on unavailable kernel backends (e.g. 'pallas'
            # requested on a CPU host) instead of erroring steps later
            # inside the first jit'd prefill
            from repro.kernels import registry
            for field, family in registry.FAMILY_FIELDS.items():
                ok, why = registry.available(family, getattr(hx, field))
                if not ok:
                    raise RuntimeError(
                        f"{field}={getattr(hx, field)!r} unavailable: {why}")
        self.hx = hx
        self.cfg = cfg
        if hx is not None and hx.lm_head_w8:
            # quantize the lm_head once up front; otherwise serve_step
            # re-quantizes the whole [H, V] matrix every decode step
            from repro.models.decode_model import quantize_lm_head
            params = quantize_lm_head(params)
        self.params = params
        self.serve_step = jax.jit(serve_step)
        self.prefill_step = jax.jit(prefill_step)
        self.max_batch = max_batch
        self.cap = cache_capacity(max_seq, kvp, rr_block)
        self.kvp, self.rr = kvp, rr_block
        self.state = init_decode_state(cfg, max_batch, self.cap, kvp,
                                       rr_block, dtype=dtype)
        # per-request lengths: [B]; empty slots keep 0
        self.state["total_len"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[Request | None] = [None] * max_batch
        self.cur_tokens = jnp.zeros((max_batch,), jnp.int32)

    # ------------------------------------------------------------- requests
    def add_request(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False if engine is full."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        last_logits, pstate = self.prefill_step(self.params, {"tokens": toks})
        t = len(req.prompt)
        for key in ("kcache", "vcache"):
            if key in self.state:
                # prefill cache capacity may differ; copy the common prefix
                # of every rank's local slots (layouts match: same kvp/rr)
                src = pstate[key][:, 0]
                dst = self.state[key][:, slot]
                self.state[key] = self.state[key].at[:, slot].set(
                    _copy_rr(src, dst, self.kvp))
        for key in ("ssm_conv", "ssm_state", "xk", "xv"):
            if key in self.state:
                self.state[key] = self.state[key].at[:, slot].set(
                    pstate[key][:, 0])
        self.state["total_len"] = self.state["total_len"].at[slot].set(t)
        nxt = int(jnp.argmax(last_logits[0, :self.cfg.vocab]))
        req.out_tokens.append(nxt)
        self.cur_tokens = self.cur_tokens.at[slot].set(nxt)
        self.slots[slot] = req
        return True

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One decode step for every active slot; returns finished requests."""
        if not any(self.slots):
            return []
        next_tokens, self.state = self.serve_step(
            self.params, self.state, self.cur_tokens)
        self.cur_tokens = next_tokens
        # one batched device->host transfer per step (per-slot int() calls
        # would each block on the device queue — B syncs instead of 1)
        toks_np = np.asarray(next_tokens)
        lens_np = np.asarray(self.state["total_len"])
        finished = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(toks_np[i])
            req.out_tokens.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.out_tokens) >= req.max_new_tokens or \
                    int(lens_np[i]) + 1 >= self.cap:
                req.done = True
                finished.append(req)
                self.slots[i] = None
                self.state["total_len"] = \
                    self.state["total_len"].at[i].set(0)
        return finished

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Step until every slot drains (or ``max_steps`` elapses)."""
        for _ in range(max_steps):
            if not any(self.slots):
                return
            self.step()

    def describe_backends(self) -> str:
        """One-line per-family kernel-backend summary (serve logging)."""
        if self.hx is None:
            return "ref (no HelixConfig)"
        from repro.kernels import registry
        parts = [f"{family}={getattr(self.hx, field)}"
                 for field, family in registry.FAMILY_FIELDS.items()]
        parts.append(f"fuse_append={self.hx.fuse_append}")
        parts.append(f"prune_blocks={self.hx.prune_blocks}")
        if self.hx.lm_head_w8:
            parts.append("lm_head_w8=True")
        return " ".join(parts)


def _copy_rr(src, dst, kvp: int):
    """Copy a round-robin cache [L?, Kh, S_src, hsz] into capacity S_dst.

    Both layouts are (rank-major, local-slot) with the same kvp/rr, so rank
    r's local slots [0, S_src/kvp) map to dst-local slots [0, S_src/kvp).
    """
    s_src = src.shape[-2]
    s_dst = dst.shape[-2]
    if s_src == s_dst:
        return src
    ls, ld = s_src // kvp, s_dst // kvp
    n = min(ls, ld)
    srcr = src.reshape(*src.shape[:-2], kvp, ls, src.shape[-1])
    dstr = dst.reshape(*dst.shape[:-2], kvp, ld, dst.shape[-1])
    out = dstr.at[..., :, :n, :].set(srcr[..., :, :n, :])
    return out.reshape(dst.shape)
