"""Scheduler-driven continuous-batching engine over the Helix serve_step.

Slot-based continuous batching with **chunked prefill**: a fixed
``[max_batch]`` decode state holds one request per slot with *per-request*
lengths ([B] total_len — the helix attention mask, rope positions and
round-robin appends are all per-request).  Admission runs through a
``Scheduler`` (serving/scheduler.py: FCFS/SJF + cache-pressure gating);
pending prompts prefill in ``chunk_tokens``-sized slices interleaved with
decode steps, so a multi-million-token prompt no longer stalls every
in-flight decode stream — the TTL blowup Helix exists to avoid (PAPER.md
§1).  Per-request lifecycle metrics (queue wait, TTFT, per-step TTL) are
collected in ``EngineMetrics``.

One engine ``step()`` is bounded work:

  1. admission      — move queued requests into free slots (Scheduler);
  2. prefill chunk  — ONE ``chunk_tokens``-sized slice for one group of
                      same-progress prefills (batched chunk packing);
  3. decode step    — one token for every decoding slot, retiring finished
                      requests (EOS / max-tokens / capacity).

Chunked prefill is bit-exact with the one-shot path: each chunk attends to
the already-cached prefix through flash_prefill's runtime ``q_offset``
contract over a carry buffer sized to the request's full prompt, and the
finalize handoff shares ``make_prefill_step``'s cache->round-robin
conversion (models/model_zoo.py).  See docs/serving.md for the dataflow.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.kvcache import (cache_capacity, cache_to_pages,
                                gather_pool_pages, init_decode_state,
                                page_positions, quantize_decode_state,
                                scatter_pool_pages)
from repro.core.sharding import HelixConfig
from repro.serving.governor import GovernorConfig, TTLGovernor
from repro.serving.metrics import EngineMetrics, VirtualClock
from repro.serving.pool import BlockAllocator
from repro.serving.scheduler import (DECODE, DONE, PREFILL, QUEUED,
                                     RESTORING, SLO_BATCH, Request,
                                     Scheduler)
from repro.serving.tier import HostPageStore

__all__ = ["DecodeEngine", "Request"]


class DecodeEngine:
    """Scheduler-driven continuous-batching decode engine (see module doc).

    Two admission APIs:

      * ``submit(req)`` + ``step()`` — the scheduler path: queued
        admission, chunked prefill (when ``chunk_tokens`` is set and the
        arch supports it), metrics.  ``step()`` returns the requests
        retired that step.
      * ``add_request(req)`` — legacy immediate one-shot prefill into a
        free slot (returns False when full); still the fast path for
        latency-insensitive bulk decoding.

    ``hx`` (when given) pins the round-robin block size, pre-quantizes the
    lm_head (``prepare_decode_params``), switches the cache to int8 when
    ``hx.kv_cache_bits == 8``, and is validated against the kernel registry
    so unavailable backends fail fast.  ``chunk_prefill_step`` comes from
    ``make_chunk_prefill_step`` (required when ``chunk_tokens`` is set) and
    ``tp_width`` must match its mesh's 'model' axis size (it shapes the
    carry buffers' padded GQA head count); ``clock`` is the metrics clock
    (injectable for deterministic tests).

    ``hx.paged_kv`` switches the decode state to the shared-pool paged
    layout (serving/pool.py, docs/serving.md): K/V pool planes + a
    ``block_tables`` state leaf, a ``BlockAllocator`` owning page
    assignment, and the scheduler consulting the *global* free-page count
    for admission/growth/retirement instead of the per-slot cap.
    ``pool_blocks`` sizes the pool (pages of ``kvp * rr_block`` positions,
    including the reserved sink page 0); the default matches the HBM the
    fixed layout would reserve.  ``max_pages`` caps one request's block
    table (default: the whole pool; cap it when serving with the ``ref``
    backend or pruning off, whose per-request cost scales with the table
    width).  Token streams are bit-exact vs the fixed layout
    (tests/serving/test_paged_engine.py).

    Multi-tenant SLO front end (docs/serving.md): ``tenants`` (a
    ``TenantConfig`` dict or iterable) layers deficit-weighted-fair
    admission over the scheduler policy; ``slo_ttl_s`` (or a full
    ``GovernorConfig`` via ``governor``) arms the TTL governor — per step
    it reads the windowed interactive TTL p95 and sheds the youngest
    decoding batch-class request through the spill path (resume: zero
    re-prefill chunks) when the target is missed, raising the dynamic
    batch cap back once latency recovers.  Pair with a ``VirtualClock``
    metrics clock for deterministic, replayable latency summaries
    (scripts/trace_smoke.py).
    """

    def __init__(self, cfg: ArchConfig, params, serve_step: Callable,
                 prefill_step: Callable, *, max_batch: int, max_seq: int,
                 kvp: int = 1, rr_block: int = 16,
                 hx: HelixConfig | None = None, dtype=jnp.float32,
                 chunk_tokens: int | None = None,
                 chunk_prefill_step: Callable | None = None,
                 tp_width: int = 1,
                 sched_policy: str = "fcfs", clock=time.monotonic,
                 pool_blocks: int | None = None,
                 max_pages: int | None = None,
                 prefix_share: bool = False,
                 host_pages: int = 0,
                 session_kv: bool = False,
                 fault_plan=None,
                 tenants=None,
                 slo_ttl_s: float | None = None,
                 governor: GovernorConfig | None = None,
                 sampling=None,
                 decode_window: int = 1,
                 serve_multistep: Callable | None = None):
        # ``hx`` (when given) wins over the bare rr_block arg so engine and
        # serve_step can't disagree on the round-robin block size.  kvp still
        # depends on the mesh (hx.kvp(mesh)), which the engine never sees —
        # that half stays the caller's contract.
        if hx is not None:
            rr_block = hx.rr_block
            # fail fast on unavailable kernel backends (e.g. 'pallas'
            # requested on a CPU host) instead of erroring steps later
            # inside the first jit'd prefill
            from repro.kernels import registry
            for field, family in registry.FAMILY_FIELDS.items():
                ok, why = registry.available(family, getattr(hx, field))
                if not ok:
                    raise RuntimeError(
                        f"{field}={getattr(hx, field)!r} unavailable: {why}")
        self.hx = hx
        self.cfg = cfg
        # quantize the lm_head once up front; otherwise serve_step
        # re-quantizes the whole [H, V] matrix every decode step
        from repro.models.decode_model import prepare_decode_params
        self.params = prepare_decode_params(params, hx)
        self.serve_step = jax.jit(serve_step)
        self.prefill_step = jax.jit(prefill_step)
        # on-device sampling (serving/sampling.py): ``sampling`` is the
        # engine-default SamplingParams; per-request policies ride
        # Request.sampling.  None keeps the historical pure-argmax path
        # (no sampling leaves in the state, nothing new traced).
        if sampling is not None:
            sampling.validate()
        self.sampling = sampling
        # windowed decode (--decode-window): N tokens per device dispatch
        # through serve_multistep (build_serve_multistep), ONE [B, N]
        # blocking transfer per window.  window=1 keeps the single-step
        # path bit-exactly.
        if decode_window < 1:
            raise ValueError(f"decode_window must be >= 1 ({decode_window})")
        if decode_window > 1 and serve_multistep is None:
            raise ValueError("decode_window > 1 needs serve_multistep "
                             "(build one with build_serve_multistep)")
        self.decode_window = decode_window
        if serve_multistep is not None:
            # donate the decode state: the multi-GB KV pool must not be
            # double-buffered across a window dispatch (CPU backends don't
            # implement donation and warn, so gate on the platform)
            if jax.default_backend() != "cpu":
                self.serve_multistep = jax.jit(serve_multistep,
                                               donate_argnums=(1,))
            else:
                self.serve_multistep = jax.jit(serve_multistep)
        else:
            self.serve_multistep = None
        # host-sync accounting for sync_stats(): blocking decode-loop
        # device->host transfers vs decode tokens emitted
        self.decode_syncs = 0
        self.decoded_tokens = 0
        self.max_batch = max_batch
        self.cap = cache_capacity(max_seq, kvp, rr_block)
        self.kvp, self.rr = kvp, rr_block
        self.kv8 = hx is not None and hx.kv_cache_bits == 8
        # shared-pool paged KV cache (hx.paged_kv, serving/pool.py): K/V in
        # pool planes + per-slot block-table rows; ``pool_blocks`` sizes the
        # pool (default: the same HBM the fixed layout would reserve, plus
        # the sink page 0 that idle rows' appends land in)
        self.paged = hx is not None and hx.paged_kv
        self.block_s = page_positions(kvp, rr_block)
        if self.paged:
            if not pool_blocks:
                pool_blocks = max_batch * (self.cap // self.block_s) + 1
            self.pool_blocks = pool_blocks
            self.pool = BlockAllocator(pool_blocks, self.block_s)
            # max_pages caps ONE request's table width (and so its logical
            # capacity).  Default: the whole pool — maximum flexibility,
            # but note the dense-sweep cost scales with it on the ref
            # backend (gather_pages materializes max_pages*block_s
            # positions per request) and on Pallas with pruning off; the
            # default Pallas+prune path only ever visits valid pages.
            self.max_pages = min(max_pages or self.pool.capacity,
                                 self.pool.capacity)
        else:
            self.pool = None
            self.pool_blocks = self.max_pages = 0
        # grouped shared-prefix decode (hx.grouped_decode): requests whose
        # tables share leading pages decode those pages once per *group*
        # instead of once per request; _set_groups refreshes the
        # group_id/group_np leaves from the pool's refcounts each step.
        self.grouped = self.paged and hx is not None and hx.grouped_decode
        if self.grouped and decode_window > 1:
            raise ValueError("decode_window > 1 is incompatible with "
                             "hx.grouped_decode: group_id/group_np are "
                             "host-recomputed every token and would go "
                             "stale mid-window")
        self.state = init_decode_state(
            cfg, max_batch, self.cap, kvp, rr_block, dtype=dtype,
            kv_bits=8 if self.kv8 else 16,
            pool_blocks=self.pool_blocks if self.paged else 0,
            max_pages=self.max_pages, grouped=self.grouped,
            sampling=self.sampling is not None)
        # per-request lengths: [B]; empty slots keep 0
        self.state["total_len"] = jnp.zeros((max_batch,), jnp.int32)
        self.slots: list[Request | None] = [None] * max_batch
        self.cur_tokens = jnp.zeros((max_batch,), jnp.int32)

        from repro.models.model_zoo import chunked_prefill_supported
        self.chunk_tokens = (chunk_tokens or None) \
            if chunked_prefill_supported(cfg) else None
        if self.chunk_tokens and chunk_prefill_step is None:
            raise ValueError("chunk_tokens set but no chunk_prefill_step "
                             "(build one with make_chunk_prefill_step)")
        self.chunk_step = (jax.jit(chunk_prefill_step)
                           if chunk_prefill_step is not None else None)
        self.tp_width = tp_width
        # host KV tier (serving/tier.py, docs/serving.md): spill live
        # pages on preemption for a zero-re-prefill resume (host_pages
        # sizes it), persist retired requests' pages per session_id for
        # multi-turn restore, and cap the prefix index's host K/V blobs
        # under the same LRU.  fault_plan (serving/faults.py) injects the
        # tier's failure modes deterministically — every injected fault
        # degrades to the re-prefill fallback, never to divergent tokens.
        self.session_kv = session_kv
        self.spill_enabled = host_pages > 0
        if (host_pages or session_kv) and not self.paged:
            raise ValueError("the host KV tier (host_pages / session_kv) "
                             "needs hx.paged_kv — spill/restore is "
                             "page-granularity")
        if (host_pages or session_kv) and any(
                k in self.state
                for k in ("ssm_conv", "ssm_state", "xk", "xv")):
            raise ValueError("the host KV tier only spills pool planes; "
                             "this arch keeps non-paged state leaves "
                             "(ssm/enc-dec) a restore could not rebuild")
        self.store = None
        if self.paged and (host_pages or session_kv or prefix_share):
            cap = host_pages or max(4 * self.pool.capacity, 256)
            self.store = HostPageStore(cap, faults=fault_plan)
        self._restores: dict[int, dict] = {}    # slot -> in-flight restore
        # prefix sharing (docs/serving.md): a PrefixIndex matches new
        # prompts against committed prefixes; matched pages are mapped
        # refcounted into the new request's table and only the suffix
        # chunk-prefills.  Needs the paged pool (pages to share) and
        # chunked prefill (a suffix-only prefill is just a resumed one).
        self.prefix_index = None
        if prefix_share:
            if not (self.paged and self.chunk_tokens):
                raise ValueError("prefix_share needs hx.paged_kv and "
                                 "chunk_tokens (suffix-only prefill rides "
                                 "the chunked-prefill q_offset contract)")
            from repro.serving.scheduler import PrefixIndex
            self.prefix_index = PrefixIndex(self.block_s, self.pool,
                                            store=self.store)
        self._prefix_admits = 0
        self._prefix_hits = 0
        # multi-tenant SLO-aware front end (docs/serving.md): ``tenants``
        # (TenantConfig dict/iterable) turns on DWFQ admission; ``slo_ttl_s``
        # (or a full GovernorConfig) arms the TTL governor, which replaces
        # the static batch cap with measured-TTL feedback — batch-class
        # work sheds through the spill path when interactive p95 TTL
        # drifts past target (serving/governor.py).
        if governor is None and slo_ttl_s is not None:
            governor = GovernorConfig(ttl_target_s=slo_ttl_s)
        self.governor = (TTLGovernor(governor, max_batch)
                         if governor is not None else None)
        self.sched = Scheduler(max_batch=max_batch, cap=self.cap,
                               policy=sched_policy, pool=self.pool,
                               max_pages=self.max_pages,
                               prefix_index=self.prefix_index,
                               tenants=tenants,
                               slo_aware=(True if (tenants or governor)
                                          else None))
        self.metrics = EngineMetrics(
            clock=clock,
            ttl_target_s=governor.ttl_target_s if governor else None)
        self._admission_retired: list[Request] = []
        self._frag_samples: list[float] = []

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        """Queue ``req`` for scheduled admission (the chunked-prefill
        path); ``step()`` admits it when a slot frees up."""
        if req.sampling is not None and self.sampling is None:
            raise ValueError("request carries SamplingParams but the "
                             "engine was built without sampling= (the "
                             "decode state has no sampling leaves)")
        self.metrics.on_submit(req.rid, tenant=req.tenant,
                               slo_class=req.slo_class)
        self.sched.submit(req)

    def pending(self) -> bool:
        """True while any request is queued, prefilling or decoding — or
        retired at admission but not yet reported by ``step()``."""
        return (bool(self.sched.queue) or any(self.slots)
                or bool(self._admission_retired))

    def add_request(self, req: Request) -> bool:
        """Legacy immediate admission: one-shot prefill ``req`` into a free
        slot right now; False if the engine is full (no queueing).  A
        request whose prompt can never fit the slot capacity is accepted
        (True) but retired immediately with ``finish_reason="rejected"``
        and reported by the next ``step()``."""
        if req.rid not in self.metrics.requests:
            self.metrics.on_submit(req.rid, tenant=req.tenant,
                                   slo_class=req.slo_class)
        if req.sampling is not None and self.sampling is None:
            raise ValueError("request carries SamplingParams but the "
                             "engine was built without sampling= (the "
                             "decode state has no sampling leaves)")
        slot = self.sched.assign_direct(req)
        if slot is None:
            if self.sched.rejected and self.sched.rejected[-1] is req:
                self.sched.rejected.pop()
                self.metrics.on_finish(req.rid, "rejected")
                self._admission_retired.append(req)
                return True
            return False
        self.metrics.on_admit(req.rid)
        self.slots[slot] = req
        # a first token that already retires (eos / max_new=1 / capacity)
        # is reported by the next step() call
        self._admission_retired += self._oneshot_prefill(req, slot)
        return True

    def preempt(self, rid: int) -> bool:
        """Release ``rid``'s slot mid-flight and requeue it at the queue
        front.  With a host tier (``host_pages``) a decoding request's
        live pool pages are **spilled** to the ``HostPageStore`` first, so
        resume is a block-table rebuild + H2D restore with zero re-prefill
        chunks and a bit-exact continued stream; without one (or when the
        store refuses the save) the pages drop and the resumed request
        re-prefills its prompt plus everything generated so far — greedy
        decoding continues with identical output tokens either way.
        Returns False when ``rid`` holds no slot."""
        for slot, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                spilled = False
                if slot in self._restores:
                    # restore still in flight: nothing committed on the
                    # device; cancel the job (the store entry survives, so
                    # the next resume retries the restore)
                    self._restores.pop(slot)
                elif (req.state == DECODE and self.spill_enabled
                        and self.store is not None):
                    spilled = self._spill(req, slot)
                req.buffers = None
                req.prefill_pos = 0
                req.prefill_tokens = None
                req.forced_tokens = None
                self.slots[slot] = None
                self.state["total_len"] = \
                    self.state["total_len"].at[slot].set(0)
                if self.paged:
                    # pages go back to the free list copy-free
                    # (sched.preempt -> release -> pool.free); park the row
                    # on the sink page
                    self.state["block_tables"] = \
                        self.state["block_tables"].at[slot].set(0)
                self.sched.preempt(slot, req)
                self.metrics.on_preempt(rid, spilled=spilled)
                return True
        return False

    def _spill(self, req: Request, slot: int) -> bool:
        """Save ``req``'s live pool pages (exact bytes: int8 payloads and
        scale planes included) into the host store before the pool
        releases them.  One device-side page gather + ONE batched
        device->host transfer per preemption — the sanctioned spill site
        (ANALYSIS_BASELINE.json); never a per-page transfer in a loop,
        which the ``sync.device-get-loop`` lint flags."""
        committed = self.sched.slot_len[slot]
        phys = self.pool.pages(req.rid)[:self.pool.pages_for(committed)]
        if committed <= 0 or not phys:
            return False
        planes = gather_pool_pages(self.state, phys)
        host = jax.device_get(planes)
        ok = self.store.put(f"spill:{req.rid}", host,
                            tokens=req.resume_tokens()[:committed])
        req.spill_key = f"spill:{req.rid}" if ok else None
        req.spill_len = committed if ok else 0
        if ok:
            self.metrics.bump("spills")
        self._sync_store_counters()
        return ok

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One bounded engine iteration: admission, at most one prefill
        chunk, one decode step for every decoding slot, then the TTL
        governor's control decision (when armed).  Returns the requests
        retired this step."""
        self._tick(steps=1)
        self._advance_restores()
        finished = self._admission_retired + self._admit()
        self._admission_retired = []
        finished += self._prefill_chunk()
        if self.decode_window > 1:
            finished += self._decode_window()
        else:
            finished += self._decode_step()
        self._govern()
        return finished

    def _tick(self, **work) -> None:
        """Advance a ``VirtualClock`` metrics clock by one tranche of
        modeled work (no-op on wall clocks): the base step cost, then
        each phase's decode-slot / prefill-token contribution as it
        happens — so TTFT/TTL samples taken inside a phase already
        include that phase's modeled cost."""
        if isinstance(self.metrics.clock, VirtualClock):
            self.metrics.clock.advance(**work)

    def _govern(self) -> None:
        """One TTL-governor decision per step: feed it the decoding
        batch-class requests youngest-first and execute the shed it
        returns through ``preempt`` — the host-tier spill path, so shed
        work resumes with zero re-prefill chunks."""
        if self.governor is None:
            return
        batch = sorted(
            ((r.admit_seq, r.rid) for r in self.slots
             if r is not None and r.state == DECODE
             and r.slo_class == SLO_BATCH),
            reverse=True)                       # youngest (newest) first
        rid = self.governor.step(self.metrics, self.sched,
                                 [b[1] for b in batch])
        if rid is not None:
            self.preempt(rid)
        self.metrics.set_counter("governor_sheds", self.governor.sheds)
        self.metrics.set_counter("governor_cap_raises",
                                 self.governor.cap_raises)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        """Step until queue and slots drain (or ``max_steps`` elapses)."""
        for _ in range(max_steps):
            if not self.pending():
                return
            self.step()

    def describe_backends(self) -> str:
        """One-line per-family kernel-backend summary (serve logging).

        Each family is tagged ``!nocontract`` when it registers no
        static-analysis contract hook — the same condition
        ``scripts/analyze.py --strict`` fails on (``contract.missing``),
        surfaced here so a serving log shows unaudited kernels at a glance.
        """
        if self.hx is None:
            return "ref (no HelixConfig)"
        from repro.kernels import registry
        parts = [f"{family}={getattr(self.hx, field)}"
                 + ("" if registry.FAMILIES[family].contract is not None
                    else "!nocontract")
                 for field, family in registry.FAMILY_FIELDS.items()]
        parts.append(f"fuse_append={self.hx.fuse_append}")
        parts.append(f"prune_blocks={self.hx.prune_blocks}")
        if self.paged:
            parts.append(f"paged_kv=True pool_blocks={self.pool_blocks} "
                         f"block_s={self.block_s}")
        if self.hx.lm_head_w8:
            parts.append("lm_head_w8=True")
        if self.chunk_tokens:
            parts.append(f"chunk_tokens={self.chunk_tokens}")
        return " ".join(parts)

    # -------------------------------------------------------------- phases
    def _admit(self) -> list[Request]:
        retired = []
        # one-shot prefills defer their first-token device value so ALL
        # admissions this step share ONE batched device->host transfer
        # (instead of one blocking int(np.asarray(...)) per prefill)
        deferred: list[tuple[Request, int, Any]] = []
        for req, slot in self.sched.admit():
            self.metrics.on_admit(req.rid)
            self.slots[slot] = req
            if self._try_restore(req, slot):
                continue
            toks = req.resume_tokens()
            if self.chunk_tokens and self.chunk_step is not None:
                from repro.models.model_zoo import init_prefill_buffers
                req.prefill_tokens = toks
                req.prefill_pos = 0
                req.buffers = init_prefill_buffers(
                    self.cfg, 1, len(toks), tp_width=self.tp_width)
                if self.prefix_index is not None:
                    self._prefix_admits += 1
                    if req.shared_len and req.shared_kv is not None:
                        self._prefix_hits += 1
                        self._restore_prefix(req)
            else:
                retired += self._oneshot_prefill(req, slot, defer=deferred)
        if deferred:
            vals = np.asarray(jnp.stack([d for _, _, d in deferred]))
            for (req, slot, _), v in zip(deferred, vals):
                retired += self._commit_first_token(req, slot, int(v))
        # cache-pressure rejections retire without ever holding a slot
        while self.sched.rejected:
            req = self.sched.rejected.pop()
            self.metrics.on_finish(req.rid, "rejected")
            retired.append(req)
        return retired

    def _restore_candidate(self, req: Request) -> tuple[str | None, int]:
        """Which host-store entry (if any) can resume ``req`` without
        re-prefilling, and how many committed tokens it covers.

        Preempt-spill entries win (exact pages of this very request);
        otherwise a session entry whose stored tokens are a prefix of the
        new prompt covers the conversation history.  Either way the
        restored span must leave at least one token to decode (the engine
        re-enters DECODE with ``cur = resume[m]`` and teacher-forces the
        rest), and a prefix-share match longer than the restorable span
        wins instead."""
        resume = req.resume_tokens()
        if req.spill_key is not None:
            toks = self.store.tokens(req.spill_key)
            m = 0 if toks is None else len(toks)
            if 0 < m < len(resume) and tuple(resume[:m]) == toks:
                return req.spill_key, m
        if self.session_kv and req.session_id is not None:
            key = f"session:{req.session_id}"
            toks = self.store.tokens(key)
            if toks:
                m = min(len(toks), len(resume) - 1)
                if (m > 0 and tuple(resume[:len(toks)])[:m] == toks[:m]
                        and tuple(resume[:m]) == toks[:m]
                        and m > req.shared_len):
                    return key, m
        return None, 0

    def _try_restore(self, req: Request, slot: int) -> bool:
        """Attempt the zero-re-prefill resume path at admission.

        On a store hit the request enters RESTORING and a restore job is
        queued: pages scatter back H2D and decode continues exactly where
        it left off — committed the same step when the tier is healthy, or
        after the injected ``delay`` steps (other slots keep decoding
        meanwhile, so a slow host tier degrades this request's TTFT, never
        in-flight TTL).  Any failure (missing/evicted entry, injected
        restore_fail, checksum/generation mismatch) returns False and the
        caller falls back to the old re-prefill path — counted, never
        divergent."""
        if self.store is None:
            return False
        key, committed = self._restore_candidate(req)
        if key is None:
            return False
        planes, delay, why = self.store.restore(key)
        self._sync_store_counters()
        if planes is None:
            if why != "missing":
                self.metrics.bump("restores_failed")
            req.resume_fallback = True   # this admission re-prefills
            if req.spill_key == key:
                req.spill_key = None     # don't retry a dead entry
                req.spill_len = 0
            return False
        req.state = RESTORING
        req.prefill_tokens = None
        req.buffers = None
        self._restores[slot] = {"req": req, "planes": planes,
                                "remaining": delay, "committed": committed,
                                "t0": self.metrics.clock()}
        if delay == 0:
            self._commit_restore(slot)
        return True

    def _advance_restores(self) -> None:
        """Tick the in-flight (fault-delayed) restore jobs by one engine
        step, committing those whose delay expired.  Runs before
        admission, so ``delay=d`` holds the slot idle for exactly ``d``
        steps while every other slot prefills/decodes normally."""
        for slot in list(self._restores):
            job = self._restores[slot]
            job["remaining"] -= 1
            if job["remaining"] <= 0:
                self._commit_restore(slot)

    def _commit_restore(self, slot: int) -> None:
        """Land a restore job: H2D-scatter the spilled pages into the
        pages granted at re-admission (skipping prefix-shared leading
        pages, which already hold byte-identical rows), rebuild the
        device block-table row, reinstall the committed length, and
        re-enter DECODE with the catch-up token queue — zero prefill
        chunks."""
        job = self._restores.pop(slot)
        req: Request = job["req"]
        committed: int = job["committed"]
        n = self.pool.pages_for(committed)
        phys = self.pool.pages(req.rid)[:n]
        s0 = min(req.shared_pages, n)
        if s0 < n:
            self.state = scatter_pool_pages(
                self.state, phys[s0:n],
                {k: v[:, s0:n] for k, v in job["planes"].items()})
        self._mirror_table(slot)
        self.state["total_len"] = \
            self.state["total_len"].at[slot].set(committed)
        self.sched.slot_len[slot] = committed
        resume = req.resume_tokens()
        self.cur_tokens = self.cur_tokens.at[slot].set(int(resume[committed]))
        # tokens beyond the restored span that are already known (the
        # resumed request's last sample / the session's new turn) are
        # teacher-forced through the decode path one step each — they
        # attend over the restored pages, so no prefill chunk ever runs
        req.forced_tokens = list(resume[committed + 1:])
        req.shared_kv = None
        req.state = DECODE
        self._install_sampling(req, slot)
        if req.spill_key is not None:
            # one-shot: the entry is stale the moment decode continues
            self.store.drop(req.spill_key)
            req.spill_key = None
            req.spill_len = 0
        self.metrics.bump("restores")
        self.metrics.on_restore(req.rid, self.metrics.clock() - job["t0"])
        self._sync_store_counters()

    def _sync_store_counters(self) -> None:
        """Mirror the store's monotonic fault counters into the metrics
        summary (idempotent absolute sets)."""
        self.metrics.set_counter("checksum_mismatches",
                                 self.store.checksum_mismatches
                                 + self.store.stale_generations)
        self.metrics.set_counter("store_evictions", self.store.evictions)

    def _restore_prefix(self, req: Request) -> None:
        """Install the prefix index's host-fp K/V for the matched prefix
        into ``req``'s fresh carry buffers and fast-forward the prefill to
        the suffix.

        The stored K/V is the registrant's own prefill output for those
        positions — bit-identical to what re-prefilling the same tokens
        would write (chunked prefill is causal with absolute rope
        positions), so skipping ``[0, shared_len)`` changes nothing
        downstream: TTFT becomes suffix-only."""
        m = req.shared_len
        k_np, v_np = req.shared_kv
        req.shared_kv = None
        for key, host in (("kcache", k_np), ("vcache", v_np)):
            req.buffers[key] = req.buffers[key].at[:, 0, :m].set(
                jnp.asarray(host[:, :m],
                            req.buffers[key].dtype))
        req.prefill_pos = m

    def _register_prefix(self, req: Request, t: int) -> None:
        """Publish a finished prefill to the prefix index: its token
        prefix, its (now committed) page list, and a host fp copy of its
        carry-buffer K/V.

        Captured *before* any quantization: a later hit restores fp rows
        into the sharer's buffers, keeping the suffix prefill bit-exact
        even on kv8 engines (whose pool pages quantize per row, so the
        shared physical pages are also byte-identical to what the sharer
        would have written)."""
        kv = (np.asarray(req.buffers["kcache"][:, 0, :t]),
              np.asarray(req.buffers["vcache"][:, 0, :t]))
        self.prefix_index.register(list(req.prefill_tokens),
                                   list(self.pool.pages(req.rid)), kv)

    def _prefill_chunk(self) -> list[Request]:
        """Advance ONE packed group of prefills by one chunk.

        Ragged packing: requests at *different* (offset, length) prefill
        progress pack into one chunk call — flash_prefill takes per-row
        ``q_offset`` and each request writes its chunk at its own buffer
        offset, so the packed call is bit-exact with per-request calls
        (batch rows are independent; carry buffers are zero-padded to the
        group's longest prompt, and those pad rows sit at positions every
        causal query masks).  The only shared dimension is the chunk width
        ``c`` (the token array must be rectangular), so the group is
        "every prefilling request with the same remaining-clamped chunk
        width as the oldest one"; the group containing the oldest
        prefilling request goes first."""
        pre = [(slot, r) for slot, r in enumerate(self.slots)
               if r is not None and r.state == PREFILL
               and r.prefill_tokens is not None]
        if not pre:
            return []

        def width(r: Request) -> int:
            return min(self.chunk_tokens,
                       len(r.prefill_tokens) - r.prefill_pos)

        # oldest admission first (admit_seq), NOT lowest slot index — a
        # freed low slot must not let fresh admissions starve an in-flight
        # prefill parked in a higher slot
        first = min(pre, key=lambda sr: sr[1].admit_seq)[1]
        c = width(first)
        group = [(s, r) for s, r in pre if width(r) == c]
        self._tick(prefill_tokens=c * len(group))
        for _, r in group:
            if self._is_resume(r):
                # a prefill chunk that reruns known context — zero on the
                # host-tier happy path, counted on every fallback
                self.metrics.bump("resume_reprefill_chunks")
        tokens = jnp.asarray(
            np.stack([r.prefill_tokens[r.prefill_pos:r.prefill_pos + c]
                      for _, r in group]), jnp.int32)
        tmax = max(len(r.prefill_tokens) for _, r in group)

        def padbuf(a):
            pad = tmax - a.shape[2]
            if pad == 0:
                return a
            width_ = [(0, 0)] * a.ndim
            width_[2] = (0, pad)
            return jnp.pad(a, width_)

        bufs = jax.tree.map(
            lambda *leaves: jnp.concatenate([padbuf(a) for a in leaves],
                                            axis=1),
            *[r.buffers for _, r in group])
        offs = jnp.asarray([r.prefill_pos for _, r in group], jnp.int32)
        if self.sampling is not None:
            # sampling engines build their chunk step with
            # return_last_logits=True: the done rows' final-position logits
            # feed the on-device first-token sampler
            next_toks, last_logits, bufs = self.chunk_step(
                self.params, tokens, bufs, offs)
        else:
            next_toks, bufs = self.chunk_step(self.params, tokens, bufs, offs)
        finished = []
        done = [r.prefill_pos + c >= len(r.prefill_tokens)
                for _, r in group]
        # one batched transfer for every request finishing this chunk
        first_np = None
        if any(done):
            di = [i for i, d in enumerate(done) if d]
            if self.sampling is not None:
                dev = self._first_token_dev(
                    last_logits[jnp.asarray(di)],
                    [group[i][1] for i in di])
            else:
                dev = next_toks[jnp.asarray(di), c - 1]
            first_np = {i: v for i, v in zip(di, np.asarray(dev))}
        for i, (slot, req) in enumerate(group):
            t_i = len(req.prefill_tokens)
            req.buffers = jax.tree.map(lambda a: a[:, i:i + 1, :t_i], bufs)
            req.prefill_pos += c
            if done[i]:
                finished += self._finish_prefill(req, slot,
                                                 int(first_np[i]))
        return finished

    def _finish_prefill(self, req: Request, slot: int,
                        first_token: int) -> list[Request]:
        """Chunked prefill complete: hand the carry buffers off to the
        decode slot and commit the first generated token."""
        from repro.models.model_zoo import finalize_chunked_prefill
        t = len(req.prefill_tokens)
        hx = self.hx if self.hx is not None else _default_hx(self.rr)
        pstate = finalize_chunked_prefill(self.cfg, hx, req.buffers, t,
                                          kvp=self.kvp)
        if self.prefix_index is not None:
            self._register_prefix(req, t)
        req.buffers = None
        req.prefill_tokens = None
        self._scatter_state(pstate, slot, t, req)
        return self._commit_first_token(req, slot, first_token)

    def _is_resume(self, req: Request) -> bool:
        """Whether this request's prefill work recomputes context the host
        tier could have restored: it was preempted before, or a restore
        attempt for it failed this admission."""
        m = self.metrics.requests.get(req.rid)
        return bool((m is not None and m.n_preempts > 0)
                    or req.resume_fallback)

    def _oneshot_prefill(self, req: Request, slot: int,
                         defer: list | None = None) -> list[Request]:
        toks_list = req.resume_tokens()
        if self._is_resume(req):
            # the whole one-shot prefill is one "chunk" of redone work
            self.metrics.bump("resume_reprefill_chunks")
        toks = jnp.asarray(toks_list, jnp.int32)[None, :]
        last_logits, pstate = self.prefill_step(self.params, {"tokens": toks})
        self._scatter_state(pstate, slot, len(toks_list), req)
        # device-side first-token decision (argmax, or the sampler when
        # the engine samples — prefill logits come out of ``forward``
        # already softcapped + vocab-masked, so they feed it directly)
        nxt_dev = self._first_token_dev(last_logits, [req])[0]
        if defer is not None:
            # scheduled admission: _admit batches every prefill's token
            # into ONE host transfer per engine step
            defer.append((req, slot, nxt_dev))
            return []
        nxt = int(np.asarray(nxt_dev))
        return self._commit_first_token(req, slot, nxt)

    def _first_token_dev(self, last_logits, reqs: list[Request]):
        """Device-side first-token decision for freshly prefilled rows:
        ``last_logits`` [G, padded_vocab] (vocab-masked by ``forward``),
        one row per request.  Greedy engines take the plain argmax;
        sampling engines run the serving/sampling.py sampler at
        ``sample_idx = 0`` — the first point of each request's PRNG
        stream, so prefill-time sampling and a decode-step sample of the
        same position agree bit-exactly."""
        if self.sampling is None:
            return jnp.argmax(last_logits[:, :self.cfg.vocab],
                              axis=-1).astype(jnp.int32)
        from repro.serving.sampling import request_seed, sample_tokens
        pols = [(r.sampling or self.sampling) for r in reqs]
        rows = [p.row() for p in pols]
        return sample_tokens(
            last_logits,
            jnp.asarray([v[0] for v in rows], jnp.float32),
            jnp.asarray([v[1] for v in rows], jnp.int32),
            jnp.asarray([v[2] for v in rows], jnp.float32),
            jnp.asarray([request_seed(p.seed, r.rid)
                         for p, r in zip(pols, reqs)], jnp.uint32),
            jnp.zeros((len(reqs),), jnp.int32))

    def _install_sampling(self, req: Request, slot: int) -> None:
        """Install ``req``'s sampling policy into ``slot``'s per-row state
        leaves.  ``sample_idx`` resumes at ``len(out_tokens)`` — the count
        of tokens already sampled — so a restored/preempted request
        continues the exact PRNG stream it left (forced catch-up tokens
        do not advance it, on either decode path)."""
        if self.sampling is None:
            return
        from repro.serving.sampling import request_seed
        sp = req.sampling or self.sampling
        t, k, p = sp.row()
        st = self.state
        st["sample_temp"] = st["sample_temp"].at[slot].set(t)
        st["sample_topk"] = st["sample_topk"].at[slot].set(k)
        st["sample_topp"] = st["sample_topp"].at[slot].set(p)
        st["sample_seed"] = st["sample_seed"].at[slot].set(
            request_seed(sp.seed, req.rid))
        st["sample_idx"] = st["sample_idx"].at[slot].set(len(req.out_tokens))

    def _commit_first_token(self, req: Request, slot: int,
                            token: int) -> list[Request]:
        req.out_tokens.append(token)
        self.cur_tokens = self.cur_tokens.at[slot].set(token)
        req.state = DECODE
        self._install_sampling(req, slot)
        self.metrics.on_token(req.rid)
        self.sched.record_served(slot)
        # the prefill token itself may already retire the request
        if (req.eos_id is not None and token == req.eos_id):
            return [self._retire(req, slot, "eos")]
        if len(req.out_tokens) >= req.max_new_tokens:
            return [self._retire(req, slot, "max_tokens")]
        r = self._grow_or_retire(req, slot)
        return [r] if r is not None else []

    def _grow_or_retire(self, req: Request, slot: int) -> Request | None:
        """Reserve what the next decode token needs through the capacity
        oracle (``Scheduler.grow_for_next_token``): fixed layout — nothing,
        until ``cap``; paged — the next page when a boundary is crossed,
        mirrored into the device block table.  Returns the retired request
        when growth is impossible (``finish_reason="capacity"``)."""
        grown = self.sched.grow_for_next_token(slot)
        if grown is None:
            return self._retire(req, slot, "capacity")
        if grown:
            self._mirror_table(slot)
        return None

    def _mirror_table(self, slot: int) -> None:
        """Write ``slot``'s page list into the device block-table row
        (unused tail entries point at the sink page 0)."""
        phys = self.pool.pages(self.slots[slot].rid)
        row = np.zeros((self.max_pages,), np.int32)
        row[:len(phys)] = phys
        self.state["block_tables"] = \
            self.state["block_tables"].at[slot].set(jnp.asarray(row))

    def _scatter_state(self, pstate: dict[str, Any], slot: int,
                       t: int, req: Request) -> None:
        """Scatter a single-request prefill state into ``slot`` (copying
        the common round-robin prefix of every rank's local slots; int8
        engines quantize the fp prefill cache per slot row —
        ``quantize_decode_state`` — matching the decode append formula).
        Paged engines instead split the round-robin cache into pages
        (``cache_to_pages``) and write them at the physical pool planes the
        allocator granted at admission, then install the block-table row."""
        if self.paged and "kcache" in pstate:
            self._scatter_paged(pstate, slot, t, req)
        elif self.kv8 and "kcache" in pstate:
            fp_slot = {}
            for key in ("kcache", "vcache"):
                dst = jnp.zeros(
                    self.state[key].shape[:1] + (1,)
                    + self.state[key].shape[2:], jnp.float32)
                src = pstate[key][:, 0].astype(jnp.float32)
                fp_slot[key] = dst.at[:, 0].set(
                    _copy_rr(src, dst[:, 0], self.kvp))
            q = quantize_decode_state(fp_slot)
            for key in ("kcache", "vcache", "kscale", "vscale"):
                self.state[key] = self.state[key].at[:, slot].set(q[key][:, 0])
        else:
            for key in ("kcache", "vcache"):
                if key in self.state and key in pstate:
                    # prefill cache capacity may differ; copy the common
                    # prefix of every rank's local slots (layouts match:
                    # same kvp/rr)
                    src = pstate[key][:, 0]
                    dst = self.state[key][:, slot]
                    self.state[key] = self.state[key].at[:, slot].set(
                        _copy_rr(src, dst, self.kvp))
        for key in ("ssm_conv", "ssm_state", "xk", "xv"):
            if key in self.state and key in pstate:
                self.state[key] = self.state[key].at[:, slot].set(
                    pstate[key][:, 0])
        self.state["total_len"] = self.state["total_len"].at[slot].set(t)

    def _scatter_paged(self, pstate: dict[str, Any], slot: int,
                       t: int, req: Request) -> None:
        """Paged half of ``_scatter_state``: prefill cache -> pool pages.

        The request's round-robin cache splits into page stacks
        (``cache_to_pages`` — pages hold ``block_s`` consecutive positions)
        written at the physical planes granted at admission.  Pages granted
        beyond the prefill extent stay untouched: any stale rows they hold
        sit at positions >= t, which every backend masks.  int8 engines
        quantize pagewise with the decode-append formula, exactly like the
        fixed path."""
        phys = self.pool.pages(req.rid)
        pages = {key: cache_to_pages(pstate[key][:, 0], self.kvp,
                                     self.block_s)
                 for key in ("kcache", "vcache")}
        n = min(pages["kcache"].shape[1], len(phys))
        # shared leading pages already hold the registrant's rows —
        # byte-identical to what this request would write for the same
        # token prefix (per-row quantization on kv8), and possibly still
        # mapped by other requests; only the unshared tail is scattered.
        s0 = min(getattr(req, "shared_pages", 0), n)
        if s0 < n:
            idx = jnp.asarray(phys[s0:n], jnp.int32)
            if self.kv8:
                qpages = quantize_decode_state(
                    {key: pages[key][:, s0:n].astype(jnp.float32)
                     for key in ("kcache", "vcache")})
                for key in ("kcache", "vcache", "kscale", "vscale"):
                    self.state[key] = \
                        self.state[key].at[:, idx].set(qpages[key])
            else:
                for key in ("kcache", "vcache"):
                    self.state[key] = self.state[key].at[:, idx].set(
                        pages[key][:, s0:n].astype(self.state[key].dtype))
        self._mirror_table(slot)
        # (_scatter_state's shared tail installs total_len and ssm leaves)

    def _cow_guard(self, active: list[int]) -> None:
        """Make every slot's append-target page exclusive before the decode
        step writes it (copy-on-write).

        The admission path already CoWs a shared partial page eagerly, so a
        shared append target here means a request decoded *through* a page
        boundary into a still-shared page — possible only when a request's
        committed length ends exactly on the shared-prefix boundary.  The
        allocator hands back a fresh page; the device copy of the old
        page's committed rows happens here, before the kernel's append."""
        for i in active:
            req = self.slots[i]
            li = self.sched.slot_len[i] // self.block_s
            phys = self.pool.pages(req.rid)
            if li >= len(phys) or self.pool.refcount(phys[li]) == 1:
                continue
            res = self.pool.cow(req.rid, li)
            assert res is not None, \
                "CoW with an empty free list: admission must pre-charge " \
                "the divergent page (scheduler._reserve)"
            old, new = res
            keys = ("kcache", "vcache") + \
                (("kscale", "vscale") if self.kv8 else ())
            for key in keys:
                self.state[key] = \
                    self.state[key].at[:, new].set(self.state[key][:, old])
            self._mirror_table(i)

    def _set_groups(self, active: list[int]) -> None:
        """Refresh the grouped-decode ``group_id``/``group_np`` leaves.

        Slots whose tables start on the same physical page form a group;
        ``group_np`` is the longest run of *identical* leading pages common
        to every member, capped at each member's full committed pages so
        the fused append (block ``slot_len // block_s``) always lands in
        the per-request suffix.  Every member gets the same ``group_np`` —
        the prefix pass has no per-member block mask, so an unequal start
        would double-count the blocks between the smallest and largest.
        Singletons and idle rows stay their own group with ``group_np=0``,
        which the kernel decodes exactly as ungrouped."""
        gid = np.arange(self.max_batch, dtype=np.int32)
        gnp = np.zeros(self.max_batch, dtype=np.int32)
        buckets: dict[int, list[int]] = {}
        for i in active:
            pages = self.pool.pages(self.slots[i].rid)
            if pages and pages[0] != 0:
                buckets.setdefault(pages[0], []).append(i)
        for members in buckets.values():
            if len(members) < 2:
                continue
            lists = [self.pool.pages(self.slots[i].rid) for i in members]
            depth = min(min(len(pl) for pl in lists),
                        min(self.sched.slot_len[i] // self.block_s
                            for i in members))
            lcp = 0
            while lcp < depth and all(pl[lcp] == lists[0][lcp]
                                      for pl in lists):
                lcp += 1
            if lcp == 0:
                continue
            g = min(members)
            for i in members:
                gid[i] = g
                gnp[i] = lcp
        self.state["group_id"] = jnp.asarray(gid)
        self.state["group_np"] = jnp.asarray(gnp)

    def _decode_step(self) -> list[Request]:
        """One decode step for every DECODE slot; returns retirements."""
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and r.state == DECODE]
        if not active:
            return []
        self._tick(decode_slots=len(active))
        if self.paged and self.prefix_index is not None:
            self._cow_guard(active)
        if self.grouped:
            self._set_groups(active)
        next_tokens, self.state = self.serve_step(
            self.params, self.state, self.cur_tokens)
        self.cur_tokens = next_tokens
        # serve_step advances total_len for every row; pin non-decoding
        # slots back to 0.  (Not the prefilling request's committed length:
        # its K/V still lives in the carry buffers, so a non-zero length
        # would make every decode step stream that many garbage cache
        # blocks for the slot.  Length 0 keeps the dead row O(1) and the
        # finalize scatter installs the real total_len.)
        idle = [i for i in range(self.max_batch) if i not in active]
        if idle:
            self.state["total_len"] = \
                self.state["total_len"].at[jnp.asarray(idle)].set(0)
        # one batched device->host transfer per step (per-slot int() calls
        # would each block on the device queue — B syncs instead of 1)
        toks_np = np.asarray(next_tokens)
        self.decode_syncs += 1
        finished = []
        forced: list[tuple[int, int]] = []
        for i in active:
            req = self.slots[i]
            if req.forced_tokens:
                # teacher-forced catch-up after a restore: this step
                # appended the KV row for the current *known* token, so
                # the sampled token is overridden by the next known one.
                # Nothing is emitted (these are prompt/history tokens,
                # not samples): no out_tokens append, no TTFT/TTL event —
                # only the committed length advances.
                forced.append((i, req.forced_tokens.pop(0)))
                self.sched.on_token(i)
                r = self._grow_or_retire(req, i)
                if r is not None:
                    finished.append(r)
                continue
            tok = int(toks_np[i])
            req.out_tokens.append(tok)
            self.sched.on_token(i)
            self.sched.record_served(i)
            self.metrics.on_token(req.rid)
            self.decoded_tokens += 1
            if req.eos_id is not None and tok == req.eos_id:
                finished.append(self._retire(req, i, "eos"))
            elif len(req.out_tokens) >= req.max_new_tokens:
                finished.append(self._retire(req, i, "max_tokens"))
            else:
                r = self._grow_or_retire(req, i)
                if r is not None:
                    finished.append(r)
        if forced:
            idx = jnp.asarray([i for i, _ in forced], jnp.int32)
            val = jnp.asarray([t for _, t in forced], jnp.int32)
            self.cur_tokens = self.cur_tokens.at[idx].set(val)
            if self.sampling is not None:
                # forced catch-up consumed no sample: rewind the PRNG
                # counter serve_step advanced for those rows, so the
                # post-catch-up stream re-joins the original exactly
                self.state["sample_idx"] = \
                    self.state["sample_idx"].at[idx].add(-1)
        if self.paged:
            self._sample_pool()
        return finished

    def _decode_window(self) -> list[Request]:
        """N decode steps for every DECODE slot in ONE device dispatch.

        The windowed inner loop (``--decode-window N`` > 1): the scheduler
        pre-reserves each slot's page/capacity budget for the whole window
        (``grow_for_window`` — one atomic extend, so nothing allocates
        mid-window), ``serve_multistep`` runs N sample->append->step
        iterations entirely on device with per-row EOS/budget/forced masks,
        and the host blocks exactly once on the ``[B, N]`` token block —
        syncs per decoded token drop from 1 to 1/N.  The transfer is
        started async (``copy_to_host_async``) and the window's host-side
        bookkeeping overlaps the copy; the donated state means the next
        window's dispatch can be enqueued as soon as the replay finishes,
        overlapping host scheduling of window k+1 with device compute
        still in flight.

        The replay is j-major (in-window step order) so scheduler token
        accounting, retirement order and VirtualClock TTL attribution all
        match the single-step engine event for event; rows that freeze
        mid-window (EOS, max-tokens, capacity-limited budget) retire at
        the boundary, which keeps windowed streams bit-identical to
        window=1 (tests/serving/test_decode_window.py)."""
        n = self.decode_window
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and r.state == DECODE]
        if not active:
            return []
        finished = []
        budgets = np.zeros((self.max_batch,), np.int32)
        wants = np.zeros((self.max_batch,), np.int32)
        eos = np.full((self.max_batch,), -1, np.int32)
        forced = np.zeros((self.max_batch, n), np.int32)
        nforced = np.zeros((self.max_batch,), np.int32)
        stepping = []
        for i in active:
            req = self.slots[i]
            nf = min(len(req.forced_tokens or ()), n)
            emit_max = max(req.max_new_tokens - len(req.out_tokens), 0)
            want = min(n, nf + emit_max)
            grant = self.sched.grow_for_window(i, want)
            if self.paged and grant:
                self._mirror_table(i)
            if grant == 0:
                # can't take a single step: the capacity retire the
                # single-step engine's grow_for_next_token would have hit
                finished.append(self._retire(req, i, "capacity"))
                continue
            budgets[i], wants[i] = grant, want
            if req.eos_id is not None:
                eos[i] = req.eos_id
            if nf:
                forced[i, :nf] = req.forced_tokens[:nf]
                nforced[i] = nf
            stepping.append(i)
        if not stepping:
            return finished
        if self.paged and self.prefix_index is not None:
            self._cow_guard(stepping)
        t0 = time.monotonic()
        out_block, cur, self.state = self.serve_multistep(
            self.params, self.state, self.cur_tokens,
            jnp.asarray(budgets), jnp.asarray(eos),
            jnp.asarray(forced), jnp.asarray(nforced))
        self.cur_tokens = cur
        # kick off the D2H copy, overlap host bookkeeping with it, then
        # block ONCE on the whole window's token block
        if hasattr(out_block, "copy_to_host_async"):
            out_block.copy_to_host_async()
        if self.paged:
            self._sample_pool()
        toks_np = np.asarray(out_block)
        self.decode_syncs += 1
        t1 = time.monotonic()
        # j-major replay: the same scheduler/metrics/retirement events the
        # single-step engine would emit, in the same order.  TTL samples
        # get in-window timestamps — VirtualClock ticks per replayed step,
        # wall clocks interpolate the measured window time over N.
        virtual = isinstance(self.metrics.clock, VirtualClock)
        nsteps = int(max(budgets[i] for i in stepping))
        retired: set[int] = set()
        for j in range(nsteps):
            rows = [i for i in stepping
                    if i not in retired and budgets[i] > j]
            if not rows:
                break
            at = None
            if virtual:
                self._tick(decode_slots=len(rows))
            else:
                at = t0 + (t1 - t0) * (j + 1) / nsteps
            for i in rows:
                req = self.slots[i]
                if req.forced_tokens:
                    # device fed the forced token in place of its sample
                    # (emitting pad); only the committed length advances
                    req.forced_tokens.pop(0)
                    self.sched.on_token(i)
                    continue
                tok = int(toks_np[i, j])
                req.out_tokens.append(tok)
                self.sched.on_token(i)
                self.sched.record_served(i)
                self.metrics.on_token(req.rid, at=at)
                self.decoded_tokens += 1
                if req.eos_id is not None and tok == req.eos_id:
                    finished.append(self._retire(req, i, "eos"))
                    retired.add(i)
                elif len(req.out_tokens) >= req.max_new_tokens:
                    finished.append(self._retire(req, i, "max_tokens"))
                    retired.add(i)
        # a capacity-limited grant the in-window EOS/max replay didn't
        # consume means the pool/cap wall sits exactly where the
        # single-step engine would retire with "capacity"
        for i in stepping:
            if i not in retired and budgets[i] < wants[i]:
                finished.append(self._retire(self.slots[i], i, "capacity"))
        return finished

    def sync_stats(self) -> dict[str, Any]:
        """Host-sync accounting for the decode loop: how many blocking
        device->host transfers the engine performed per decoded token.
        ``syncs_per_token`` is 1.0 for the single-step engine and 1/N
        under ``--decode-window N`` — the headline number of this
        optimization, asserted by scripts/decode_window_smoke.py and
        surfaced as a bench_serving column."""
        return {"decode_window": self.decode_window,
                "decode_syncs": self.decode_syncs,
                "decoded_tokens": self.decoded_tokens,
                "syncs_per_token":
                    self.decode_syncs / max(self.decoded_tokens, 1)}

    def _sample_pool(self) -> None:
        """Record one pool-health sample (occupancy / internal
        fragmentation of allocated pages) for ``pool_stats``."""
        used = self.pool.used_count
        if used == 0:
            return
        committed = sum(self.sched.slot_len)
        self._frag_samples.append(
            1.0 - committed / (used * self.block_s))

    def pool_stats(self) -> dict[str, float]:
        """Paged-pool health for the serving bench: peak occupancy (peak
        pages in use / allocatable pages), mean internal fragmentation of
        allocated pages (1 - committed/allocated slots, sampled each decode
        step), the retirement count with ``finish_reason="capacity"``, and
        the prefix-sharing pair: ``prefix_hit_rate`` (share of chunked
        admissions that matched a cached prefix) and ``pages_shared_peak``
        (peak pages mapped by more than one request).  Fixed-cap engines
        report zeros for the pool fields; ``capacity_retired`` is the real
        count on both layouts."""
        cap_retired = sum(
            1 for m in self.metrics.requests.values()
            if getattr(m, "finish_reason", None) == "capacity")
        if not self.paged:
            return {"paged_kv": False, "pool_occupancy_peak": 0.0,
                    "pool_frag_mean": 0.0, "capacity_retired": cap_retired,
                    "prefix_hit_rate": 0.0, "pages_shared_peak": 0,
                    "store_evictions": 0}
        frag = (float(np.mean(self._frag_samples))
                if self._frag_samples else 0.0)
        return {"paged_kv": True,
                "pool_occupancy_peak":
                    self.pool.peak_in_use / max(self.pool.capacity, 1),
                "pool_frag_mean": frag,
                "capacity_retired": cap_retired,
                "prefix_hit_rate":
                    self._prefix_hits / max(self._prefix_admits, 1),
                "pages_shared_peak": self.pool.pages_shared_peak,
                "store_evictions":
                    self.store.evictions if self.store is not None else 0}

    def tier_stats(self) -> dict:
        """Host KV tier health for the serving bench: store occupancy and
        the save/restore/fault counters (``HostPageStore.stats``).  Engines
        without a host store report all-zero counters so downstream schema
        consumers never key-error."""
        if self.store is None:
            return {k: 0 for k in (
                "host_pages_capacity", "host_pages_used", "host_entries",
                "host_saves", "host_restores", "restores_failed",
                "checksum_mismatches", "stale_generations",
                "store_evictions", "store_full")}
        return self.store.stats()

    def _retire(self, req: Request, slot: int, reason: str) -> Request:
        req.done = True
        req.state = DONE
        req.finish_reason = reason
        # session KV: persist the retired request's committed pages keyed
        # by session id — BEFORE the pool reclaims them — so the next turn
        # restores the conversation history instead of re-prefilling it
        if (self.session_kv and req.session_id is not None
                and self.store is not None
                and reason in ("eos", "max_tokens")):
            self._save_session(req, slot)
        if req.spill_key is not None:
            # a retired request never resumes; free its spill entry
            self.store.drop(req.spill_key)
            req.spill_key = None
            req.spill_len = 0
        self.slots[slot] = None
        self.sched.release(slot)
        self.state["total_len"] = self.state["total_len"].at[slot].set(0)
        if self.paged:
            # park the freed row on the sink page (all-zero table row)
            self.state["block_tables"] = \
                self.state["block_tables"].at[slot].set(0)
        self.metrics.on_finish(req.rid, reason)
        return req

    def _save_session(self, req: Request, slot: int) -> None:
        """Spill a retiring request's committed pages under its session
        key (same exact-bytes gather + one batched D2H as ``_spill``).
        The stored token prefix is ``prompt + out[:-1]`` — always a proper
        prefix of turn N+1's prompt (history + new text), which is what
        makes the restore applicability check a plain prefix match."""
        committed = self.sched.slot_len[slot]
        phys = self.pool.pages(req.rid)[:self.pool.pages_for(committed)]
        if committed <= 0 or not phys:
            return
        planes = gather_pool_pages(self.state, phys)
        host = jax.device_get(planes)
        if self.store.put(f"session:{req.session_id}", host,
                          tokens=req.resume_tokens()[:committed]):
            self.metrics.bump("spills")
        self._sync_store_counters()


def _default_hx(rr_block: int) -> HelixConfig:
    return HelixConfig(kvp_axes=(), tpa_axis=None, rr_block=rr_block)


def _copy_rr(src, dst, kvp: int):
    """Copy a round-robin cache [L?, Kh, S_src, hsz] into capacity S_dst.

    Both layouts are (rank-major, local-slot) with the same kvp/rr, so rank
    r's local slots [0, S_src/kvp) map to dst-local slots [0, S_src/kvp).
    """
    s_src = src.shape[-2]
    s_dst = dst.shape[-2]
    if s_src == s_dst:
        return src
    ls, ld = s_src // kvp, s_dst // kvp
    n = min(ls, ld)
    srcr = src.reshape(*src.shape[:-2], kvp, ls, src.shape[-1])
    dstr = dst.reshape(*dst.shape[:-2], kvp, ld, dst.shape[-1])
    out = dstr.at[..., :, :n, :].set(srcr[..., :, :n, :])
    return out.reshape(dst.shape)
