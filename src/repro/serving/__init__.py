from repro.serving.engine import DecodeEngine, Request
from repro.serving.metrics import EngineMetrics, RequestMetrics
from repro.serving.scheduler import (DECODE, DONE, PREFILL, QUEUED,
                                     Scheduler)

__all__ = ["DecodeEngine", "Request", "Scheduler", "EngineMetrics",
           "RequestMetrics", "QUEUED", "PREFILL", "DECODE", "DONE"]
