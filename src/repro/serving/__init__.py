from repro.serving.engine import DecodeEngine, Request
from repro.serving.governor import GovernorConfig, TTLGovernor
from repro.serving.metrics import EngineMetrics, RequestMetrics, VirtualClock
from repro.serving.sampling import (SAMPLING_KINDS, SamplingParams,
                                    request_seed, sample_oracle,
                                    sample_tokens)
from repro.serving.scheduler import (DECODE, DONE, PREFILL, QUEUED,
                                     SLO_BATCH, SLO_CLASSES,
                                     SLO_INTERACTIVE, Scheduler,
                                     TenantConfig)
from repro.serving.workload import (TenantSpec, TraceRow, generate_trace,
                                    load_trace, requests_from_trace,
                                    save_trace, trace_id)

__all__ = ["DecodeEngine", "Request", "Scheduler", "EngineMetrics",
           "SamplingParams", "SAMPLING_KINDS", "request_seed",
           "sample_tokens", "sample_oracle",
           "RequestMetrics", "VirtualClock", "TenantConfig", "TenantSpec",
           "TraceRow", "GovernorConfig", "TTLGovernor", "generate_trace",
           "load_trace", "save_trace", "trace_id", "requests_from_trace",
           "QUEUED", "PREFILL", "DECODE", "DONE",
           "SLO_INTERACTIVE", "SLO_BATCH", "SLO_CLASSES"]
