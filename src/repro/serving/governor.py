"""Adaptive TTL governor: trades batch-class concurrency for interactive
latency.

The paper's whole premise is a hard interactive TTL budget while batch
size grows (PAPER.md §1); a *static* batch cap either wastes slots when
interactive traffic is light or blows the budget when it isn't.  The
governor replaces it with measured-TTL feedback: each engine step it
reads the windowed interactive TTL p95 estimator
(``EngineMetrics.recent_ttl_p95``) and

  * **sheds** when p95 drifts past target — lowers the scheduler's
    dynamic ``batch_cap`` below the running batch-slot count and picks
    the *youngest* running batch-class request to preempt (youngest =
    least sunk work; seniors keep their progress).  The engine routes the
    preemption through the PR 8 spill path, so shed work resumes later
    via a host-tier page restore with **zero re-prefill chunks** —
    graceful degradation, not wasted compute;
  * **recovers** after ``recover_steps`` consecutive healthy steps —
    raises ``batch_cap`` one slot at a time back toward ``max_batch``
    (hysteresis: one shed cannot ping-pong with one raise);
  * **holds still** when the estimator has no fresh interactive samples
    (none yet, or none for ``recover_steps`` steps): no interactive
    traffic means nothing to protect, so batch keeps full throughput and
    a stale window can never pin the cap down after interactive drains.

Cooldown (``cooldown_steps``) spaces shed actions so one TTL spike sheds
one slot, not the whole batch tier at once.  All decisions read only
host-side metrics/scheduler state — nothing here touches the device.

Regression suite: tests/serving/test_governor.py; end-to-end acceptance:
scripts/trace_smoke.py (CI).
"""
from __future__ import annotations

import dataclasses

from repro.serving.scheduler import SLO_INTERACTIVE


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """TTL-governor tuning: the interactive p95 TTL target (seconds,
    engine clock — use a ``VirtualClock`` for deterministic replays), the
    estimator window / warm-up sample floor, the shed cooldown, the
    healthy-streak length before the batch cap recovers a slot, and the
    floor the cap never sheds below."""
    ttl_target_s: float
    window: int = 32
    min_samples: int = 8
    cooldown_steps: int = 4
    recover_steps: int = 12
    min_batch_slots: int = 0


class TTLGovernor:
    """Per-step TTL feedback controller over the scheduler's dynamic
    ``batch_cap`` (see module docstring for the shed / recover / hold
    policy).  The engine owns the actual preemption; ``step`` only
    returns the victim rid."""

    def __init__(self, cfg: GovernorConfig, max_batch: int):
        assert cfg.ttl_target_s > 0, cfg
        assert 0 <= cfg.min_batch_slots <= max_batch, cfg
        self.cfg = cfg
        self.max_batch = max_batch
        self.sheds = 0                 # batch slots preempted-to-spill
        self.cap_raises = 0            # recovery steps of the cap
        self._steps = 0
        self._last_action = -10**9
        self._healthy_streak = 0
        self._stale_steps = 0
        self._last_seen = 0

    def step(self, metrics, sched, batch_rids: list[int]) -> int | None:
        """One control decision.  ``batch_rids`` are the currently
        *decoding* batch-class requests, youngest first (the shed
        order).  Returns the rid to preempt-to-spill this step, or None;
        adjusts ``sched.batch_cap`` either way."""
        self._steps += 1
        cfg = self.cfg
        seen = metrics.class_samples(SLO_INTERACTIVE)
        self._stale_steps = 0 if seen > self._last_seen \
            else self._stale_steps + 1
        self._last_seen = seen
        p95 = metrics.recent_ttl_p95(SLO_INTERACTIVE, window=cfg.window,
                                     min_samples=cfg.min_samples)
        # stale estimator = interactive stopped producing tokens; its old
        # samples must not keep batch throttled (the no-thrash contract)
        healthy = (p95 is None or p95 <= cfg.ttl_target_s
                   or self._stale_steps >= cfg.recover_steps)
        if not healthy:
            self._healthy_streak = 0
            if self._steps - self._last_action < cfg.cooldown_steps:
                return None
            self._last_action = self._steps
            n_batch = len(batch_rids)
            sched.batch_cap = max(cfg.min_batch_slots,
                                  min(sched.batch_cap, n_batch) - 1)
            if n_batch > cfg.min_batch_slots:
                self.sheds += 1
                return batch_rids[0]
            return None
        self._healthy_streak += 1
        if (self._healthy_streak >= cfg.recover_steps
                and sched.batch_cap < self.max_batch):
            sched.batch_cap += 1
            self.cap_raises += 1
            self._healthy_streak = 0
        return None
