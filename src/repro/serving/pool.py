"""Shared-pool paged KV cache: the refcounted block (page) allocator.

The paper's premise is that DRAM reads for long KV histories cap interactive
decode — yet a fixed per-slot cache (`core/kvcache.cache_capacity`) reserves
worst-case HBM for *every* slot, so one multi-million-token request's
capacity is multiplied by ``max_batch`` whether or not the other slots need
it.  The paged pool replaces that with one shared plane of fixed-size
**pages** plus a per-request block table:

  * K/V live in pool planes ``[L, n_blocks, Kh, block_s, hsz]`` — page ``p``
    of a request holds its global positions ``[i*block_s, (i+1)*block_s)``
    for logical page index ``i`` (core/kvcache.py documents the layout and
    its KVP sharding; the Pallas kernels index the physical page through a
    scalar-prefetched ``[B, max_pages]`` table).
  * ``BlockAllocator`` (this module) owns which physical page belongs to
    which request: pure python, jax-free, so its invariants are
    property-testable (tests/serving/test_pool_props.py) and the scheduler
    can consult the **global** free-page count for admission instead of the
    per-slot capacity gate.

Prefix sharing (refcounts + copy-on-write)
------------------------------------------
Pages are **refcounted**: ``share()`` maps another request's live pages into
a new request's table (the scheduler's prefix index matches a new prompt
against committed prefixes, so same-system-prompt traffic pays its prefix
pages once), ``release()`` decrefs and a page returns to the FIFO free list
only at refcount zero, and ``cow()`` gives a holder a fresh *exclusive* page
for one logical index before its first divergent append — a page with
refcount > 1 is never written (the engine/device copy happens before the
write, through the exclusive page ``cow`` hands back).  Capacity accounting
is in **unique** pages: a page shared by five requests occupies one page of
HBM, so ``used_count``/``free_count`` (and through them the scheduler's
admission oracle) never double-charge a shared prefix.  Every page also
carries a **generation** stamp bumped each time it leaves the free list, so
the prefix index can detect stale entries whose pages were recycled.

Page 0 is reserved as the *sink* page: idle engine slots keep zeroed block
tables, so the decode step's unconditional per-row KV append lands in page 0
instead of corrupting a live request's page.  The allocator therefore hands
out pages ``1 .. n_blocks-1`` only.

Preemption releases a request's pages **copy-free**: the pages go back on
the free list (or stay alive under a sharer's refcount) and the request
re-prefills on resume (the engine already recomputes preempted context —
serving/engine.py).
"""
from __future__ import annotations

from collections import deque


def pages_for(length: int, block_s: int) -> int:
    """Pages needed to hold ``length`` committed cache positions."""
    return -(-max(length, 0) // block_s)


class BlockAllocator:
    """Refcounted free-list allocator for the shared KV page pool.

    ``n_blocks`` counts *all* pool planes including the reserved sink page 0;
    ``capacity`` (= ``n_blocks - 1``) pages are allocatable.  Pages are
    handed out in FIFO free-list order — deterministic, so engine runs
    replay exactly.  Per-request page lists keep allocation order, i.e.
    ``pages(rid)[i]`` is the physical page of logical page ``i``; the same
    physical page may appear in several requests' lists (prefix sharing),
    in which case its refcount equals its multiplicity across lists and it
    is charged to the pool once.
    """

    SINK = 0                              # reserved idle-row append target

    def __init__(self, n_blocks: int, block_s: int):
        assert n_blocks >= 2, "pool needs the sink page plus >= 1 real page"
        assert block_s > 0
        self.n_blocks = n_blocks
        self.block_s = block_s
        self._free: deque[int] = deque(range(1, n_blocks))
        self._pages: dict[int, list[int]] = {}
        self._refs: list[int] = [0] * n_blocks
        self._gen: list[int] = [0] * n_blocks
        self.peak_in_use = 0
        self.pages_shared_peak = 0

    # ------------------------------------------------------------ queries
    @property
    def capacity(self) -> int:
        """Allocatable page count (pool minus the reserved sink page)."""
        return self.n_blocks - 1

    @property
    def free_count(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    @property
    def used_count(self) -> int:
        """*Unique* pages currently owned by requests — a shared prefix
        page counts once however many tables map it."""
        return self.capacity - len(self._free)

    def pages(self, rid: int) -> list[int]:
        """Physical pages owned by ``rid`` in logical-page order."""
        return self._pages.get(rid, [])

    def pages_for(self, length: int) -> int:
        """Pages needed for ``length`` positions at this pool's page size."""
        return pages_for(length, self.block_s)

    def refcount(self, page: int) -> int:
        """How many request tables currently map ``page`` (0 = free)."""
        return self._refs[page]

    def generation(self, page: int) -> int:
        """Allocation-generation stamp of ``page`` — bumped each time the
        page leaves the free list, so a (page, generation) pair uniquely
        names one tenancy (the prefix index's staleness check)."""
        return self._gen[page]

    def shared_count(self) -> int:
        """Pages currently mapped by more than one request table."""
        return sum(1 for r in self._refs if r > 1)

    # ---------------------------------------------------------- mutation
    def _take(self) -> int:
        page = self._free.popleft()
        self._refs[page] = 1
        self._gen[page] += 1
        return page

    def _note_peaks(self) -> None:
        self.peak_in_use = max(self.peak_in_use, self.used_count)
        self.pages_shared_peak = max(self.pages_shared_peak,
                                     self.shared_count())

    def alloc(self, rid: int, n: int) -> list[int] | None:
        """Grant ``n`` fresh pages to (new) request ``rid``.

        Returns the page list, or None (allocator untouched) when fewer
        than ``n`` pages are free.  ``rid`` must not already hold pages."""
        assert rid not in self._pages, f"rid {rid} already holds pages"
        if n > len(self._free):
            return None
        got = [self._take() for _ in range(n)]
        self._pages[rid] = got
        self._note_peaks()
        return list(got)

    def extend(self, rid: int, n: int) -> list[int] | None:
        """Grant ``n`` more pages to ``rid`` (decode growth / chunked-prefill
        extension).  Returns only the *new* pages, or None (allocator
        untouched) when fewer than ``n`` are free."""
        assert rid in self._pages, f"rid {rid} holds no pages"
        if n > len(self._free):
            return None
        got = [self._take() for _ in range(n)]
        self._pages[rid].extend(got)
        self._note_peaks()
        return got

    def share(self, rid: int, phys_pages: list[int]) -> list[int]:
        """Map existing **live** pages into (new) request ``rid``'s table —
        the prefix-sharing entry point.

        Each page's refcount is incremented; no page moves and no free page
        is consumed (``free_count`` is untouched — shared prefixes are not
        double-charged).  ``phys_pages`` become ``rid``'s leading logical
        pages in order; follow with ``extend`` for the unshared suffix and
        ``cow`` for a trailing partial page.  Sharing a free or sink page
        asserts — the prefix index must validate entries (refcount +
        generation) before handing pages here."""
        assert rid not in self._pages, f"rid {rid} already holds pages"
        for p in phys_pages:
            assert p != self.SINK, "sharing the sink page"
            assert self._refs[p] > 0, f"sharing free page {p}"
        for p in phys_pages:
            self._refs[p] += 1
        self._pages[rid] = list(phys_pages)
        self._note_peaks()
        return list(phys_pages)

    def cow(self, rid: int, logical: int) -> tuple[int, int] | None:
        """Make ``rid``'s logical page ``logical`` exclusive before a write
        (copy-on-write).

        Returns ``(old_phys, new_phys)``: when the page is already exclusive
        (refcount 1) it is returned unchanged (``old == new``, nothing
        allocated); when shared, a fresh page is taken from the free list,
        installed at ``logical`` in ``rid``'s table, and the old page's
        refcount is decremented — the *caller* copies whatever committed
        rows the old page held into ``new`` before writing (the allocator
        never touches page contents; a page with refcount > 1 is never
        mutated).  Returns None (allocator untouched) when the page is
        shared but the free list is empty."""
        old = self._pages[rid][logical]
        if self._refs[old] == 1:
            return (old, old)
        if not self._free:
            return None
        new = self._take()
        self._pages[rid][logical] = new
        self._refs[old] -= 1
        self._note_peaks()
        return (old, new)

    def release(self, rid: int) -> int:
        """Decref all of ``rid``'s pages (retirement or preemption —
        copy-free); pages reaching refcount zero return to the FIFO free
        list.  Returns how many pages actually became free (shared pages a
        survivor still maps stay live and are not counted)."""
        got = self._pages.pop(rid, [])
        freed = 0
        for p in got:
            assert self._refs[p] > 0, f"releasing free page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                freed += 1
        return freed

    def free(self, rid: int) -> int:
        """Alias of ``release`` (the pre-refcount name, kept for callers)."""
        return self.release(rid)

    # -------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert page conservation under refcounts (the property suite
        calls this after every simulated operation): unique owned pages +
        free pages == capacity, every page's refcount equals its
        multiplicity across request tables, no page is both free and owned,
        and the sink page is never handed out."""
        mult: dict[int, int] = {}
        for pages in self._pages.values():
            for p in pages:
                mult[p] = mult.get(p, 0) + 1
        free = list(self._free)
        assert len(free) == len(set(free)), "free-list duplicates"
        assert not set(free) & set(mult), "page both free and owned"
        assert sorted(set(mult) | set(free)) == list(range(1, self.n_blocks)), \
            f"page conservation violated: owned {sorted(mult)} free {sorted(free)}"
        assert len(mult) + len(free) == self.capacity
        for p in range(self.n_blocks):
            assert self._refs[p] >= 0, f"negative refcount on page {p}"
            assert self._refs[p] == mult.get(p, 0), \
                f"page {p} refcount {self._refs[p]} != multiplicity {mult.get(p, 0)}"
        assert self.SINK not in mult, "sink page handed out"
        assert self._refs[self.SINK] == 0
        assert self.free_count == self.capacity - len(mult)
