"""Shared-pool paged KV cache: the block (page) allocator.

The paper's premise is that DRAM reads for long KV histories cap interactive
decode — yet a fixed per-slot cache (`core/kvcache.cache_capacity`) reserves
worst-case HBM for *every* slot, so one multi-million-token request's
capacity is multiplied by ``max_batch`` whether or not the other slots need
it.  The paged pool replaces that with one shared plane of fixed-size
**pages** plus a per-request block table:

  * K/V live in pool planes ``[L, n_blocks, Kh, block_s, hsz]`` — page ``p``
    of a request holds its global positions ``[i*block_s, (i+1)*block_s)``
    for logical page index ``i`` (core/kvcache.py documents the layout and
    its KVP sharding; the Pallas kernels index the physical page through a
    scalar-prefetched ``[B, max_pages]`` table).
  * ``BlockAllocator`` (this module) owns which physical page belongs to
    which request: pure python, jax-free, so its invariants are
    property-testable (tests/serving/test_pool_props.py) and the scheduler
    can consult the **global** free-page count for admission instead of the
    per-slot capacity gate.

Page 0 is reserved as the *sink* page: idle engine slots keep zeroed block
tables, so the decode step's unconditional per-row KV append lands in page 0
instead of corrupting a live request's page.  The allocator therefore hands
out pages ``1 .. n_blocks-1`` only.

Preemption releases a request's pages **copy-free**: the pages go back on
the free list and the request re-prefills on resume (the engine already
recomputes preempted context — serving/engine.py).
"""
from __future__ import annotations

from collections import deque


def pages_for(length: int, block_s: int) -> int:
    """Pages needed to hold ``length`` committed cache positions."""
    return -(-max(length, 0) // block_s)


class BlockAllocator:
    """Free-list allocator for the shared KV page pool (pure python).

    ``n_blocks`` counts *all* pool planes including the reserved sink page 0;
    ``capacity`` (= ``n_blocks - 1``) pages are allocatable.  Pages are
    handed out in FIFO free-list order — deterministic, so engine runs
    replay exactly.  Per-request page lists keep allocation order, i.e.
    ``pages(rid)[i]`` is the physical page of logical page ``i``.
    """

    SINK = 0                              # reserved idle-row append target

    def __init__(self, n_blocks: int, block_s: int):
        assert n_blocks >= 2, "pool needs the sink page plus >= 1 real page"
        assert block_s > 0
        self.n_blocks = n_blocks
        self.block_s = block_s
        self._free: deque[int] = deque(range(1, n_blocks))
        self._pages: dict[int, list[int]] = {}
        self.peak_in_use = 0

    # ------------------------------------------------------------ queries
    @property
    def capacity(self) -> int:
        """Allocatable page count (pool minus the reserved sink page)."""
        return self.n_blocks - 1

    @property
    def free_count(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    @property
    def used_count(self) -> int:
        """Pages currently owned by requests."""
        return self.capacity - len(self._free)

    def pages(self, rid: int) -> list[int]:
        """Physical pages owned by ``rid`` in logical-page order."""
        return self._pages.get(rid, [])

    def pages_for(self, length: int) -> int:
        """Pages needed for ``length`` positions at this pool's page size."""
        return pages_for(length, self.block_s)

    # ---------------------------------------------------------- mutation
    def alloc(self, rid: int, n: int) -> list[int] | None:
        """Grant ``n`` fresh pages to (new) request ``rid``.

        Returns the page list, or None (allocator untouched) when fewer
        than ``n`` pages are free.  ``rid`` must not already hold pages."""
        assert rid not in self._pages, f"rid {rid} already holds pages"
        if n > len(self._free):
            return None
        got = [self._free.popleft() for _ in range(n)]
        self._pages[rid] = got
        self.peak_in_use = max(self.peak_in_use, self.used_count)
        return list(got)

    def extend(self, rid: int, n: int) -> list[int] | None:
        """Grant ``n`` more pages to ``rid`` (decode growth / chunked-prefill
        extension).  Returns only the *new* pages, or None (allocator
        untouched) when fewer than ``n`` are free."""
        assert rid in self._pages, f"rid {rid} holds no pages"
        if n > len(self._free):
            return None
        got = [self._free.popleft() for _ in range(n)]
        self._pages[rid].extend(got)
        self.peak_in_use = max(self.peak_in_use, self.used_count)
        return got

    def free(self, rid: int) -> int:
        """Release all of ``rid``'s pages back to the free list (retirement
        or preemption — copy-free) and return how many were released."""
        got = self._pages.pop(rid, [])
        self._free.extend(got)
        return len(got)

    # -------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert page conservation and exclusive ownership (the property
        suite calls this after every simulated operation)."""
        owned = [p for pages in self._pages.values() for p in pages]
        allp = owned + list(self._free)
        assert len(allp) == len(set(allp)), "page double-assignment"
        assert sorted(allp) == list(range(1, self.n_blocks)), \
            f"page conservation violated: {sorted(allp)}"
        assert self.SINK not in owned, "sink page handed out"
        assert self.free_count == self.capacity - sum(
            len(p) for p in self._pages.values())
