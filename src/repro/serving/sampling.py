"""On-device token sampling: the fused epilogue over the lm_head logits.

``sample_tokens`` is the device half — a pure-jnp epilogue
``build_serve_step`` fuses after the (optionally w8a16) lm_head matmul, so
sampled tokens never leave the device except at scheduling boundaries (the
engine's single ``[B, window]`` transfer per decode window).
``sample_oracle`` is the host numpy reference the test suite pins it
against bit-exactly (tests/serving/test_sampling.py).

Semantics (per batch row, fully vectorized — every row carries its own
``(temp, top_k, top_p, seed, idx)``, so one batch mixes greedy and sampled
requests freely):

  * ``temp <= 0``    — greedy: plain argmax over the (vocab-masked)
    logits; bit-identical to the pre-sampling engine's device argmax.
  * ``temp > 0``     — Gumbel-max categorical sample over
    ``logits / temp`` restricted by the top-k and/or top-p masks.
  * top-k (``0 < k < V``) keeps entries >= the k-th largest scaled logit
    (ties at the threshold all stay in).
  * top-p (``0 < p < 1``) keeps the smallest nucleus of
    highest-probability tokens whose *preceding* cumulative probability is
    ``< p`` (the most probable token always stays in).

Randomness: row ``b`` draws its Gumbel noise from
``jax.random.fold_in(jax.random.PRNGKey(seed[b]), idx[b])`` where ``idx``
counts the tokens already sampled for that request.  Every request's token
stream is therefore a pure function of (prompt, params, per-request seed) —
independent of batch composition, slot assignment, decode-window size,
preemption and restore, which is what makes the engine's
``--decode-window N`` streams bit-identical to ``N=1``.  The oracle reuses
the same jax PRNG stream (the noise *is* the spec); the
masking/temperature/argmax decision math is reimplemented independently in
numpy float32.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["SAMPLING_KINDS", "SamplingParams", "request_seed",
           "gumbel_noise", "sample_tokens", "sample_oracle"]

SAMPLING_KINDS = ("greedy", "temperature", "top_k", "top_p")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request (or engine-default) sampling policy.

    ``kind`` picks the decision rule (``SAMPLING_KINDS``); ``temperature``
    applies to every non-greedy kind; ``top_k``/``top_p`` only to their
    kinds.  ``seed`` is the base PRNG seed — the engine decorrelates
    requests sharing one ``SamplingParams`` via ``request_seed``.
    """
    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Fail fast on out-of-domain knobs (the engine constructs device
        leaves from these values; a bad row would sample garbage)."""
        if self.kind not in SAMPLING_KINDS:
            raise ValueError(f"kind {self.kind!r} not in {SAMPLING_KINDS}")
        if self.kind != "greedy" and self.temperature <= 0.0:
            raise ValueError("non-greedy sampling needs temperature > 0 "
                             f"(got {self.temperature}); use kind='greedy' "
                             "for argmax")
        if self.kind == "top_k" and self.top_k < 1:
            raise ValueError(f"top_k kind needs top_k >= 1 ({self.top_k})")
        if self.kind == "top_p" and not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1] ({self.top_p})")

    def row(self) -> tuple[float, int, float]:
        """The ``(temp, top_k, top_p)`` device-leaf values for one request
        row.  Greedy encodes as ``temp = 0`` (the device's argmax branch);
        knobs foreign to ``kind`` collapse to their no-op values so the
        device never applies a mask the policy didn't ask for."""
        if self.kind == "greedy":
            return 0.0, 0, 1.0
        if self.kind == "temperature":
            return float(self.temperature), 0, 1.0
        if self.kind == "top_k":
            return float(self.temperature), int(self.top_k), 1.0
        return float(self.temperature), 0, float(self.top_p)


def request_seed(seed: int, rid: int) -> int:
    """Per-request PRNG seed derived from the policy ``seed`` and the
    request id: decorrelates requests that share one engine-level
    ``SamplingParams`` while staying a pure function of ``(seed, rid)`` —
    the same request replays the same stream across engine configurations,
    decode windows and restores."""
    return (int(seed) * 1_000_003 + int(rid) * 7_919) % (2**31 - 1)


def gumbel_noise(seed, idx, n: int):
    """Per-row Gumbel(0, 1) noise ``[B, n]``: row ``b`` uses the key
    ``fold_in(PRNGKey(seed[b]), idx[b])``.  Shared verbatim by the device
    sampler and the numpy oracle — the PRNG stream is part of the sampling
    spec, only the decision math differs between the two."""
    def row(s, i):
        key = jax.random.fold_in(jax.random.PRNGKey(s), i)
        return jax.random.gumbel(key, (n,), jnp.float32)
    return jax.vmap(row)(jnp.asarray(seed, jnp.uint32),
                         jnp.asarray(idx, jnp.int32))


def sample_tokens(logits, temp, top_k, top_p, seed, idx):
    """Fused on-device sampling epilogue (see module doc for semantics).

    ``logits`` ``[B, V]`` must already be vocab-masked (pad lanes at
    ``-1e30`` — both ``serve_step`` and the prefill ``forward`` emit
    logits that way); ``temp``/``top_p`` are ``[B]`` f32, ``top_k``/``idx``
    ``[B]`` int32, ``seed`` ``[B]`` uint32.  Returns ``[B]`` int32 tokens.
    Rows with ``temp <= 0`` return the plain argmax (bit-identical to the
    argmax-only epilogue)."""
    logits = logits.astype(jnp.float32)
    b, v = logits.shape
    greedy = temp <= 0.0
    t = jnp.where(greedy, 1.0, temp)
    scaled = logits / t[:, None]
    # top-k: keep entries >= the k-th largest (k outside (0, V) keeps all)
    k_eff = jnp.where((top_k > 0) & (top_k < v), top_k, v)
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, (k_eff - 1)[:, None], axis=-1)
    masked = jnp.where(scaled >= kth, scaled, -jnp.inf)
    # top-p nucleus over the (top-k-restricted) softmax: keep sorted
    # entries whose preceding cumulative probability is < p, then lift the
    # per-row probability threshold back to vocab order
    p_eff = jnp.where((top_p > 0.0) & (top_p < 1.0), top_p, 1.0)
    probs = jax.nn.softmax(masked, axis=-1)
    sp = jnp.sort(probs, axis=-1)[:, ::-1]
    before = jnp.cumsum(sp, axis=-1) - sp
    nkeep = jnp.maximum(jnp.sum(before < p_eff[:, None], axis=-1), 1)
    thresh = jnp.take_along_axis(sp, (nkeep - 1)[:, None], axis=-1)
    final = jnp.where(probs >= thresh, masked, -jnp.inf)
    g = gumbel_noise(seed, idx, v)
    sampled = jnp.argmax(final + g, axis=-1)
    out = jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled)
    return out.astype(jnp.int32)


def sample_oracle(logits, temp, top_k, top_p, seed, idx):
    """Host numpy reference for ``sample_tokens`` (bit-exact at a fixed
    seed).  Same arguments as numpy arrays; the Gumbel noise comes from
    the shared ``gumbel_noise`` stream (the PRNG is part of the spec), the
    temperature/top-k/top-p/argmax decision math is independent numpy
    float32."""
    logits = np.asarray(logits, np.float32)
    b, v = logits.shape
    g = np.asarray(gumbel_noise(seed, idx, v))
    out = np.zeros((b,), np.int32)
    for r in range(b):
        row = logits[r]
        if float(temp[r]) <= 0.0:
            out[r] = int(np.argmax(row))
            continue
        scaled = (row / np.float32(temp[r])).astype(np.float32)
        k = int(top_k[r])
        if 0 < k < v:
            kth = np.sort(scaled)[::-1][k - 1]
            masked = np.where(scaled >= kth, scaled, -np.inf)
        else:
            masked = scaled
        p = float(top_p[r])
        e = np.exp((masked - masked.max()).astype(np.float32))
        probs = (e / e.sum(dtype=np.float32)).astype(np.float32)
        if 0.0 < p < 1.0:
            sp = np.sort(probs)[::-1]
            before = (np.cumsum(sp, dtype=np.float32) - sp).astype(np.float32)
            nkeep = max(int(np.sum(before < np.float32(p))), 1)
            thresh = sp[nkeep - 1]
            final = np.where(probs >= thresh, masked, -np.inf)
        else:
            final = masked
        out[r] = int(np.argmax(final + g[r]))
    return out
