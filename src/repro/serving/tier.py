"""Host-side KV page store: the spill/restore tier under the device pool.

The paper's scarce resource is device HBM for multi-million-token KV
histories; serving/pool.py rations it, but until now the only responses to
pool pressure were "queue" or "preempt and recompute everything".  The
``HostPageStore`` adds the missing tier: page-granularity save/restore of
KV state in host memory, so

  * preemption **spills** a request's live pool pages (int8 payloads and
    f32 scale planes included — exact bytes, not a re-quantized copy)
    before the pool releases them, and resume becomes a block-table
    rebuild plus one H2D scatter with zero re-prefill chunks;
  * a retired request's pages can persist keyed by session id, so turn
    N+1 of a multi-turn conversation restores its history instead of
    re-prefilling it (``DecodeEngine`` session KV);
  * the PrefixIndex's host fp K/V blobs (PR 7 kept them forever) ride the
    same LRU so prefix-restore host memory is capped.

Integrity is never assumed: every stored page carries a CRC32 checksum
and a generation stamp, both verified before any byte is handed back — a
corrupt or stale entry is detected, dropped and reported, and the engine
falls back to the re-prefill path (graceful degradation, never divergent
tokens).  ``serving/faults.py`` injects the failure modes
deterministically so CI can prove that contract (scripts/chaos_smoke.py).

The store is layout-agnostic pure host python + numpy: an entry is a dict
of page-stacked planes with the page axis at position 1 (pool spills use
``[L, P, Kh, block_s, hsz]``; prefix blobs reshape their carry-buffer
layout the same way).  Capacity is counted in pages across all planes'
page axis; eviction is LRU over whole entries (sessions), mirroring the
device pool's accounting style so the property suite
(tests/serving/test_tier_props.py) can model it exactly.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict

import numpy as np

from repro.serving.faults import FaultPlan

__all__ = ["HostPageStore", "HostEntry"]


@dataclasses.dataclass
class HostEntry:
    """One stored KV snapshot: page-stacked planes + integrity metadata.

    ``planes`` maps plane name -> host array with the page axis at
    position 1; ``tokens`` is the token prefix the pages represent (the
    restore-applicability check); ``gen`` is the entry's generation stamp
    with ``page_gens[p]`` expected to equal it for every page — a
    mismatch means the page was recycled under us; ``sums[p]`` is the
    CRC32 over page ``p``'s bytes across all planes."""

    key: str
    tokens: tuple
    planes: dict[str, np.ndarray]
    n_pages: int
    gen: int
    page_gens: list[int]
    sums: list[int]


def _page_crc(planes: dict[str, np.ndarray], p: int) -> int:
    # chained CRC over every plane's page-p slice, in sorted plane order
    acc = 0
    for name in sorted(planes):
        acc = zlib.crc32(np.ascontiguousarray(planes[name][:, p]).tobytes(),
                         acc)
    return acc


class HostPageStore:
    """Capacity-bounded host KV store with checksums, generations and LRU.

    ``capacity_pages`` bounds the total page count across live entries;
    ``put`` evicts least-recently-used entries to make room (whole
    entries — a half-restored session is useless).  ``faults`` (a
    ``serving/faults.FaultPlan``) deterministically injects the tier's
    failure modes; with no plan the store is exact and loss-free.

    Counters (all monotonic): ``saves``/``restores``/``restores_failed``,
    ``checksum_mismatches`` (corrupt bytes), ``stale_generations``
    (recycled pages), ``evictions``/``evicted_pages`` (LRU),
    ``store_full`` (refused saves, genuine or injected).
    """

    def __init__(self, capacity_pages: int,
                 faults: FaultPlan | None = None):
        assert capacity_pages > 0, "host store needs >= 1 page"
        self.capacity = capacity_pages
        self._faults = (faults or FaultPlan()).injector()
        self._entries: "OrderedDict[str, HostEntry]" = OrderedDict()
        self._gen = 0
        self.pages_used = 0
        self.saves = 0
        self.restores = 0
        self.restores_failed = 0
        self.checksum_mismatches = 0
        self.stale_generations = 0
        self.evictions = 0
        self.evicted_pages = 0
        self.store_full = 0

    def __len__(self) -> int:
        return len(self._entries)

    def has(self, key: str) -> bool:
        """Whether an entry for ``key`` is currently live (no LRU touch,
        no fault draw, no integrity verification — a cheap existence
        probe; the restore itself may still fail)."""
        return key in self._entries

    def tokens(self, key: str) -> tuple | None:
        """The token prefix stored under ``key`` (None when absent) — the
        engine's restore-applicability check.  No LRU touch, no fault
        draw."""
        e = self._entries.get(key)
        return None if e is None else e.tokens

    # ----------------------------------------------------------- mutation
    def put(self, key: str, planes: dict, tokens=()) -> bool:
        """Save one snapshot under ``key`` (overwriting any previous one).

        ``planes`` must be non-empty arrays sharing the page axis (axis 1)
        extent; they are copied to host memory, stamped with a fresh
        generation, and checksummed per page.  Returns False — allocator
        untouched beyond counters — when the save is refused: injected
        ``store_full`` fault, or the entry alone exceeds capacity.
        Otherwise LRU entries are evicted until the entry fits."""
        assert planes, "empty snapshot"
        n_pages = {int(v.shape[1]) for v in planes.values()}
        assert len(n_pages) == 1, f"ragged page axes: {n_pages}"
        n = n_pages.pop()
        assert n > 0, "zero-page snapshot"
        if self._faults.draw("store_full"):
            self.store_full += 1
            return False
        self.drop(key)
        if n > self.capacity:
            self.store_full += 1
            return False
        while self.pages_used + n > self.capacity:
            old_key, old = next(iter(self._entries.items()))
            self._entries.pop(old_key)
            self.pages_used -= old.n_pages
            self.evictions += 1
            self.evicted_pages += old.n_pages
        host = {name: np.array(v, copy=True) for name, v in planes.items()}
        gen = self._gen
        self._gen += 1
        entry = HostEntry(key=key, tokens=tuple(int(t) for t in tokens),
                          planes=host, n_pages=n, gen=gen,
                          page_gens=[gen] * n,
                          sums=[_page_crc(host, p) for p in range(n)])
        if self._faults.draw("corrupt"):
            self._corrupt(entry)
        self._entries[key] = entry
        self.pages_used += n
        self.saves += 1
        return True

    def _corrupt(self, entry: HostEntry) -> None:
        # damage AFTER checksumming, so verification catches it: either a
        # byte flip in one page (checksum mismatch) or a bumped page
        # generation (stale-tenancy mismatch)
        p = self._faults.pick(entry.n_pages)
        if self._faults.pick(2) == 0:
            name = sorted(entry.planes)[0]
            arr = entry.planes[name]
            # the page slice is strided (page axis 1), so mutate a
            # contiguous copy and write it back — a view-reshape would
            # silently flip a throwaway buffer instead
            page = np.ascontiguousarray(arr[:, p])
            flat = page.view(np.uint8).reshape(-1)
            flat[self._faults.pick(flat.size)] ^= 0xFF
            arr[:, p] = page
        else:
            entry.page_gens[p] += 1

    def drop(self, key: str) -> bool:
        """Remove ``key``'s entry (no-op on absence); True when dropped."""
        e = self._entries.pop(key, None)
        if e is None:
            return False
        self.pages_used -= e.n_pages
        return True

    # ------------------------------------------------------------ restore
    def _verify(self, entry: HostEntry) -> str | None:
        for p in range(entry.n_pages):
            if entry.page_gens[p] != entry.gen:
                self.stale_generations += 1
                return "generation"
            if _page_crc(entry.planes, p) != entry.sums[p]:
                self.checksum_mismatches += 1
                return "checksum"
        return None

    def restore(self, key: str) -> tuple[dict | None, int, str | None]:
        """Fetch ``key``'s planes for an H2D restore, with fault draws.

        Returns ``(planes, delay_steps, why)``: on success planes is the
        stored dict, ``delay_steps`` how many engine steps the injected
        ``delay`` fault withholds them (0 normally), ``why`` None.  On
        failure planes is None and ``why`` one of ``"missing"`` (no
        entry), ``"injected"`` (restore_fail fault), ``"checksum"`` /
        ``"generation"`` (integrity verification — the entry is dropped so
        corrupt bytes can never be served later)."""
        entry = self._entries.get(key)
        if entry is None:
            return None, 0, "missing"
        if self._faults.draw("restore_fail"):
            self.restores_failed += 1
            return None, 0, "injected"
        why = self._verify(entry)
        if why is not None:
            self.restores_failed += 1
            self.drop(key)
            return None, 0, why
        delay = self._faults.plan.delay_steps \
            if self._faults.draw("delay") else 0
        self._entries.move_to_end(key)
        self.restores += 1
        return entry.planes, delay, None

    def fetch(self, key: str) -> dict | None:
        """Integrity-verified payload WITHOUT injected restore faults.

        The prefix-sharing admission path calls this up to three times per
        decision (fits / can_admit_now / reserve) and all three must agree,
        so only deterministic failures apply: a corrupt/stale entry is
        dropped (counted) and every subsequent call consistently misses.
        Touches LRU recency; does not count as a restore."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        if self._verify(entry) is not None:
            self.drop(key)
            return None
        self._entries.move_to_end(key)
        return entry.planes

    # -------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Counter snapshot (plus occupancy) for metrics summaries."""
        return {
            "host_pages_capacity": self.capacity,
            "host_pages_used": self.pages_used,
            "host_entries": len(self._entries),
            "host_saves": self.saves,
            "host_restores": self.restores,
            "restores_failed": self.restores_failed,
            "checksum_mismatches": self.checksum_mismatches,
            "stale_generations": self.stale_generations,
            "store_evictions": self.evictions,
            "store_full": self.store_full,
        }

    # --------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert the accounting the property suite pins: page usage
        equals the sum over entries, never exceeds capacity, and every
        healthy entry's checksums verify."""
        total = sum(e.n_pages for e in self._entries.values())
        assert total == self.pages_used, (total, self.pages_used)
        assert total <= self.capacity, (total, self.capacity)
        for e in self._entries.values():
            assert e.n_pages == next(iter(e.planes.values())).shape[1]
