"""Per-request lifecycle metrics for the serving engine.

Tracks, per request: queue wait (submit -> admission), TTFT (submit ->
first generated token, i.e. end of prefill) and the per-step TTL samples
(gap between consecutive generated tokens — the latency the paper holds
steady while batch size grows, PAPER.md §1).  ``summary()`` aggregates
p50/p95/mean across finished requests plus engine throughput.

The clock is injectable (any monotonic ``() -> float`` in seconds) so
tests can drive it deterministically; the default is
``time.monotonic``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    """Raw per-request timeline (seconds, engine clock)."""
    rid: int
    submit_t: float
    admit_t: float | None = None
    first_token_t: float | None = None
    last_token_t: float | None = None
    finish_t: float | None = None
    finish_reason: str | None = None
    n_tokens: int = 0
    n_preempts: int = 0
    # the host-tier split of n_preempts (serving/tier.py): spill = live
    # pages saved for a zero-re-prefill resume; drop = pages discarded
    # (no store / store refused), resume re-prefills
    n_preempt_spills: int = 0
    n_preempt_drops: int = 0
    ttl_samples: list[float] = dataclasses.field(default_factory=list)
    restore_samples: list[float] = dataclasses.field(default_factory=list)

    @property
    def queue_wait(self) -> float | None:
        """Seconds from submission to (first) slot admission."""
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def ttft(self) -> float | None:
        """Seconds from submission to the first generated token."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)


def _pct(vals, q) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q))


def _stats(vals) -> dict[str, float]:
    if not vals:
        return {"p50": 0.0, "p95": 0.0, "mean": 0.0, "n": 0}
    return {"p50": _pct(vals, 50), "p95": _pct(vals, 95),
            "mean": float(np.mean(vals)), "n": len(vals)}


class EngineMetrics:
    """Lifecycle-event collector the engine drives; pure host python."""

    # host-tier counter keys always present in summary() (zeros without a
    # host store), so bench/schema consumers never key-error
    TIER_COUNTERS = ("spills", "restores", "restores_failed",
                     "checksum_mismatches", "store_evictions",
                     "resume_reprefill_chunks")

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.requests: dict[int, RequestMetrics] = {}
        self.start_t = clock()
        self.counters: dict[str, int] = {k: 0 for k in self.TIER_COUNTERS}

    # ------------------------------------------------------------ events
    def on_submit(self, rid: int) -> None:
        """Request entered the engine (queue or direct admission)."""
        self.requests[rid] = RequestMetrics(rid=rid, submit_t=self.clock())

    def on_admit(self, rid: int) -> None:
        """Request placed into a slot (first admission only counts for
        queue-wait; re-admissions after preemption keep the original)."""
        m = self.requests[rid]
        if m.admit_t is None:
            m.admit_t = self.clock()

    def on_token(self, rid: int) -> None:
        """One token generated: records TTFT on the first, a TTL sample
        on each subsequent one."""
        m = self.requests[rid]
        now = self.clock()
        if m.first_token_t is None:
            m.first_token_t = now
        else:
            m.ttl_samples.append(now - m.last_token_t)
        m.last_token_t = now
        m.n_tokens += 1

    def on_preempt(self, rid: int, spilled: bool = False) -> None:
        """Request was preempted (slot released, requeued).  ``spilled``
        records whether its live pages made it into the host tier (resume
        restores, zero re-prefill) or were dropped (resume re-prefills)."""
        m = self.requests[rid]
        m.n_preempts += 1
        if spilled:
            m.n_preempt_spills += 1
        else:
            m.n_preempt_drops += 1

    def on_restore(self, rid: int, seconds: float) -> None:
        """One completed host->device restore for ``rid`` took
        ``seconds`` from admission to committed pages (the latency a slow
        host tier adds to TTFT — never to in-flight TTL)."""
        self.requests[rid].restore_samples.append(seconds)

    def bump(self, counter: str, n: int = 1) -> None:
        """Increment a summary counter (host-tier events and the like)."""
        self.counters[counter] = self.counters.get(counter, 0) + n

    def set_counter(self, counter: str, value: int) -> None:
        """Pin a summary counter to an absolute value (mirroring a
        monotonic store-side counter is idempotent this way)."""
        self.counters[counter] = int(value)

    def on_finish(self, rid: int, reason: str) -> None:
        """Request retired (eos | max_tokens | capacity | rejected)."""
        m = self.requests[rid]
        m.finish_t = self.clock()
        m.finish_reason = reason

    # ----------------------------------------------------------- summary
    def summary(self) -> dict:
        """Aggregate p50/p95/mean of TTFT / TTL / queue wait (seconds)
        over finished requests, plus token throughput since construction."""
        fin = [m for m in self.requests.values() if m.finish_t is not None]
        ttls = [s for m in fin for s in m.ttl_samples]
        toks = sum(m.n_tokens for m in fin)
        dt = max(self.clock() - self.start_t, 1e-9)
        return {
            "n_finished": len(fin),
            "n_tokens": toks,
            "throughput_tok_s": toks / dt,
            "ttft_s": _stats([m.ttft for m in fin if m.ttft is not None]),
            "ttl_s": _stats(ttls),
            "queue_wait_s": _stats([m.queue_wait for m in fin
                                    if m.queue_wait is not None]),
            "preempts": sum(m.n_preempts for m in fin),
            "preempt_spills": sum(m.n_preempt_spills for m in fin),
            "preempt_drops": sum(m.n_preempt_drops for m in fin),
            "restore_s": _stats([s for m in fin for s in m.restore_samples]),
            **{k: self.counters.get(k, 0) for k in self.TIER_COUNTERS},
            "finish_reasons": {r: sum(1 for m in fin if m.finish_reason == r)
                               for r in {m.finish_reason for m in fin}},
        }
