"""Per-request lifecycle metrics for the serving engine.

Tracks, per request: queue wait (submit -> admission), TTFT (submit ->
first generated token, i.e. end of prefill) and the per-step TTL samples
(gap between consecutive generated tokens — the latency the paper holds
steady while batch size grows, PAPER.md §1).  ``summary()`` aggregates
p50/p95/mean across finished requests plus engine throughput, split
per tenant and per SLO class when requests are tagged
(serving/workload.py traces tag every row).

The clock is injectable (any monotonic ``() -> float`` in seconds) so
tests can drive it deterministically; the default is ``time.monotonic``.
``VirtualClock`` is the deterministic alternative serving replays use: a
cost-model clock the engine advances by modeled per-step work, so two
runs of the same trace produce *identical* latency summaries — and so
shedding batch work genuinely lowers the modeled interactive TTL, which
is what gives the TTL governor (serving/governor.py) a load-responsive,
replayable signal.

``recent_ttl_p95`` is the governor's windowed estimator: p95 over the
last ``window`` TTL samples of one SLO class, None until ``min_samples``
accumulate (no interactive traffic -> no governor action, by design).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import numpy as np


class VirtualClock:
    """Deterministic cost-model clock for replayable serving runs.

    Wall clocks make every latency summary run-unique; a step counter is
    load-blind (all slots decode in lockstep, so per-request TTL would be
    a constant one step).  This clock models per-step time instead: the
    engine calls ``advance`` with the step's composition and modeled time
    moves by

        base_s * steps + decode_slot_s * decode_slots
                       + prefill_token_s * prefill_tokens

    so a heavily batched step *costs more modeled time* — shedding batch
    slots measurably lowers interactive TTL, deterministically.  The
    default coefficients are CPU-ish milliseconds; tests pass explicit
    ones to pin exact arithmetic."""

    def __init__(self, base_s: float = 1e-3, decode_slot_s: float = 5e-4,
                 prefill_token_s: float = 1e-4):
        self.base_s = base_s
        self.decode_slot_s = decode_slot_s
        self.prefill_token_s = prefill_token_s
        self._t = 0.0

    def __call__(self) -> float:
        return self._t

    def advance(self, *, steps: int = 0, decode_slots: int = 0,
                prefill_tokens: int = 0) -> None:
        """Advance modeled time by one tranche of engine work."""
        self._t += (self.base_s * steps
                    + self.decode_slot_s * decode_slots
                    + self.prefill_token_s * prefill_tokens)


@dataclasses.dataclass
class RequestMetrics:
    """Raw per-request timeline (seconds, engine clock)."""
    rid: int
    submit_t: float
    tenant: str = "default"
    slo_class: str = "interactive"
    admit_t: float | None = None
    first_token_t: float | None = None
    last_token_t: float | None = None
    finish_t: float | None = None
    finish_reason: str | None = None
    n_tokens: int = 0
    n_preempts: int = 0
    # the host-tier split of n_preempts (serving/tier.py): spill = live
    # pages saved for a zero-re-prefill resume; drop = pages discarded
    # (no store / store refused), resume re-prefills
    n_preempt_spills: int = 0
    n_preempt_drops: int = 0
    ttl_samples: list[float] = dataclasses.field(default_factory=list)
    restore_samples: list[float] = dataclasses.field(default_factory=list)

    @property
    def queue_wait(self) -> float | None:
        """Seconds from submission to (first) slot admission."""
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def ttft(self) -> float | None:
        """Seconds from submission to the first generated token."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)


def _pct(vals, q) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q))

def _stats(vals) -> dict[str, float]:
    if not vals:
        return {"p50": 0.0, "p95": 0.0, "mean": 0.0, "n": 0}
    return {"p50": _pct(vals, 50), "p95": _pct(vals, 95),
            "mean": float(np.mean(vals)), "n": len(vals)}


class EngineMetrics:
    """Lifecycle-event collector the engine drives; pure host python."""

    # host-tier counter keys always present in summary() (zeros without a
    # host store), so bench/schema consumers never key-error
    TIER_COUNTERS = ("spills", "restores", "restores_failed",
                     "checksum_mismatches", "store_evictions",
                     "resume_reprefill_chunks")
    # TTL-governor counters (serving/governor.py), same always-present
    # contract: batch slots shed to spill, and cap recoveries
    GOVERNOR_COUNTERS = ("governor_sheds", "governor_cap_raises")

    def __init__(self, clock=time.monotonic, ttl_target_s: float | None = None,
                 recent_window: int = 256):
        self.clock = clock
        self.ttl_target_s = ttl_target_s
        self.requests: dict[int, RequestMetrics] = {}
        self.start_t = clock()
        self.counters: dict[str, int] = {
            k: 0 for k in self.TIER_COUNTERS + self.GOVERNOR_COUNTERS}
        # rolling (slo_class, ttl_sample) ring for the governor's windowed
        # estimator; bounded so a long run never grows it
        self._recent: deque[tuple[str, float]] = deque(maxlen=recent_window)
        self._class_samples: dict[str, int] = {}

    # ------------------------------------------------------------ events
    def on_submit(self, rid: int, tenant: str = "default",
                  slo_class: str = "interactive") -> None:
        """Request entered the engine (queue or direct admission),
        tagged with its tenant and SLO class for the per-tenant /
        per-class summary splits."""
        self.requests[rid] = RequestMetrics(rid=rid, submit_t=self.clock(),
                                            tenant=tenant,
                                            slo_class=slo_class)

    def on_admit(self, rid: int) -> None:
        """Request placed into a slot (first admission only counts for
        queue-wait; re-admissions after preemption keep the original)."""
        m = self.requests[rid]
        if m.admit_t is None:
            m.admit_t = self.clock()

    def on_token(self, rid: int, at: float | None = None) -> None:
        """One token generated: records TTFT on the first, a TTL sample
        on each subsequent one (also fed to the per-class recent ring).

        ``at`` overrides the clock read for windowed decode
        (``--decode-window N``): the engine replays a window's N tokens
        after one device call, attributing each an in-window timestamp
        (VirtualClock ticks per in-window step, or wall-clock window time
        / N) so TTL percentiles — and the governor's p95 control loop —
        stay per-token-meaningful instead of seeing N-1 zero gaps and one
        window-sized spike."""
        m = self.requests[rid]
        now = self.clock() if at is None else at
        if m.first_token_t is None:
            m.first_token_t = now
        else:
            ttl = now - m.last_token_t
            m.ttl_samples.append(ttl)
            self._recent.append((m.slo_class, ttl))
            self._class_samples[m.slo_class] = \
                self._class_samples.get(m.slo_class, 0) + 1
        m.last_token_t = now
        m.n_tokens += 1

    def on_preempt(self, rid: int, spilled: bool = False) -> None:
        """Request was preempted (slot released, requeued).  ``spilled``
        records whether its live pages made it into the host tier (resume
        restores, zero re-prefill) or were dropped (resume re-prefills)."""
        m = self.requests[rid]
        m.n_preempts += 1
        if spilled:
            m.n_preempt_spills += 1
        else:
            m.n_preempt_drops += 1

    def on_restore(self, rid: int, seconds: float) -> None:
        """One completed host->device restore for ``rid`` took
        ``seconds`` from admission to committed pages (the latency a slow
        host tier adds to TTFT — never to in-flight TTL)."""
        self.requests[rid].restore_samples.append(seconds)

    def bump(self, counter: str, n: int = 1) -> None:
        """Increment a summary counter (host-tier events and the like)."""
        self.counters[counter] = self.counters.get(counter, 0) + n

    def set_counter(self, counter: str, value: int) -> None:
        """Pin a summary counter to an absolute value (mirroring a
        monotonic store-side counter is idempotent this way)."""
        self.counters[counter] = int(value)

    def on_finish(self, rid: int, reason: str) -> None:
        """Request retired (eos | max_tokens | capacity | rejected)."""
        m = self.requests[rid]
        m.finish_t = self.clock()
        m.finish_reason = reason

    # --------------------------------------------------- TTL estimation
    def class_samples(self, slo_class: str) -> int:
        """Total TTL samples ever recorded for ``slo_class`` — the
        governor's freshness signal (an unchanged count means that class
        produced no tokens lately, so its stale window must not keep the
        batch cap pinned down)."""
        return self._class_samples.get(slo_class, 0)

    def recent_ttl_p95(self, slo_class: str = "interactive",
                       window: int | None = None,
                       min_samples: int = 8) -> float | None:
        """p95 TTL over the last ``window`` recent samples of one SLO
        class (None until ``min_samples`` accumulate) — the per-step
        estimator the TTL governor steers on."""
        vals = [s for cls, s in self._recent if cls == slo_class]
        if window is not None:
            vals = vals[-window:]
        if len(vals) < min_samples:
            return None
        return _pct(vals, 95)

    # ----------------------------------------------------------- summary
    def _good_tokens(self, m: RequestMetrics) -> int:
        """Tokens of ``m`` that count toward goodput: all of them for
        batch work (throughput-bound) or when no TTL target is set;
        interactive tokens count when their TTL sample met the target
        (the first token always does — TTFT has no target here)."""
        if self.ttl_target_s is None or m.slo_class != "interactive":
            return m.n_tokens
        ok = sum(1 for s in m.ttl_samples if s <= self.ttl_target_s)
        return ok + (1 if m.first_token_t is not None else 0)

    def _agg(self, fin: list[RequestMetrics], dt: float) -> dict:
        """Latency/goodput aggregate over one subset of finished
        requests (the whole run, one tenant, or one SLO class)."""
        ttls = [s for m in fin for s in m.ttl_samples]
        toks = sum(m.n_tokens for m in fin)
        misses = (0 if self.ttl_target_s is None else
                  sum(1 for m in fin if m.slo_class == "interactive"
                      for s in m.ttl_samples if s > self.ttl_target_s))
        inter_ttls = sum(len(m.ttl_samples) for m in fin
                         if m.slo_class == "interactive")
        return {
            "n_finished": len(fin),
            "n_tokens": toks,
            "throughput_tok_s": toks / dt,
            "goodput_tok_s": sum(self._good_tokens(m) for m in fin) / dt,
            "ttl_target_miss_rate": misses / max(inter_ttls, 1),
            "ttft_s": _stats([m.ttft for m in fin if m.ttft is not None]),
            "ttl_s": _stats(ttls),
            "queue_wait_s": _stats([m.queue_wait for m in fin
                                    if m.queue_wait is not None]),
        }

    def summary(self) -> dict:
        """Aggregate p50/p95/mean of TTFT / TTL / queue wait (seconds)
        over finished requests, token throughput and SLO goodput since
        construction, per-tenant and per-SLO-class splits of the same,
        the recent per-class TTL p95 the governor last saw, and the
        tier/governor counters."""
        fin = [m for m in self.requests.values() if m.finish_t is not None]
        dt = max(self.clock() - self.start_t, 1e-9)
        out = self._agg(fin, dt)
        out.update({
            "ttl_target_s": self.ttl_target_s or 0.0,
            "ttl_recent_p95_s": {
                cls: (self.recent_ttl_p95(cls, min_samples=1) or 0.0)
                for cls in ("interactive", "batch")},
            "per_tenant": {t: self._agg([m for m in fin if m.tenant == t],
                                        dt)
                           for t in sorted({m.tenant for m in fin})},
            "per_class": {c: self._agg([m for m in fin if m.slo_class == c],
                                       dt)
                          for c in sorted({m.slo_class for m in fin})},
            "preempts": sum(m.n_preempts for m in fin),
            "preempt_spills": sum(m.n_preempt_spills for m in fin),
            "preempt_drops": sum(m.n_preempt_drops for m in fin),
            "restore_s": _stats([s for m in fin for s in m.restore_samples]),
            **{k: self.counters.get(k, 0)
               for k in self.TIER_COUNTERS + self.GOVERNOR_COUNTERS},
            "finish_reasons": {r: sum(1 for m in fin if m.finish_reason == r)
                               for r in {m.finish_reason for m in fin}},
        })
        return out
