"""Per-request lifecycle metrics for the serving engine.

Tracks, per request: queue wait (submit -> admission), TTFT (submit ->
first generated token, i.e. end of prefill) and the per-step TTL samples
(gap between consecutive generated tokens — the latency the paper holds
steady while batch size grows, PAPER.md §1).  ``summary()`` aggregates
p50/p95/mean across finished requests plus engine throughput.

The clock is injectable (any monotonic ``() -> float`` in seconds) so
tests can drive it deterministically; the default is
``time.monotonic``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class RequestMetrics:
    """Raw per-request timeline (seconds, engine clock)."""
    rid: int
    submit_t: float
    admit_t: float | None = None
    first_token_t: float | None = None
    last_token_t: float | None = None
    finish_t: float | None = None
    finish_reason: str | None = None
    n_tokens: int = 0
    n_preempts: int = 0
    ttl_samples: list[float] = dataclasses.field(default_factory=list)

    @property
    def queue_wait(self) -> float | None:
        """Seconds from submission to (first) slot admission."""
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def ttft(self) -> float | None:
        """Seconds from submission to the first generated token."""
        return (None if self.first_token_t is None
                else self.first_token_t - self.submit_t)


def _pct(vals, q) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q))


def _stats(vals) -> dict[str, float]:
    if not vals:
        return {"p50": 0.0, "p95": 0.0, "mean": 0.0, "n": 0}
    return {"p50": _pct(vals, 50), "p95": _pct(vals, 95),
            "mean": float(np.mean(vals)), "n": len(vals)}


class EngineMetrics:
    """Lifecycle-event collector the engine drives; pure host python."""

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.requests: dict[int, RequestMetrics] = {}
        self.start_t = clock()

    # ------------------------------------------------------------ events
    def on_submit(self, rid: int) -> None:
        """Request entered the engine (queue or direct admission)."""
        self.requests[rid] = RequestMetrics(rid=rid, submit_t=self.clock())

    def on_admit(self, rid: int) -> None:
        """Request placed into a slot (first admission only counts for
        queue-wait; re-admissions after preemption keep the original)."""
        m = self.requests[rid]
        if m.admit_t is None:
            m.admit_t = self.clock()

    def on_token(self, rid: int) -> None:
        """One token generated: records TTFT on the first, a TTL sample
        on each subsequent one."""
        m = self.requests[rid]
        now = self.clock()
        if m.first_token_t is None:
            m.first_token_t = now
        else:
            m.ttl_samples.append(now - m.last_token_t)
        m.last_token_t = now
        m.n_tokens += 1

    def on_preempt(self, rid: int) -> None:
        """Request was preempted (slot released, requeued)."""
        self.requests[rid].n_preempts += 1

    def on_finish(self, rid: int, reason: str) -> None:
        """Request retired (eos | max_tokens | capacity | rejected)."""
        m = self.requests[rid]
        m.finish_t = self.clock()
        m.finish_reason = reason

    # ----------------------------------------------------------- summary
    def summary(self) -> dict:
        """Aggregate p50/p95/mean of TTFT / TTL / queue wait (seconds)
        over finished requests, plus token throughput since construction."""
        fin = [m for m in self.requests.values() if m.finish_t is not None]
        ttls = [s for m in fin for s in m.ttl_samples]
        toks = sum(m.n_tokens for m in fin)
        dt = max(self.clock() - self.start_t, 1e-9)
        return {
            "n_finished": len(fin),
            "n_tokens": toks,
            "throughput_tok_s": toks / dt,
            "ttft_s": _stats([m.ttft for m in fin if m.ttft is not None]),
            "ttl_s": _stats(ttls),
            "queue_wait_s": _stats([m.queue_wait for m in fin
                                    if m.queue_wait is not None]),
            "preempts": sum(m.n_preempts for m in fin),
            "finish_reasons": {r: sum(1 for m in fin if m.finish_reason == r)
                               for r in {m.finish_reason for m in fin}},
        }
