"""Kernel layer: Pallas TPU kernels for the paper's compute hotspots.

Each subpackage is one kernel *family* — ``kernel.py`` (the Pallas kernel),
``ops.py`` (the jit'd public wrapper handling layout/padding) and ``ref.py``
(the pure-jnp oracle that defines the contract).  ``registry.py`` is the
single switchboard that routes every family through the shared backend
lattice ``ref`` | ``pallas-interpret`` | ``pallas`` — see docs/kernels.md
for the per-family support matrix and ``HelixConfig`` (core/sharding.py)
for how call sites select backends.
"""
from repro.kernels import registry  # noqa: F401

__all__ = ["registry"]
