"""jit'd public wrapper for the flash_decode Pallas kernel.

Handles layout (Q-head grouping for GQA), padding (Q-group to sublane multiple,
S to block multiple) and un-padding, so callers use natural shapes:

    out, lse = flash_decode(q, k, v, total_len, rank, kvp=..., ...)

    q      [B, Qh, hsz]
    k, v   [B, Kh, S_cap, hsz]     (Qh % Kh == 0)
    out    [B, Qh, hsz]            lse [B, Qh] f32

Padded S slots are auto-masked: the round-robin position formula is strictly
increasing in the slot index, so any slot >= the true capacity maps to a
position >= total_len and is masked by the in-kernel total_len check, provided
S_cap * kvp >= total_len (always true for a correctly sized cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import round_up, pad_dim
from repro.kernels.flash_decode.kernel import flash_decode_kernel


@functools.partial(
    jax.jit,
    static_argnames=("kvp", "rr_block", "window", "scale", "block_s", "interpret"))
def flash_decode(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                 window: int = 0, scale: float | None = None,
                 block_s: int = 512, interpret: bool = True):
    b, qh, hsz = q.shape
    kh, s_cap = k.shape[1], k.shape[2]
    assert qh % kh == 0, (qh, kh)
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5

    block_s = min(block_s, round_up(s_cap, 128))
    qp = round_up(g, 8)

    qg = q.reshape(b, kh, g, hsz)
    qg = pad_dim(qg, 2, qp)
    kp = pad_dim(k, 2, block_s)
    vp = pad_dim(v, 2, block_s)

    scalars = jnp.stack([jnp.asarray(total_len, jnp.int32),
                         jnp.asarray(rank, jnp.int32)])

    out, lse = flash_decode_kernel(
        qg, kp, vp, scalars, scale=scale, kvp=kvp, rr_block=rr_block,
        window=window, block_s=block_s, interpret=interpret)

    out = out[:, :, :g, :].reshape(b, qh, hsz)
    lse = lse[:, :, :g].reshape(b, qh)
    return out, lse
