"""jit'd public wrapper for the flash_decode Pallas kernel.

Handles layout (Q-head grouping for GQA), padding (Q-group to sublane multiple,
S to block multiple) and un-padding, so callers use natural shapes:

    out, lse = flash_decode(q, k, v, total_len, rank, kvp=..., ...)

    q      [B, Qh, hsz]
    k, v   [B, Kh, S_cap, hsz]     (Qh % Kh == 0)
    out    [B, Qh, hsz]            lse [B, Qh] f32

Covers everything core/helix.py::_local_attend needs (the kernel is the real
Helix execution path when ``HelixConfig.attn_backend`` selects it):

  * ``total_len`` — scalar or per-request [B] int32 (continuous batching);
    prefetched as a length vector, one entry per batch row.
  * ``contiguous`` — non-round-robin shard layout (whisper cross-attention):
    local slot j holds global position rank*S_cap + j.
  * ``slot_offset`` — the sliding-window cache-slice fast path: positions are
    computed for slot j + slot_offset.
  * ``window`` — runtime sliding-window scalar (<= 0 disables); may be a
    traced per-layer value.
  * ``kscale``/``vscale`` [B, Kh, S_cap] — int8 K/V cache mode: dequant
    happens inside the kernel, block-by-block in VMEM.
  * ``k_new``/``v_new`` [B, Kh, hsz] — fused KV-append epilogue: the kernel
    writes the new token's row into the (aliased) cache and attends over it,
    so the separate ``append_kv`` cache round-trip disappears.  Requires the
    round-robin layout without slot_offset; ``total_len`` must already count
    the appended token.  Returns ``(out, lse, kcache, vcache)``; with an
    int8 cache (``kscale``/``vscale`` given) the raw rows are quantized
    in-kernel and ``(out, lse, kcache, vcache, kscale, vscale)`` comes back.
  * ``prune`` — block pruning (default on): fully-invalid S blocks are
    *skipped*, not masked — the K/V index_maps clamp to the valid span so
    Pallas elides the pruned blocks' DMAs, and ``pl.when`` skips their
    compute.  Bit-exact vs ``prune=False``; per-request HBM traffic becomes
    O(valid_len) instead of O(S_cap).  ``flash_decode_accounting`` reports
    the resulting blocks/bytes per call.

Padded S slots are masked in-kernel against the true capacity (prefetch-free:
it is a static kernel parameter), so any S_cap works in both layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import round_up, pad_dim
from repro.kernels.contract import KernelContract, Operand
from repro.kernels.flash_decode.kernel import (_append_slot,
                                               decode_index_maps,
                                               flash_decode_kernel,
                                               prune_block_range)


@functools.partial(
    jax.jit,
    static_argnames=("kvp", "rr_block", "scale", "block_s", "interpret",
                     "contiguous", "prune"))
def flash_decode(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                 window=0, scale: float | None = None, block_s: int = 512,
                 interpret: bool = True, contiguous: bool = False,
                 slot_offset=0, kscale=None, vscale=None,
                 k_new=None, v_new=None, prune: bool = True,
                 block_tables=None):
    """Decode-shape attention over one KV shard via the Pallas kernel.

    This is the flash_decode *family* entry point the kernel-backend
    registry routes to (``HelixConfig.attn_backend``); see the module
    docstring for the full mode lattice and ``flash_decode_ref`` for the
    oracle that defines the semantics.

    Paged mode: with ``block_tables`` ([B, max_pages] int32) the K/V
    operands are shared pool planes ``[n_pool, Kh, page_s, hsz]``
    (``kscale``/``vscale`` become ``[n_pool, Kh, page_s]``): request ``b``'s
    logical local slots ``[p*page_s, (p+1)*page_s)`` live in physical pool
    page ``block_tables[b, p]``.  The kernel's S-block size is pinned to
    ``page_s`` and the index_maps stream through the prefetched table
    (bit-exact vs the fixed layout at the same block size; pruning, quant
    and the fused append all compose).  Unallocated table entries should
    point at the reserved sink page 0.

    Returns ``(out [B, Qh, hsz], lse [B, Qh] f32)``, plus the appended
    ``(kcache, vcache)`` when ``k_new``/``v_new`` engage the fused-append
    epilogue (and the updated ``(kscale, vscale)`` for int8 caches) — pool
    planes in paged mode.
    """
    b, qh, hsz = q.shape
    kh = k.shape[1]
    paged = block_tables is not None
    assert qh % kh == 0, (qh, kh)
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5
    quant = kscale is not None
    append = k_new is not None
    if append:
        assert v_new is not None and not contiguous
        # slot_offset may reach here as a (weak) tracer under an outer jit;
        # only a concrete non-zero value can be rejected eagerly.  The Helix
        # caller guarantees the slice fast path and fusion never overlap
        # (core/helix.fuse_append_applicable).
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "fused append excludes the sliding-window cache-slice fast path"
    if paged:
        assert not contiguous, "paged mode excludes the contiguous layout"
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "paged mode excludes the cache-slice fast path"
        # page size is the kernel block; logical capacity spans the table
        block_s = k.shape[2]
        s_cap = block_tables.shape[1] * block_s
        kp, vp = k, v
        tables = jnp.asarray(block_tables, jnp.int32)
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        kp = pad_dim(k, 2, block_s)
        vp = pad_dim(v, 2, block_s)
        tables = None
    qp = round_up(g, 8)

    qg = q.reshape(b, kh, g, hsz)
    qg = pad_dim(qg, 2, qp)
    if kscale is not None:
        kscale = kscale.astype(jnp.float32)
        vscale = vscale.astype(jnp.float32)
        if not paged:
            kscale = pad_dim(kscale, 2, block_s)
            vscale = pad_dim(vscale, 2, block_s)

    meta = jnp.stack([jnp.asarray(rank, jnp.int32),
                      jnp.asarray(slot_offset, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    tl = jnp.asarray(total_len, jnp.int32).reshape(-1)     # scalar -> [1]
    tl = jnp.broadcast_to(tl, (b,))

    kw = {}
    if append:
        if quant:
            # the kernel quantizes the raw rows itself (payload + scale)
            kw = dict(k_new=k_new.astype(jnp.float32),
                      v_new=v_new.astype(jnp.float32))
        else:
            # match the unfused append_kv dtype cast so fusion is bit-exact
            kw = dict(k_new=k_new.astype(k.dtype), v_new=v_new.astype(v.dtype))

    res = flash_decode_kernel(
        qg, kp, vp, meta, tl, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_cap, contiguous=contiguous,
        kscale=kscale, vscale=vscale, prune=prune, block_tables=tables,
        interpret=interpret, **kw)

    out, lse = res[0], res[1]
    out = out[:, :, :g, :].reshape(b, qh, hsz)
    lse = lse[:, :, :g].reshape(b, qh)
    if append:
        if paged:
            return (out, lse) + tuple(res[2:])
        kc, vc = res[2][:, :, :s_cap], res[3][:, :, :s_cap]
        if quant:
            return out, lse, kc, vc, res[4][:, :, :s_cap], res[5][:, :, :s_cap]
        return out, lse, kc, vc
    return out, lse


def flash_decode_accounting(q, k, v, total_len, rank, *, kvp: int = 1,
                            rr_block: int = 16, window=0,
                            block_s: int = 512, contiguous: bool = False,
                            slot_offset=0, prune: bool = True,
                            kscale=None, vscale=None, block_tables=None,
                            **_ignored):
    """Blocks/bytes the matching ``flash_decode`` call streams from HBM.

    Replays the kernel's pruning ``index_map`` (``prune_block_range`` — the
    same function the kernel clamps its K/V DMAs with) over the grid and
    counts *distinct* block fetches: consecutive grid steps that reference
    the same block are one DMA on TPU, which is exactly how pruning turns
    masked blocks into elided reads.  ``prune=False`` reproduces the dense
    sweep (every block of every (b, h) pair).

    Paged mode (``block_tables`` [B, max_pages]): ``k``/``v`` are pool
    planes ``[n_pool, Kh, page_s, hsz]``; the replay walks the same logical
    page ranges through the table — a request's pages are distinct physical
    planes, so the distinct-fetch count (and the prune bound
    ``<= ceil(valid_len/block_s) + 1`` per (b, h)) is unchanged by the
    indirection, only ``block_s`` is pinned to the page size.

    Pure host-side arithmetic — no kernel launch, any argument set accepted
    by ``flash_decode`` works (extra kwargs are ignored), and ``q``/``k``/
    ``v`` may be ``jax.ShapeDtypeStruct``s (only shapes/dtypes are read).
    Returns a dict:

      ``blocks_visited`` / ``blocks_total`` — distinct K/V block DMAs vs the
      dense sweep, summed over the (B, Kh, S-blocks) grid;
      ``bytes_read`` / ``bytes_total`` — the corresponding K+V HBM bytes
      (+ dequant-scale bytes in int8 mode);
      ``block_s``, ``n_blocks`` — resolved kernel blocking.
    """
    paged = block_tables is not None
    kh, hsz = k.shape[1], k.shape[3]
    b = q.shape[0]
    if paged:
        block_s = k.shape[2]                       # page size is the block
        n_blocks = np.shape(block_tables)[1]       # logical pages
        s_cap = n_blocks * block_s
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        s_pad = round_up(s_cap, block_s)
        n_blocks = s_pad // block_s

    tl = np.broadcast_to(np.asarray(total_len, np.int32).reshape(-1), (b,))
    if prune:
        _, nb = prune_block_range(
            jnp.asarray(tl), jnp.asarray(rank, jnp.int32),
            jnp.asarray(slot_offset, jnp.int32), jnp.asarray(window, jnp.int32),
            kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_cap,
            contiguous=contiguous)
        # a fully-pruned request still references one (clamped) block: the
        # grid's first step fetches it before pl.when skips the compute
        per_req = np.maximum(np.asarray(nb), 1)
    else:
        per_req = np.full((b,), n_blocks)
    blocks_visited = int(kh * per_req.sum())
    blocks_total = b * kh * n_blocks
    el = jnp.dtype(k.dtype).itemsize
    blk_bytes = 2 * block_s * hsz * el                    # K + V payload
    if kscale is not None:
        blk_bytes += 2 * block_s * 4                      # f32 dequant scales
    return {
        "blocks_visited": blocks_visited,
        "blocks_total": blocks_total,
        "bytes_read": blocks_visited * blk_bytes,
        "bytes_total": blocks_total * blk_bytes,
        "block_s": block_s,
        "n_blocks": n_blocks,
    }

# --- static-analysis contract -------------------------------------------

# default audit lattice: prune x window x paged x kv8 x rr/contiguous x
# slot_offset x fused append, at interpreter-friendly toy shapes.  Mode
# exclusions mirror flash_decode's assertions (append/paged exclude the
# contiguous layout and the cache-slice fast path).
_CONTRACT_LATTICE = (
    dict(case="rr-prune"),
    dict(case="rr-dense", prune=False),
    dict(case="rr-window", window=6),
    dict(case="rr-window-slice", window=6, slot_offset=3),
    dict(case="rr-rank0", rank=0),
    dict(case="contig-prune", contiguous=True, kvp=1, rank=0),
    dict(case="contig-window", contiguous=True, kvp=1, rank=0, window=6),
    dict(case="contig-rank1", contiguous=True, rank=1, total_len=(20, 30)),
    dict(case="kv8-prune", quant=True),
    dict(case="append-rr", append=True),
    dict(case="append-kv8", append=True, quant=True),
    dict(case="append-window", append=True, window=6),
    dict(case="paged-prune", paged=True),
    dict(case="paged-dense", paged=True, prune=False),
    dict(case="paged-kv8", paged=True, quant=True),
    dict(case="paged-append-kv8", paged=True, quant=True, append=True),
    dict(case="paged-sink-tail", paged=True, sink_tail=True),
)


def decode_case_contract(case="rr-prune", *, b=2, qh=4, kh=2, hsz=8,
                         s_cap=16, kvp=2, rr_block=2, block_s=4, rank=1,
                         total_len=(5, 13), window=0, slot_offset=0,
                         contiguous=False, quant=False, append=False,
                         prune=True, paged=False, sink_tail=False, seed=0):
    """Build the ``KernelContract`` for one flash_decode configuration.

    Mirrors ``flash_decode``'s geometry resolution (padding, block sizing,
    prefetch layout) at the given shapes and binds the *same* index_map
    callables the kernel would pass to ``pallas_call``
    (``kernel.decode_index_maps``), so the static auditor proves properties
    of the real DMA addressing.  ``sink_tail`` leaves unallocated paged
    table entries on the reserved sink page 0.  Returns one
    ``KernelContract``; ``flash_decode_contract`` assembles the lattice.
    """
    g = qh // kh
    qp = round_up(g, 8)
    if paged:
        n_blocks = s_cap // block_s
        s_pad = n_blocks * block_s
    else:
        block_s = min(block_s, round_up(s_cap, 128))
        s_pad = round_up(s_cap, block_s)
        n_blocks = s_pad // block_s
    s_true = s_cap

    meta = np.array([rank, slot_offset, window], np.int32)
    tl = np.broadcast_to(np.asarray(total_len, np.int32).reshape(-1), (b,))
    prefetch = (meta, tl)

    table = None
    n_pool = None
    if paged:
        rng = np.random.RandomState(seed)
        n_pool = 1 + b * n_blocks            # page 0 is the reserved sink
        table = (1 + rng.permutation(b * n_blocks)
                 .reshape(b, n_blocks)).astype(np.int32)
        if sink_tail:
            # entries past the valid span are unallocated -> sink page 0
            need = (tl + block_s - 1) // block_s
            for i in range(b):
                table[i, max(int(need[i]), 1):] = 0
        prefetch = prefetch + (table,)

    idx = decode_index_maps(
        kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_true,
        n_blocks=n_blocks, contiguous=contiguous, prune=prune, paged=paged)

    kv_shape = ((n_pool, kh, block_s, hsz) if paged
                else (b, kh, s_pad, hsz))
    sc_shape = ((n_pool, kh, block_s) if paged else (b, kh, s_pad))
    pax = 0 if paged else None

    operands = [
        Operand("q", (b, kh, qp, hsz), (1, 1, qp, hsz), idx["q"]),
        Operand("k", kv_shape, (1, 1, block_s, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
        Operand("v", kv_shape, (1, 1, block_s, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
    ]
    if quant:
        operands += [
            Operand("kscale", sc_shape, (1, 1, block_s), idx["scale"],
                    streamed=True, paged_axis=pax),
            Operand("vscale", sc_shape, (1, 1, block_s), idx["scale"],
                    streamed=True, paged_axis=pax),
        ]
    if append:
        operands += [
            Operand("k_new", (b, kh, hsz), (1, 1, hsz), idx["new"]),
            Operand("v_new", (b, kh, hsz), (1, 1, hsz), idx["new"]),
            Operand("k_row_in", kv_shape, (1, 1, 1, hsz), idx["row"],
                    paged_axis=pax),
            Operand("v_row_in", kv_shape, (1, 1, 1, hsz), idx["row"],
                    paged_axis=pax),
        ]
        if quant:
            operands += [
                Operand("kscale_row_in", sc_shape, (1, 1, 1), idx["srow"],
                        paged_axis=pax),
                Operand("vscale_row_in", sc_shape, (1, 1, 1), idx["srow"],
                        paged_axis=pax),
            ]
    operands += [
        Operand("out", (b, kh, qp, hsz), (1, 1, qp, hsz), idx["q"],
                kind="out"),
        Operand("lse", (b, kh, qp), (1, 1, qp), idx["lse"], kind="out"),
    ]
    npre = 3 if paged else 2
    aliases = {}
    if append:
        operands += [
            Operand("k_row_out", kv_shape, (1, 1, 1, hsz), idx["row"],
                    kind="out", alias_of="k", paged_axis=pax),
            Operand("v_row_out", kv_shape, (1, 1, 1, hsz), idx["row"],
                    kind="out", alias_of="v", paged_axis=pax),
        ]
        aliases = {npre + 1: 2, npre + 2: 3}
        if quant:
            operands += [
                Operand("kscale_row_out", sc_shape, (1, 1, 1), idx["srow"],
                        kind="out", alias_of="kscale", paged_axis=pax),
                Operand("vscale_row_out", sc_shape, (1, 1, 1), idx["srow"],
                        kind="out", alias_of="vscale", paged_axis=pax),
            ]
            aliases = {npre + 1: 2, npre + 2: 3, npre + 3: 4, npre + 4: 5}

    active = None
    if prune:
        lo_d, nb_d = prune_block_range(
            jnp.asarray(tl), jnp.asarray(rank, jnp.int32),
            jnp.asarray(slot_offset, jnp.int32),
            jnp.asarray(window, jnp.int32), kvp=kvp, rr_block=rr_block,
            block_s=block_s, s_true=s_true, contiguous=contiguous)
        nb_np = np.asarray(nb_d)

        def active(bi, h, s, _nb=nb_np):
            return bool(s < _nb[bi])

    expected_row = None
    if append:
        j_new = np.asarray(_append_slot(jnp.asarray(tl), kvp, rr_block,
                                        s_pad))

        def expected_row(bi, h, _j=j_new, _tbl=table):
            j = int(_j[bi])
            if _tbl is not None:
                return (int(_tbl[bi, j // block_s]), h, j % block_s, 0)
            return (bi, h, j, 0)

    return KernelContract(
        family="flash_decode", case=case, grid=(b, kh, n_blocks),
        operands=operands, prefetch=prefetch, stream_axis=2,
        aliases=aliases, active=active, expected_row=expected_row,
        table=table, n_pool=n_pool,
        notes=dict(kvp=kvp, rr_block=rr_block, block_s=block_s,
                   s_true=s_true, prune=prune, paged=paged, quant=quant,
                   append=append, contiguous=contiguous, window=window,
                   slot_offset=slot_offset))


def flash_decode_contract():
    """Contracts for the flash_decode audit lattice (``repro.analysis``).

    One ``KernelContract`` per configuration in the default lattice —
    prune x window x paged x kv8 x rr/contiguous x slot_offset x fused
    append — each binding the kernel's real index_map callables at toy
    shapes the auditor can enumerate exhaustively.
    """
    return [decode_case_contract(**dict(c)) for c in _CONTRACT_LATTICE]
