"""jit'd public wrapper for the flash_decode Pallas kernel.

Handles layout (Q-head grouping for GQA), padding (Q-group to sublane multiple,
S to block multiple) and un-padding, so callers use natural shapes:

    out, lse = flash_decode(q, k, v, total_len, rank, kvp=..., ...)

    q      [B, Qh, hsz]
    k, v   [B, Kh, S_cap, hsz]     (Qh % Kh == 0)
    out    [B, Qh, hsz]            lse [B, Qh] f32

Covers everything core/helix.py::_local_attend needs (the kernel is the real
Helix execution path when ``HelixConfig.attn_backend`` selects it):

  * ``total_len`` — scalar or per-request [B] int32 (continuous batching);
    prefetched as a length vector, one entry per batch row.
  * ``contiguous`` — non-round-robin shard layout (whisper cross-attention):
    local slot j holds global position rank*S_cap + j.
  * ``slot_offset`` — the sliding-window cache-slice fast path: positions are
    computed for slot j + slot_offset.
  * ``window`` — runtime sliding-window scalar (<= 0 disables); may be a
    traced per-layer value.
  * ``kscale``/``vscale`` [B, Kh, S_cap] — int8 K/V cache mode: dequant
    happens inside the kernel, block-by-block in VMEM.
  * ``k_new``/``v_new`` [B, Kh, hsz] — fused KV-append epilogue: the kernel
    writes the new token's row into the (aliased) cache and attends over it,
    so the separate ``append_kv`` cache round-trip disappears.  Requires the
    round-robin layout without slot_offset; ``total_len`` must already count
    the appended token.  Returns ``(out, lse, kcache, vcache)``; with an
    int8 cache (``kscale``/``vscale`` given) the raw rows are quantized
    in-kernel and ``(out, lse, kcache, vcache, kscale, vscale)`` comes back.
  * ``prune`` — block pruning (default on): fully-invalid S blocks are
    *skipped*, not masked — the K/V index_maps clamp to the valid span so
    Pallas elides the pruned blocks' DMAs, and ``pl.when`` skips their
    compute.  Bit-exact vs ``prune=False``; per-request HBM traffic becomes
    O(valid_len) instead of O(S_cap).  ``flash_decode_accounting`` reports
    the resulting blocks/bytes per call.

Padded S slots are masked in-kernel against the true capacity (prefetch-free:
it is a static kernel parameter), so any S_cap works in both layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import round_up, pad_dim
from repro.kernels.contract import KernelContract, Operand
from repro.kernels.flash_decode.kernel import (_append_slot,
                                               decode_index_maps,
                                               flash_decode_kernel,
                                               grouped_prefix_index_maps,
                                               prefix_pass_kernel,
                                               prune_block_range)


@functools.partial(
    jax.jit,
    static_argnames=("kvp", "rr_block", "scale", "block_s", "interpret",
                     "contiguous", "prune"))
def flash_decode(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                 window=0, scale: float | None = None, block_s: int = 512,
                 interpret: bool = True, contiguous: bool = False,
                 slot_offset=0, kscale=None, vscale=None,
                 k_new=None, v_new=None, prune: bool = True,
                 block_tables=None, groups=None):
    """Decode-shape attention over one KV shard via the Pallas kernel.

    This is the flash_decode *family* entry point the kernel-backend
    registry routes to (``HelixConfig.attn_backend``); see the module
    docstring for the full mode lattice and ``flash_decode_ref`` for the
    oracle that defines the semantics.

    Paged mode: with ``block_tables`` ([B, max_pages] int32) the K/V
    operands are shared pool planes ``[n_pool, Kh, page_s, hsz]``
    (``kscale``/``vscale`` become ``[n_pool, Kh, page_s]``): request ``b``'s
    logical local slots ``[p*page_s, (p+1)*page_s)`` live in physical pool
    page ``block_tables[b, p]``.  The kernel's S-block size is pinned to
    ``page_s`` and the index_maps stream through the prefetched table
    (bit-exact vs the fixed layout at the same block size; pruning, quant
    and the fused append all compose).  Unallocated table entries should
    point at the reserved sink page 0.

    Grouped shared-prefix decode (``groups`` — paged only): ``groups =
    (group_id [B], group_np [B])`` int32 marks requests whose block tables
    share their leading ``group_np`` physical pages (CoDec-style, arXiv
    2505.17694).  ``group_id`` is any stable representative (e.g. the
    lowest member's batch row); singletons use their own row with
    ``group_np == 0``.  The call splits into two passes: a *prefix* pass
    (``prefix_pass_kernel``) stacks each group's Q rows and streams every
    shared page **once per group**, emitting raw online-softmax state, and
    the *suffix* pass resumes that state while its span clamp skips blocks
    below ``group_np``.  Bit-exact with ``groups=None`` — same block order,
    same masks — while prefix HBM reads drop by ~1/group_size.

    Returns ``(out [B, Qh, hsz], lse [B, Qh] f32)``, plus the appended
    ``(kcache, vcache)`` when ``k_new``/``v_new`` engage the fused-append
    epilogue (and the updated ``(kscale, vscale)`` for int8 caches) — pool
    planes in paged mode.
    """
    b, qh, hsz = q.shape
    kh = k.shape[1]
    paged = block_tables is not None
    assert qh % kh == 0, (qh, kh)
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5
    quant = kscale is not None
    append = k_new is not None
    if append:
        assert v_new is not None and not contiguous
        # slot_offset may reach here as a (weak) tracer under an outer jit;
        # only a concrete non-zero value can be rejected eagerly.  The Helix
        # caller guarantees the slice fast path and fusion never overlap
        # (core/helix.fuse_append_applicable).
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "fused append excludes the sliding-window cache-slice fast path"
    if paged:
        assert not contiguous, "paged mode excludes the contiguous layout"
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "paged mode excludes the cache-slice fast path"
        # page size is the kernel block; logical capacity spans the table
        block_s = k.shape[2]
        s_cap = block_tables.shape[1] * block_s
        kp, vp = k, v
        tables = jnp.asarray(block_tables, jnp.int32)
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        kp = pad_dim(k, 2, block_s)
        vp = pad_dim(v, 2, block_s)
        tables = None
    qp = round_up(g, 8)

    qg = q.reshape(b, kh, g, hsz)
    qg = pad_dim(qg, 2, qp)
    if kscale is not None:
        kscale = kscale.astype(jnp.float32)
        vscale = vscale.astype(jnp.float32)
        if not paged:
            kscale = pad_dim(kscale, 2, block_s)
            vscale = pad_dim(vscale, 2, block_s)

    meta = jnp.stack([jnp.asarray(rank, jnp.int32),
                      jnp.asarray(slot_offset, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    tl = jnp.asarray(total_len, jnp.int32).reshape(-1)     # scalar -> [1]
    tl = jnp.broadcast_to(tl, (b,))

    kw = {}
    if append:
        if quant:
            # the kernel quantizes the raw rows itself (payload + scale)
            kw = dict(k_new=k_new.astype(jnp.float32),
                      v_new=v_new.astype(jnp.float32))
        else:
            # match the unfused append_kv dtype cast so fusion is bit-exact
            kw = dict(k_new=k_new.astype(k.dtype), v_new=v_new.astype(v.dtype))

    if groups is not None:
        assert paged, "grouped decode requires paged mode"
        gid = jnp.asarray(groups[0], jnp.int32)
        gnp_req = jnp.asarray(groups[1], jnp.int32)
        # static worst case: B group rows x B member slots (every request a
        # singleton, or one group holding the whole batch); unused rows
        # carry gnp == 0 / gtl == 0 and degenerate to the identity update
        gnp = jnp.zeros((b,), jnp.int32).at[gid].max(gnp_req)
        bidx = jnp.arange(b)
        same = gid[None, :] == gid[:, None]
        ms = jnp.sum(same & (bidx[None, :] < bidx[:, None]), axis=1)
        gtl = jnp.zeros((b, b), jnp.int32).at[gid, ms].set(tl)
        # duplicate-index winner is irrelevant: only the leading gnp[g]
        # entries are read, and members of a group share exactly those
        gtab = jnp.zeros((b, tables.shape[1]), jnp.int32).at[gid].set(tables)
        qs = jnp.zeros((b, kh, b, qp, hsz), qg.dtype).at[gid, :, ms].set(qg)
        acc_g, m_g, l_g = prefix_pass_kernel(
            qs.reshape(b, kh, b * qp, hsz), kp, vp, meta, gnp, gtl, gtab,
            scale=scale, kvp=kvp, rr_block=rr_block, block_s=block_s,
            s_true=s_cap, kscale=kscale, vscale=vscale, interpret=interpret)
        acc0 = acc_g.reshape(b, kh, b, qp, hsz)[gid, :, ms]
        m0 = m_g.reshape(b, kh, b, qp)[gid, :, ms]
        l0 = l_g.reshape(b, kh, b, qp)[gid, :, ms]
        kw.update(sfx_start=gnp_req, init_state=(acc0, m0, l0))

    res = flash_decode_kernel(
        qg, kp, vp, meta, tl, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_cap, contiguous=contiguous,
        kscale=kscale, vscale=vscale, prune=prune, block_tables=tables,
        interpret=interpret, **kw)

    out, lse = res[0], res[1]
    out = out[:, :, :g, :].reshape(b, qh, hsz)
    lse = lse[:, :, :g].reshape(b, qh)
    if append:
        if paged:
            return (out, lse) + tuple(res[2:])
        kc, vc = res[2][:, :, :s_cap], res[3][:, :, :s_cap]
        if quant:
            return out, lse, kc, vc, res[4][:, :, :s_cap], res[5][:, :, :s_cap]
        return out, lse, kc, vc
    return out, lse


def flash_decode_accounting(q, k, v, total_len, rank, *, kvp: int = 1,
                            rr_block: int = 16, window=0,
                            block_s: int = 512, contiguous: bool = False,
                            slot_offset=0, prune: bool = True,
                            kscale=None, vscale=None, block_tables=None,
                            groups=None, **_ignored):
    """Blocks/bytes the matching ``flash_decode`` call streams from HBM.

    Replays the kernel's pruning ``index_map`` (``prune_block_range`` — the
    same function the kernel clamps its K/V DMAs with) over the grid and
    counts *distinct* block fetches: consecutive grid steps that reference
    the same block are one DMA on TPU, which is exactly how pruning turns
    masked blocks into elided reads.  ``prune=False`` reproduces the dense
    sweep (every block of every (b, h) pair).

    Paged mode (``block_tables`` [B, max_pages]): ``k``/``v`` are pool
    planes ``[n_pool, Kh, page_s, hsz]``; the replay walks the same logical
    page ranges through the table — a request's pages are distinct physical
    planes, so the distinct-fetch count (and the prune bound
    ``<= ceil(valid_len/block_s) + 1`` per (b, h)) is unchanged by the
    indirection, only ``block_s`` is pinned to the page size.

    Grouped mode (``groups = (group_id [B], group_np [B])``): replays both
    passes.  The prefix pass streams ``max(group_np_g, 1)`` pages per
    *group* grid row (all B rows exist; memberless rows reference the
    clamped sink page once), the suffix pass per request lifts the pruned
    span's lower bound to ``group_np[b]`` — together they prove the
    ~1/group_size prefix bytes-read reduction.  The split is reported via
    ``prefix_blocks``/``suffix_blocks`` (and ``prefix_bytes``/
    ``suffix_bytes``); ungrouped calls report ``prefix_blocks == 0``.

    Pure host-side arithmetic — no kernel launch, any argument set accepted
    by ``flash_decode`` works (extra kwargs are ignored), and ``q``/``k``/
    ``v`` may be ``jax.ShapeDtypeStruct``s (only shapes/dtypes are read).
    Returns a dict:

      ``blocks_visited`` / ``blocks_total`` — distinct K/V block DMAs vs the
      dense sweep, summed over the (B, Kh, S-blocks) grid;
      ``bytes_read`` / ``bytes_total`` — the corresponding K+V HBM bytes
      (+ dequant-scale bytes in int8 mode);
      ``prefix_blocks``/``suffix_blocks``, ``prefix_bytes``/
      ``suffix_bytes`` — the grouped two-pass split of ``blocks_visited``;
      ``block_s``, ``n_blocks`` — resolved kernel blocking.
    """
    paged = block_tables is not None
    kh, hsz = k.shape[1], k.shape[3]
    b = q.shape[0]
    if paged:
        block_s = k.shape[2]                       # page size is the block
        n_blocks = np.shape(block_tables)[1]       # logical pages
        s_cap = n_blocks * block_s
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        s_pad = round_up(s_cap, block_s)
        n_blocks = s_pad // block_s

    tl = np.broadcast_to(np.asarray(total_len, np.int32).reshape(-1), (b,))
    if prune:
        lo, nb = prune_block_range(
            jnp.asarray(tl), jnp.asarray(rank, jnp.int32),
            jnp.asarray(slot_offset, jnp.int32), jnp.asarray(window, jnp.int32),
            kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_cap,
            contiguous=contiguous)
        lo, nb = np.asarray(lo), np.asarray(nb)
        if groups is not None:
            # suffix pass: the span's lower bound is lifted to the first
            # unshared page (mirrors decode_index_maps grouped clamp)
            start = np.broadcast_to(
                np.asarray(groups[1], np.int32).reshape(-1), (b,))
            lo2 = np.maximum(lo, start)
            nb = np.maximum(lo + nb - lo2, 0)
        # a fully-pruned request still references one (clamped) block: the
        # grid's first step fetches it before pl.when skips the compute
        per_req = np.maximum(nb, 1)
    else:
        per_req = np.full((b,), n_blocks)
    prefix_blocks = 0
    if groups is not None:
        # prefix pass grid is (B group rows, Kh, n_blocks): row g streams
        # its max(gnp, 1) span-clamped shared pages once per *group*
        gid = np.broadcast_to(np.asarray(groups[0], np.int32).reshape(-1),
                              (b,))
        gnp_req = np.broadcast_to(np.asarray(groups[1], np.int32).reshape(-1),
                                  (b,))
        gnp = np.zeros((b,), np.int32)
        np.maximum.at(gnp, gid, gnp_req)
        prefix_blocks = int(kh * np.maximum(gnp, 1).sum())
    suffix_blocks = int(kh * per_req.sum())
    blocks_visited = prefix_blocks + suffix_blocks
    blocks_total = b * kh * n_blocks
    el = jnp.dtype(k.dtype).itemsize
    blk_bytes = 2 * block_s * hsz * el                    # K + V payload
    if kscale is not None:
        blk_bytes += 2 * block_s * 4                      # f32 dequant scales
    return {
        "blocks_visited": blocks_visited,
        "blocks_total": blocks_total,
        "bytes_read": blocks_visited * blk_bytes,
        "bytes_total": blocks_total * blk_bytes,
        "prefix_blocks": prefix_blocks,
        "suffix_blocks": suffix_blocks,
        "prefix_bytes": prefix_blocks * blk_bytes,
        "suffix_bytes": suffix_blocks * blk_bytes,
        "block_s": block_s,
        "n_blocks": n_blocks,
    }

# --- static-analysis contract -------------------------------------------

# default audit lattice: prune x window x paged x kv8 x rr/contiguous x
# slot_offset x fused append, at interpreter-friendly toy shapes.  Mode
# exclusions mirror flash_decode's assertions (append/paged exclude the
# contiguous layout and the cache-slice fast path).
_CONTRACT_LATTICE = (
    dict(case="rr-prune"),
    dict(case="rr-dense", prune=False),
    dict(case="rr-window", window=6),
    dict(case="rr-window-slice", window=6, slot_offset=3),
    dict(case="rr-rank0", rank=0),
    dict(case="contig-prune", contiguous=True, kvp=1, rank=0),
    dict(case="contig-window", contiguous=True, kvp=1, rank=0, window=6),
    dict(case="contig-rank1", contiguous=True, rank=1, total_len=(20, 30)),
    dict(case="kv8-prune", quant=True),
    dict(case="append-rr", append=True),
    dict(case="append-kv8", append=True, quant=True),
    dict(case="append-window", append=True, window=6),
    dict(case="paged-prune", paged=True),
    dict(case="paged-dense", paged=True, prune=False),
    dict(case="paged-kv8", paged=True, quant=True),
    dict(case="paged-append-kv8", paged=True, quant=True, append=True),
    dict(case="paged-sink-tail", paged=True, sink_tail=True),
    dict(case="paged-grouped", paged=True, grouped=True),
    dict(case="paged-grouped-append", paged=True, grouped=True, append=True),
    dict(case="paged-shared-prefix", paged=True, grouped=True,
         shared_prefix=True, kvp=1, rank=0, total_len=(9, 13)),
    dict(case="paged-shared-append", paged=True, grouped=True,
         shared_prefix=True, append=True, kvp=1, rank=0, total_len=(9, 13)),
)


def decode_case_contract(case="rr-prune", *, b=2, qh=4, kh=2, hsz=8,
                         s_cap=16, kvp=2, rr_block=2, block_s=4, rank=1,
                         total_len=(5, 13), window=0, slot_offset=0,
                         contiguous=False, quant=False, append=False,
                         prune=True, paged=False, sink_tail=False,
                         grouped=False, shared_prefix=False, seed=0):
    """Build the ``KernelContract`` for one flash_decode configuration.

    Mirrors ``flash_decode``'s geometry resolution (padding, block sizing,
    prefetch layout) at the given shapes and binds the *same* index_map
    callables the kernel would pass to ``pallas_call``
    (``kernel.decode_index_maps``), so the static auditor proves properties
    of the real DMA addressing.  ``sink_tail`` leaves unallocated paged
    table entries on the reserved sink page 0.  ``grouped`` audits the
    grouped-suffix maps: a ``start [B]`` prefetch operand joins the table,
    the init-state operands precede q, and the pruned span is lifted to the
    start page.  ``shared_prefix`` makes the requests share their leading
    table page (request 1 maps request 0's first page) and sets the
    ``shared_ok`` note so the table audit allows the read-only duplicate —
    append targets must still be exclusive.  Returns one
    ``KernelContract``; ``flash_decode_contract`` assembles the lattice.
    """
    g = qh // kh
    qp = round_up(g, 8)
    if paged:
        n_blocks = s_cap // block_s
        s_pad = n_blocks * block_s
    else:
        block_s = min(block_s, round_up(s_cap, 128))
        s_pad = round_up(s_cap, block_s)
        n_blocks = s_pad // block_s
    s_true = s_cap

    meta = np.array([rank, slot_offset, window], np.int32)
    tl = np.broadcast_to(np.asarray(total_len, np.int32).reshape(-1), (b,))
    prefetch = (meta, tl)

    table = None
    n_pool = None
    start = None
    if paged:
        rng = np.random.RandomState(seed)
        n_pool = 1 + b * n_blocks            # page 0 is the reserved sink
        table = (1 + rng.permutation(b * n_blocks)
                 .reshape(b, n_blocks)).astype(np.int32)
        if sink_tail:
            # entries past the valid span are unallocated -> sink page 0
            need = (tl + block_s - 1) // block_s
            for i in range(b):
                table[i, max(int(need[i]), 1):] = 0
        if shared_prefix:
            # both requests map request 0's first page as their shared
            # (read-only, refcounted) leading prefix page
            table[1, 0] = table[0, 0]
        prefetch = prefetch + (table,)
    if grouped:
        assert paged, "grouped suffix maps require paged mode"
        # first unshared logical page per request: with shared_prefix both
        # requests resume past the one shared page; otherwise request 0 is
        # a singleton (start 0) and request 1 pretends one prefix page
        start = (np.full((b,), 1, np.int32) if shared_prefix
                 else np.arange(b, dtype=np.int32) % 2)
        prefetch = prefetch + (start,)

    idx = decode_index_maps(
        kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_true,
        n_blocks=n_blocks, contiguous=contiguous, prune=prune, paged=paged,
        grouped=grouped)

    kv_shape = ((n_pool, kh, block_s, hsz) if paged
                else (b, kh, s_pad, hsz))
    sc_shape = ((n_pool, kh, block_s) if paged else (b, kh, s_pad))
    pax = 0 if paged else None

    operands = []
    if grouped:
        # the prefix pass's raw state precedes q (kernel arg order)
        operands += [
            Operand("acc0", (b, kh, qp, hsz), (1, 1, qp, hsz), idx["q"]),
            Operand("m0", (b, kh, qp), (1, 1, qp), idx["lse"]),
            Operand("l0", (b, kh, qp), (1, 1, qp), idx["lse"]),
        ]
    operands += [
        Operand("q", (b, kh, qp, hsz), (1, 1, qp, hsz), idx["q"]),
        Operand("k", kv_shape, (1, 1, block_s, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
        Operand("v", kv_shape, (1, 1, block_s, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
    ]
    if quant:
        operands += [
            Operand("kscale", sc_shape, (1, 1, block_s), idx["scale"],
                    streamed=True, paged_axis=pax),
            Operand("vscale", sc_shape, (1, 1, block_s), idx["scale"],
                    streamed=True, paged_axis=pax),
        ]
    if append:
        operands += [
            Operand("k_new", (b, kh, hsz), (1, 1, hsz), idx["new"]),
            Operand("v_new", (b, kh, hsz), (1, 1, hsz), idx["new"]),
            Operand("k_row_in", kv_shape, (1, 1, 1, hsz), idx["row"],
                    paged_axis=pax),
            Operand("v_row_in", kv_shape, (1, 1, 1, hsz), idx["row"],
                    paged_axis=pax),
        ]
        if quant:
            operands += [
                Operand("kscale_row_in", sc_shape, (1, 1, 1), idx["srow"],
                        paged_axis=pax),
                Operand("vscale_row_in", sc_shape, (1, 1, 1), idx["srow"],
                        paged_axis=pax),
            ]
    operands += [
        Operand("out", (b, kh, qp, hsz), (1, 1, qp, hsz), idx["q"],
                kind="out"),
        Operand("lse", (b, kh, qp), (1, 1, qp), idx["lse"], kind="out"),
    ]
    npre = (3 if paged else 2) + (1 if grouped else 0)
    qoff = npre + (3 if grouped else 0)
    aliases = {}
    if append:
        operands += [
            Operand("k_row_out", kv_shape, (1, 1, 1, hsz), idx["row"],
                    kind="out", alias_of="k", paged_axis=pax),
            Operand("v_row_out", kv_shape, (1, 1, 1, hsz), idx["row"],
                    kind="out", alias_of="v", paged_axis=pax),
        ]
        aliases = {qoff + 1: 2, qoff + 2: 3}
        if quant:
            operands += [
                Operand("kscale_row_out", sc_shape, (1, 1, 1), idx["srow"],
                        kind="out", alias_of="kscale", paged_axis=pax),
                Operand("vscale_row_out", sc_shape, (1, 1, 1), idx["srow"],
                        kind="out", alias_of="vscale", paged_axis=pax),
            ]
            aliases = {qoff + 1: 2, qoff + 2: 3, qoff + 3: 4, qoff + 4: 5}

    active = None
    if prune:
        lo_d, nb_d = prune_block_range(
            jnp.asarray(tl), jnp.asarray(rank, jnp.int32),
            jnp.asarray(slot_offset, jnp.int32),
            jnp.asarray(window, jnp.int32), kvp=kvp, rr_block=rr_block,
            block_s=block_s, s_true=s_true, contiguous=contiguous)
        lo_np, nb_np = np.asarray(lo_d), np.asarray(nb_d)
        if grouped:
            lo2 = np.maximum(lo_np, start)
            nb_np = np.maximum(lo_np + nb_np - lo2, 0)

        def active(bi, h, s, _nb=nb_np):
            return bool(s < _nb[bi])
    # dense grouped mode skips compute below start but still streams every
    # block (no index clamp), so no elision predicate applies there

    expected_row = None
    if append:
        j_new = np.asarray(_append_slot(jnp.asarray(tl), kvp, rr_block,
                                        s_pad))

        def expected_row(bi, h, _j=j_new, _tbl=table):
            j = int(_j[bi])
            if _tbl is not None:
                return (int(_tbl[bi, j // block_s]), h, j % block_s, 0)
            return (bi, h, j, 0)

    return KernelContract(
        family="flash_decode", case=case, grid=(b, kh, n_blocks),
        operands=operands, prefetch=prefetch, stream_axis=2,
        aliases=aliases, active=active, expected_row=expected_row,
        table=table, n_pool=n_pool,
        notes=dict(kvp=kvp, rr_block=rr_block, block_s=block_s,
                   s_true=s_true, prune=prune, paged=paged, quant=quant,
                   append=append, contiguous=contiguous, window=window,
                   slot_offset=slot_offset, grouped=grouped,
                   shared_ok=shared_prefix))


def prefix_case_contract(case="grouped-prefix", *, g=2, gm=2, kh=2, hsz=8,
                         qp=8, kvp=1, rr_block=2, block_s=4, n_blocks=4,
                         window=0, quant=False, seed=0):
    """``KernelContract`` for the grouped shared-prefix pass.

    Grid ``(G, Kh, n_blocks)`` over group rows; binds the *same*
    ``grouped_prefix_index_maps`` callables ``prefix_pass_kernel`` hands to
    ``pallas_call``.  Group 0 holds two members sharing a two-page prefix,
    group 1 is a memberless padding row (``gnp == 0``, all lengths 0) — the
    degenerate shape every batch position the engine leaves ungrouped
    takes, whose span clamp pins the stream to one page.
    """
    rows = gm * qp
    rng = np.random.RandomState(seed)
    n_pool = 1 + g * n_blocks
    gtab = (1 + rng.permutation(g * n_blocks)
            .reshape(g, n_blocks)).astype(np.int32)
    gnp = np.array([2] + [0] * (g - 1), np.int32)
    gtl = np.zeros((g, gm), np.int32)
    gtl[0] = [2 * block_s + 1, 3 * block_s + 1][:gm]
    meta = np.array([0, 0, window], np.int32)

    idx = grouped_prefix_index_maps(n_blocks=n_blocks)
    operands = [
        Operand("q", (g, kh, rows, hsz), (1, 1, rows, hsz), idx["q"]),
        Operand("k", (n_pool, kh, block_s, hsz), (1, 1, block_s, hsz),
                idx["kv"], streamed=True, paged_axis=0),
        Operand("v", (n_pool, kh, block_s, hsz), (1, 1, block_s, hsz),
                idx["kv"], streamed=True, paged_axis=0),
    ]
    if quant:
        operands += [
            Operand("kscale", (n_pool, kh, block_s), (1, 1, block_s),
                    idx["scale"], streamed=True, paged_axis=0),
            Operand("vscale", (n_pool, kh, block_s), (1, 1, block_s),
                    idx["scale"], streamed=True, paged_axis=0),
        ]
    operands += [
        Operand("acc", (g, kh, rows, hsz), (1, 1, rows, hsz), idx["acc"],
                kind="out"),
        Operand("m", (g, kh, rows), (1, 1, rows), idx["ml"], kind="out"),
        Operand("l", (g, kh, rows), (1, 1, rows), idx["ml"], kind="out"),
    ]

    def active(gi, h, s, _np=gnp):
        return bool(s < _np[gi])

    return KernelContract(
        family="flash_decode", case=case, grid=(g, kh, n_blocks),
        operands=operands, prefetch=(meta, gnp, gtl, gtab), stream_axis=2,
        active=active, table=gtab, n_pool=n_pool,
        notes=dict(kvp=kvp, rr_block=rr_block, block_s=block_s,
                   quant=quant, grouped_prefix=True))


def flash_decode_contract():
    """Contracts for the flash_decode audit lattice (``repro.analysis``).

    One ``KernelContract`` per configuration in the default lattice —
    prune x window x paged x kv8 x rr/contiguous x slot_offset x fused
    append x grouped/shared-prefix — each binding the kernel's real
    index_map callables at toy shapes the auditor can enumerate
    exhaustively, plus the grouped shared-prefix pass's own contracts.
    """
    suite = [decode_case_contract(**dict(c)) for c in _CONTRACT_LATTICE]
    suite.append(prefix_case_contract())
    suite.append(prefix_case_contract(case="grouped-prefix-kv8", quant=True))
    return suite
