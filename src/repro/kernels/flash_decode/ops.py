"""jit'd public wrapper for the flash_decode Pallas kernel.

Handles layout (Q-head grouping for GQA), padding (Q-group to sublane multiple,
S to block multiple) and un-padding, so callers use natural shapes:

    out, lse = flash_decode(q, k, v, total_len, rank, kvp=..., ...)

    q      [B, Qh, hsz]
    k, v   [B, Kh, S_cap, hsz]     (Qh % Kh == 0)
    out    [B, Qh, hsz]            lse [B, Qh] f32

Covers everything core/helix.py::_local_attend needs (the kernel is the real
Helix execution path when ``HelixConfig.attn_backend`` selects it):

  * ``total_len`` — scalar or per-request [B] int32 (continuous batching);
    prefetched as a length vector, one entry per batch row.
  * ``contiguous`` — non-round-robin shard layout (whisper cross-attention):
    local slot j holds global position rank*S_cap + j.
  * ``slot_offset`` — the sliding-window cache-slice fast path: positions are
    computed for slot j + slot_offset.
  * ``window`` — runtime sliding-window scalar (<= 0 disables); may be a
    traced per-layer value.
  * ``kscale``/``vscale`` [B, Kh, S_cap] — int8 K/V cache mode: dequant
    happens inside the kernel, block-by-block in VMEM.
  * ``k_new``/``v_new`` [B, Kh, hsz] — fused KV-append epilogue: the kernel
    writes the new token's row into the (aliased) cache and attends over it,
    so the separate ``append_kv`` cache round-trip disappears.  Requires the
    round-robin layout without slot_offset; ``total_len`` must already count
    the appended token.  Returns ``(out, lse, kcache, vcache)``; with an
    int8 cache (``kscale``/``vscale`` given) the raw rows are quantized
    in-kernel and ``(out, lse, kcache, vcache, kscale, vscale)`` comes back.
  * ``prune`` — block pruning (default on): fully-invalid S blocks are
    *skipped*, not masked — the K/V index_maps clamp to the valid span so
    Pallas elides the pruned blocks' DMAs, and ``pl.when`` skips their
    compute.  Bit-exact vs ``prune=False``; per-request HBM traffic becomes
    O(valid_len) instead of O(S_cap).  ``flash_decode_accounting`` reports
    the resulting blocks/bytes per call.

Padded S slots are masked in-kernel against the true capacity (prefetch-free:
it is a static kernel parameter), so any S_cap works in both layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import round_up, pad_dim
from repro.kernels.flash_decode.kernel import (flash_decode_kernel,
                                               prune_block_range)


@functools.partial(
    jax.jit,
    static_argnames=("kvp", "rr_block", "scale", "block_s", "interpret",
                     "contiguous", "prune"))
def flash_decode(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                 window=0, scale: float | None = None, block_s: int = 512,
                 interpret: bool = True, contiguous: bool = False,
                 slot_offset=0, kscale=None, vscale=None,
                 k_new=None, v_new=None, prune: bool = True,
                 block_tables=None):
    """Decode-shape attention over one KV shard via the Pallas kernel.

    This is the flash_decode *family* entry point the kernel-backend
    registry routes to (``HelixConfig.attn_backend``); see the module
    docstring for the full mode lattice and ``flash_decode_ref`` for the
    oracle that defines the semantics.

    Paged mode: with ``block_tables`` ([B, max_pages] int32) the K/V
    operands are shared pool planes ``[n_pool, Kh, page_s, hsz]``
    (``kscale``/``vscale`` become ``[n_pool, Kh, page_s]``): request ``b``'s
    logical local slots ``[p*page_s, (p+1)*page_s)`` live in physical pool
    page ``block_tables[b, p]``.  The kernel's S-block size is pinned to
    ``page_s`` and the index_maps stream through the prefetched table
    (bit-exact vs the fixed layout at the same block size; pruning, quant
    and the fused append all compose).  Unallocated table entries should
    point at the reserved sink page 0.

    Returns ``(out [B, Qh, hsz], lse [B, Qh] f32)``, plus the appended
    ``(kcache, vcache)`` when ``k_new``/``v_new`` engage the fused-append
    epilogue (and the updated ``(kscale, vscale)`` for int8 caches) — pool
    planes in paged mode.
    """
    b, qh, hsz = q.shape
    kh = k.shape[1]
    paged = block_tables is not None
    assert qh % kh == 0, (qh, kh)
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5
    quant = kscale is not None
    append = k_new is not None
    if append:
        assert v_new is not None and not contiguous
        # slot_offset may reach here as a (weak) tracer under an outer jit;
        # only a concrete non-zero value can be rejected eagerly.  The Helix
        # caller guarantees the slice fast path and fusion never overlap
        # (core/helix.fuse_append_applicable).
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "fused append excludes the sliding-window cache-slice fast path"
    if paged:
        assert not contiguous, "paged mode excludes the contiguous layout"
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "paged mode excludes the cache-slice fast path"
        # page size is the kernel block; logical capacity spans the table
        block_s = k.shape[2]
        s_cap = block_tables.shape[1] * block_s
        kp, vp = k, v
        tables = jnp.asarray(block_tables, jnp.int32)
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        kp = pad_dim(k, 2, block_s)
        vp = pad_dim(v, 2, block_s)
        tables = None
    qp = round_up(g, 8)

    qg = q.reshape(b, kh, g, hsz)
    qg = pad_dim(qg, 2, qp)
    if kscale is not None:
        kscale = kscale.astype(jnp.float32)
        vscale = vscale.astype(jnp.float32)
        if not paged:
            kscale = pad_dim(kscale, 2, block_s)
            vscale = pad_dim(vscale, 2, block_s)

    meta = jnp.stack([jnp.asarray(rank, jnp.int32),
                      jnp.asarray(slot_offset, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    tl = jnp.asarray(total_len, jnp.int32).reshape(-1)     # scalar -> [1]
    tl = jnp.broadcast_to(tl, (b,))

    kw = {}
    if append:
        if quant:
            # the kernel quantizes the raw rows itself (payload + scale)
            kw = dict(k_new=k_new.astype(jnp.float32),
                      v_new=v_new.astype(jnp.float32))
        else:
            # match the unfused append_kv dtype cast so fusion is bit-exact
            kw = dict(k_new=k_new.astype(k.dtype), v_new=v_new.astype(v.dtype))

    res = flash_decode_kernel(
        qg, kp, vp, meta, tl, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_cap, contiguous=contiguous,
        kscale=kscale, vscale=vscale, prune=prune, block_tables=tables,
        interpret=interpret, **kw)

    out, lse = res[0], res[1]
    out = out[:, :, :g, :].reshape(b, qh, hsz)
    lse = lse[:, :, :g].reshape(b, qh)
    if append:
        if paged:
            return (out, lse) + tuple(res[2:])
        kc, vc = res[2][:, :, :s_cap], res[3][:, :, :s_cap]
        if quant:
            return out, lse, kc, vc, res[4][:, :, :s_cap], res[5][:, :, :s_cap]
        return out, lse, kc, vc
    return out, lse


def flash_decode_accounting(q, k, v, total_len, rank, *, kvp: int = 1,
                            rr_block: int = 16, window=0,
                            block_s: int = 512, contiguous: bool = False,
                            slot_offset=0, prune: bool = True,
                            kscale=None, vscale=None, block_tables=None,
                            **_ignored):
    """Blocks/bytes the matching ``flash_decode`` call streams from HBM.

    Replays the kernel's pruning ``index_map`` (``prune_block_range`` — the
    same function the kernel clamps its K/V DMAs with) over the grid and
    counts *distinct* block fetches: consecutive grid steps that reference
    the same block are one DMA on TPU, which is exactly how pruning turns
    masked blocks into elided reads.  ``prune=False`` reproduces the dense
    sweep (every block of every (b, h) pair).

    Paged mode (``block_tables`` [B, max_pages]): ``k``/``v`` are pool
    planes ``[n_pool, Kh, page_s, hsz]``; the replay walks the same logical
    page ranges through the table — a request's pages are distinct physical
    planes, so the distinct-fetch count (and the prune bound
    ``<= ceil(valid_len/block_s) + 1`` per (b, h)) is unchanged by the
    indirection, only ``block_s`` is pinned to the page size.

    Pure host-side arithmetic — no kernel launch, any argument set accepted
    by ``flash_decode`` works (extra kwargs are ignored), and ``q``/``k``/
    ``v`` may be ``jax.ShapeDtypeStruct``s (only shapes/dtypes are read).
    Returns a dict:

      ``blocks_visited`` / ``blocks_total`` — distinct K/V block DMAs vs the
      dense sweep, summed over the (B, Kh, S-blocks) grid;
      ``bytes_read`` / ``bytes_total`` — the corresponding K+V HBM bytes
      (+ dequant-scale bytes in int8 mode);
      ``block_s``, ``n_blocks`` — resolved kernel blocking.
    """
    paged = block_tables is not None
    kh, hsz = k.shape[1], k.shape[3]
    b = q.shape[0]
    if paged:
        block_s = k.shape[2]                       # page size is the block
        n_blocks = np.shape(block_tables)[1]       # logical pages
        s_cap = n_blocks * block_s
    else:
        s_cap = k.shape[2]
        block_s = min(block_s, round_up(s_cap, 128))
        s_pad = round_up(s_cap, block_s)
        n_blocks = s_pad // block_s

    tl = np.broadcast_to(np.asarray(total_len, np.int32).reshape(-1), (b,))
    if prune:
        _, nb = prune_block_range(
            jnp.asarray(tl), jnp.asarray(rank, jnp.int32),
            jnp.asarray(slot_offset, jnp.int32), jnp.asarray(window, jnp.int32),
            kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_cap,
            contiguous=contiguous)
        # a fully-pruned request still references one (clamped) block: the
        # grid's first step fetches it before pl.when skips the compute
        per_req = np.maximum(np.asarray(nb), 1)
    else:
        per_req = np.full((b,), n_blocks)
    blocks_visited = int(kh * per_req.sum())
    blocks_total = b * kh * n_blocks
    el = jnp.dtype(k.dtype).itemsize
    blk_bytes = 2 * block_s * hsz * el                    # K + V payload
    if kscale is not None:
        blk_bytes += 2 * block_s * 4                      # f32 dequant scales
    return {
        "blocks_visited": blocks_visited,
        "blocks_total": blocks_total,
        "bytes_read": blocks_visited * blk_bytes,
        "bytes_total": blocks_total * blk_bytes,
        "block_s": block_s,
        "n_blocks": n_blocks,
    }
