"""jit'd public wrapper for the flash_decode Pallas kernel.

Handles layout (Q-head grouping for GQA), padding (Q-group to sublane multiple,
S to block multiple) and un-padding, so callers use natural shapes:

    out, lse = flash_decode(q, k, v, total_len, rank, kvp=..., ...)

    q      [B, Qh, hsz]
    k, v   [B, Kh, S_cap, hsz]     (Qh % Kh == 0)
    out    [B, Qh, hsz]            lse [B, Qh] f32

Covers everything core/helix.py::_local_attend needs (the kernel is the real
Helix execution path when ``HelixConfig.attn_backend`` selects it):

  * ``total_len`` — scalar or per-request [B] int32 (continuous batching);
    prefetched as a length vector, one entry per batch row.
  * ``contiguous`` — non-round-robin shard layout (whisper cross-attention):
    local slot j holds global position rank*S_cap + j.
  * ``slot_offset`` — the sliding-window cache-slice fast path: positions are
    computed for slot j + slot_offset.
  * ``window`` — runtime sliding-window scalar (<= 0 disables); may be a
    traced per-layer value.
  * ``kscale``/``vscale`` [B, Kh, S_cap] — int8 K/V cache mode: dequant
    happens inside the kernel, block-by-block in VMEM.
  * ``k_new``/``v_new`` [B, Kh, hsz] — fused KV-append epilogue: the kernel
    writes the new token's row into the (aliased) cache and attends over it,
    so the separate ``append_kv`` cache round-trip disappears.  Requires the
    round-robin layout without quant/slot_offset; ``total_len`` must already
    count the appended token.  Returns ``(out, lse, kcache, vcache)``.

Padded S slots are masked in-kernel against the true capacity (prefetch-free:
it is a static kernel parameter), so any S_cap works in both layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import round_up, pad_dim
from repro.kernels.flash_decode.kernel import flash_decode_kernel


@functools.partial(
    jax.jit,
    static_argnames=("kvp", "rr_block", "scale", "block_s", "interpret",
                     "contiguous"))
def flash_decode(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                 window=0, scale: float | None = None, block_s: int = 512,
                 interpret: bool = True, contiguous: bool = False,
                 slot_offset=0, kscale=None, vscale=None,
                 k_new=None, v_new=None):
    """Decode-shape attention over one KV shard via the Pallas kernel.

    This is the flash_decode *family* entry point the kernel-backend
    registry routes to (``HelixConfig.attn_backend``); see the module
    docstring for the full mode lattice and ``flash_decode_ref`` for the
    oracle that defines the semantics.

    Returns ``(out [B, Qh, hsz], lse [B, Qh] f32)``, plus the appended
    ``(kcache, vcache)`` when ``k_new``/``v_new`` engage the fused-append
    epilogue.
    """
    b, qh, hsz = q.shape
    kh, s_cap = k.shape[1], k.shape[2]
    assert qh % kh == 0, (qh, kh)
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5
    append = k_new is not None
    if append:
        assert v_new is not None and kscale is None and not contiguous
        # slot_offset may reach here as a (weak) tracer under an outer jit;
        # only a concrete non-zero value can be rejected eagerly.  The Helix
        # caller guarantees the slice fast path and fusion never overlap
        # (core/helix.fuse_append_applicable).
        assert not (isinstance(slot_offset, int) and slot_offset != 0), \
            "fused append excludes the sliding-window cache-slice fast path"

    block_s = min(block_s, round_up(s_cap, 128))
    qp = round_up(g, 8)

    qg = q.reshape(b, kh, g, hsz)
    qg = pad_dim(qg, 2, qp)
    kp = pad_dim(k, 2, block_s)
    vp = pad_dim(v, 2, block_s)
    if kscale is not None:
        kscale = pad_dim(kscale.astype(jnp.float32), 2, block_s)
        vscale = pad_dim(vscale.astype(jnp.float32), 2, block_s)

    meta = jnp.stack([jnp.asarray(rank, jnp.int32),
                      jnp.asarray(slot_offset, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    tl = jnp.asarray(total_len, jnp.int32).reshape(-1)     # scalar -> [1]
    tl = jnp.broadcast_to(tl, (b,))

    kw = {}
    if append:
        # match the unfused append_kv dtype cast so fusion is bit-exact
        kw = dict(k_new=k_new.astype(k.dtype), v_new=v_new.astype(v.dtype))

    res = flash_decode_kernel(
        qg, kp, vp, meta, tl, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_cap, contiguous=contiguous,
        kscale=kscale, vscale=vscale, interpret=interpret, **kw)

    out, lse = res[0], res[1]
    out = out[:, :, :g, :].reshape(b, qh, hsz)
    lse = lse[:, :, :g].reshape(b, qh)
    if append:
        kc, vc = res[2][:, :, :s_cap], res[3][:, :, :s_cap]
        return out, lse, kc, vc
    return out, lse
