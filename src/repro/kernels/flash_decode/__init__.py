"""Flash-decode: the Helix attention-phase kernel (paper §2.1 hotspot).

Three interchangeable implementations of one contract:

  * ``ref.flash_decode_ref``  — pure-jnp oracle (two-pass softmax)
  * ``ops.flash_decode``      — the Pallas TPU kernel (online softmax),
    interpreted (``interpret=True``, runs on any backend) or compiled

Which one the model path uses is the ``HelixConfig.attn_backend`` knob
(core/sharding.py): ``"ref"`` | ``"pallas-interpret"`` | ``"pallas"``,
plumbed through models/decode_model.py::build_serve_step(attn_backend=...),
launch/serve.py ``--attn-backend`` and serving/engine.py.  All backends are
exact up to fp summation order; tests/kernels/test_flash_decode_parity.py
sweeps the full mode lattice.

The contract (shared by kernel and ref)
---------------------------------------
Inputs q [B, Qh, hsz]; k, v [B, Kh, S_cap, hsz] — one KV *shard*; outputs the
softmax-normalized partial attention out [B, Qh, hsz] plus this shard's
log-sum-exp [B, Qh] f32 (NEG_INF for empty shards), which the Helix combine
(core/combine.py) needs for the exact cross-shard rescale-sum.

Masking is computed in-kernel from prefetched scalars only — the kernel never
reads a per-slot position array from HBM:

  * meta [3] int32 = (rank, slot_offset, window).  Round-robin layout (§2.3):
    slot j holds global position ((j//rr)*kvp + rank)*rr + j%rr; contiguous
    layout (``contiguous=True``, whisper cross-attention): rank*S_cap + j.
    ``slot_offset`` shifts j (the sliding-window cache-slice fast path);
    ``window`` is a *runtime* scalar (<= 0 disables) so traced per-layer
    windows work.
  * tl [B] int32 = per-request global lengths (continuous batching); scalar
    total_len is prefetched as a broadcast vector.  A slot is valid iff
    pos < tl[b] (and pos >= tl[b] - window when windowed).
  * Slots j >= the true (unpadded) capacity are masked unconditionally, so
    S padding is exact in both layouts.

int8 KV cache (§Perf kv8): pass k/v as int8 with kscale/vscale [B, Kh, S_cap]
f32; dequant happens block-by-block in VMEM, so the f32 copy of the shard
never materializes in HBM.

Benchmark: benchmarks/bench_decode_kernel.py (ref vs kernel over S).
"""
from repro.kernels.flash_decode.ops import (flash_decode,
                                            flash_decode_accounting)
from repro.kernels.flash_decode.kernel import prune_block_range
from repro.kernels.flash_decode.ref import (
    flash_decode_ref, shard_positions, local_valid_len)

__all__ = ["flash_decode", "flash_decode_accounting", "flash_decode_ref",
           "prune_block_range", "shard_positions", "local_valid_len"]
