from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import (
    flash_decode_ref, shard_positions, local_valid_len)

__all__ = ["flash_decode", "flash_decode_ref", "shard_positions",
           "local_valid_len"]
