"""Pallas TPU flash-decode kernel (Helix attention phase hotspot).

Decode-shape attention: one new query token per sequence against a (possibly
round-robin-sharded) KV cache shard.  Emits the partial output *and* the
log-sum-exp — the Helix combine (core/combine.py) needs both.

TPU mapping
-----------
  grid = (B, Kh, S_cap / block_s)   — S blocks iterated innermost so the
                                      online-softmax state lives in VMEM scratch
  q block   (1, 1, Qp, hsz)  : the Qp = padded Q-per-KV-head group, resident
  k/v block (1, 1, bs, hsz)  : streamed HBM->VMEM, bs a multiple of 128 (MXU)
  scale blk (1, 1, bs)       : int8-cache dequant scales (quant mode only)
  scratch   acc f32 (Qp,hsz), m/l f32 (Qp,1)

The two matmuls per block — (Qp,hsz)@(hsz,bs) and (Qp,bs)@(bs,hsz) — keep the
MXU contraction dims at hsz/bs multiples of 128 (hsz=64 archs pad lanes
internally).  VMEM footprint per step: 2*bs*hsz*2B (K,V) + Qp*hsz*4B + O(Qp),
e.g. bs=512, hsz=128: ~288 KiB — far under the ~16 MiB/core VMEM budget, so the
grid pipeline can double-buffer the K/V streams.

Masking semantics match ref.py and are computed in-kernel from prefetched
scalars only — no per-slot position array is read from HBM:

  meta [3] int32 : (rank, slot_offset, window) — slot_offset shifts the local
                   slot index (the sliding-window cache-slice fast path);
                   window <= 0 disables the sliding-window mask, and is a
                   *runtime* scalar so traced per-layer windows work.
  tl   [B] int32 : per-request global sequence lengths (continuous batching);
                   uniform batches prefetch a broadcast scalar.

Layouts: round-robin (§2.3) pos = ((j//rr)*kvp + rank)*rr + j%rr, or
contiguous (whisper cross-attention KV split) pos = rank*S_true + j.  Slots
j >= S_true (the unpadded local capacity) are masked unconditionally, so S
padding is exact in both layouts.

Block pruning (``prune=True``, the default)
-------------------------------------------
Positions are strictly increasing in the local slot index in *both* layouts,
so the valid slots of a request form one contiguous span ``[jj_lo, jj_hi)``
(``jj_lo > 0`` only with a sliding window).  Instead of sweeping the full
padded capacity and masking dead blocks, the kernel

  1. clamps the K/V (and scale) ``index_map`` to that span — grid step ``s``
     streams physical block ``min(lo + s, hi - 1)``, so every pruned step
     references the block of the previous step and Pallas TPU elides the
     HBM->VMEM DMA entirely;
  2. skips the compute body of pruned steps with ``pl.when``.

Per-step HBM traffic drops from O(S_cap) to O(valid_len) per request —
O(window) for sliding-window layers, which subsumes the caller-side
dynamic-slice fast path (``slot_offset``) and composes with every other mode
(per-request lengths, contiguous layout, quant, fused append).  Pruned and
unpruned results are bit-identical: a fully-masked block contributes the
identity online-softmax update.  ``prune_block_range`` is the single source
of truth for the span; the block-accounting layer (ops.py) replays it to
report blocks/bytes actually streamed.

Quant mode (§Perf kv8): K/V arrive int8 with per-(B, Kh, slot) f32 scales and
are dequantized block-by-block in VMEM — the f32 copy of the shard never
exists in HBM.

Fused KV-append epilogue (append mode)
--------------------------------------
The rr-slot ``append_kv`` update is fused into the kernel: the caller passes
the *pre-append* cache plus the new token's K/V row, and the kernel

  1. substitutes the new row into the streamed K/V tile in VMEM for the
     attention compute (the HBM block containing the target slot is stale),
  2. writes the row back to the cache through a (1, 1, 1, hsz) output window
     whose index_map derives the target slot from the prefetched per-request
     lengths — ``input_output_aliases`` makes these outputs *the same HBM
     buffers* as the K/V inputs, so the rest of the cache is untouched and
     the separate append pass (one full-cache HBM round-trip per layer per
     decode step) disappears.

The row window is re-written (idempotently) at every S-block step, so the
kernel is correct under both write-back policies Pallas implementations use
(every visit, or last visit only).  Non-owner ranks (round-robin: the new
position lives on exactly one KVP rank) write back the unmodified row read
through a matching (1, 1, 1, hsz) *input* window.  Append mode composes with
per-request [B] lengths (each row appends at its own slot) but excludes the
contiguous layout (static cross-attention KV is never appended) and the
``slot_offset`` cache-slice path — the Helix caller falls back to the
unfused ``append_kv`` there (core/helix.py).

int8 append (append + quant): the new token's row arrives *unquantized*
(f32); the kernel quantizes it in VMEM with the same per-(B, Kh) symmetric
formula as ``core/helix.quantize_kv_token`` (scale = max|x|/127, round,
clip) and persists payload + scale through aliased (1, 1, 1, hsz) / (1, 1, 1)
row windows, so the fused path is bit-exact with ``append_kv_quant`` followed
by the attention pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import NEG_INF
from repro.kernels.flash_decode.ref import local_valid_len
from repro.kernels.pruning import phys_block as _phys_block
from repro.kernels.pruning import table_block as _table_block


def _append_slot(total_len, kvp: int, rr_block: int, s_max: int):
    """Local rr slot of the appended token (position total_len - 1), clamped
    to the padded capacity.  Rank-independent (same formula on every rank);
    ownership is a separate check."""
    pos = total_len - 1
    blk = pos // rr_block
    j = (blk // kvp) * rr_block + pos % rr_block
    return jnp.clip(j, 0, s_max - 1)


def _quantize_row(x):
    """In-kernel mirror of ``core/helix.quantize_kv_token`` for one [hsz]
    f32 row: (int8-valued f32 payload, f32 scale).  Must stay formula-exact
    with the host-side version so fused int8 append is bit-identical."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q, scale


def valid_slot_span(total_len, rank, slot_offset, window, *, kvp: int,
                    rr_block: int, s_true: int, contiguous: bool):
    """``[jj_lo, jj_hi)`` — the physical-slot span that can hold unmasked
    slots for one request.

    Positions are strictly increasing in the local slot index in both
    layouts, so ``pos < total_len`` bounds a prefix and (with a window)
    ``pos >= total_len - window`` bounds a suffix; their intersection is one
    contiguous span.  All arguments may be traced scalars (this runs inside
    Pallas ``index_map``s against prefetched scalars).
    """
    total_len = jnp.maximum(jnp.asarray(total_len, jnp.int32), 0)
    window = jnp.asarray(window, jnp.int32)
    if contiguous:
        j_hi = total_len - rank * s_true
        j_lo = total_len - window - rank * s_true
    else:
        j_hi = local_valid_len(total_len, rank, kvp, rr_block)
        j_lo = local_valid_len(jnp.maximum(total_len - window, 0), rank, kvp,
                               rr_block)
    jj_hi = jnp.clip(j_hi - slot_offset, 0, s_true)
    jj_lo = jnp.where(window > 0, jnp.clip(j_lo - slot_offset, 0, s_true), 0)
    return jj_lo, jj_hi


def prune_block_range(total_len, rank, slot_offset, window, *, kvp: int,
                      rr_block: int, block_s: int, s_true: int,
                      contiguous: bool = False):
    """(first_block, n_valid_blocks) of the S-block span a request can touch.

    The single source of truth for decode block pruning: the kernel's K/V
    ``index_map``s clamp to this range (so pruned grid steps re-reference the
    previous block and the DMA is elided), the kernel body skips compute
    outside it, and ``ops.flash_decode_accounting`` replays it to count the
    blocks/bytes actually streamed.
    """
    jj_lo, jj_hi = valid_slot_span(total_len, rank, slot_offset, window,
                                   kvp=kvp, rr_block=rr_block, s_true=s_true,
                                   contiguous=contiguous)
    lo = jj_lo // block_s
    hi = (jj_hi + block_s - 1) // block_s
    return lo, jnp.maximum(hi - lo, 0)


def decode_index_maps(*, kvp: int, rr_block: int, block_s: int, s_true: int,
                      n_blocks: int, contiguous: bool, prune: bool,
                      paged: bool, grouped: bool = False):
    """Named index_map callables for one decode-kernel configuration.

    The single source of truth for the kernel's DMA addressing:
    ``flash_decode_kernel`` passes exactly these callables to
    ``pallas_call``, and ``ops.flash_decode_contract`` exposes the same
    callables to the static index-space auditor (``repro.analysis``), so
    what the auditor proves is what the kernel runs.

    Every map takes ``(b, h, s, meta_ref, tl_ref, [tables_ref])`` — the
    grid coordinates then the scalar-prefetch operands — and is a pure jnp
    function of them (no data-dependent python branches; see
    ``kernels/pruning.py``).  Keys:

      kv     streamed K/V blocks (1, 1, block_s, hsz); prune-clamped, and
             table-indirected in paged mode
      scale  streamed dequant-scale blocks (1, 1, block_s); same clamp
      row    fused-append (1, 1, 1, hsz) row window of the new token
      srow   fused-append (1, 1, 1) scale-row window
      q      resident query block (constant along the S axis)
      new    the new token's (1, 1, hsz) K/V row (resident)
      lse    the [B, Kh, Qp] log-sum-exp output

    ``grouped`` (suffix pass of the shared-prefix grouped decode — paged
    only): a fourth prefetch operand ``start [B]`` gives each request's
    first *unshared* logical page; the pruned span's lower bound is lifted
    to it, so the shared prefix pages — already streamed once per group by
    the prefix pass (``grouped_prefix_index_maps``) — are never re-read
    per request.  Maps then take ``(b, h, s, meta, tl, tables, start)``.
    """
    s_pad = n_blocks * block_s
    assert not grouped or paged, "grouped suffix maps require paged mode"

    def logical_block(s, meta_ref, tl_ref, b, *rest):
        # pruned steps re-reference the previous step's block: the DMA is
        # elided, so HBM reads scale with the valid length, not capacity
        if not prune:
            return s
        lo, nb = prune_block_range(
            tl_ref[b], meta_ref[0], meta_ref[1], meta_ref[2], kvp=kvp,
            rr_block=rr_block, block_s=block_s, s_true=s_true,
            contiguous=contiguous)
        if grouped:
            # suffix pass: blocks below the request's shared-prefix extent
            # were streamed by the prefix pass — lift the span above them
            lo2 = jnp.maximum(lo, rest[1][b])
            nb = jnp.maximum(lo + nb - lo2, 0)
            lo = lo2
        return _phys_block(s, lo, nb, n_blocks)

    def kv_idx(b, h, s, meta_ref, tl_ref, *rest):
        # paged: the physical pool page comes from the prefetched table at
        # the (clamped) logical id — same id as the fixed layout, so the
        # DMA-elision property survives the indirection (pruning.table_block)
        lg = logical_block(s, meta_ref, tl_ref, b, *rest)
        if paged:
            return (rest[0][b, lg], h, 0, 0)
        return (b, h, lg, 0)

    def scale_idx(b, h, s, meta_ref, tl_ref, *rest):
        return kv_idx(b, h, s, meta_ref, tl_ref, *rest)[:3]

    def row_idx(b, h, s, meta_ref, tl_ref, *rest):
        # target row window of the appended token; depends on the prefetched
        # per-request length only (rank-independent slot formula)
        j_new = _append_slot(tl_ref[b], kvp, rr_block, s_pad)
        if paged:
            return (rest[0][b, j_new // block_s], h, j_new % block_s, 0)
        return (b, h, j_new, 0)

    def srow_idx(b, h, s, meta_ref, tl_ref, *rest):
        return row_idx(b, h, s, meta_ref, tl_ref, *rest)[:3]

    def q_idx(b, h, s, *_):
        return (b, h, 0, 0)

    def new_idx(b, h, s, *_):
        return (b, h, 0)

    def lse_idx(b, h, s, *_):
        return (b, h, 0)

    return {"kv": kv_idx, "scale": scale_idx, "row": row_idx,
            "srow": srow_idx, "q": q_idx, "new": new_idx, "lse": lse_idx}


def _decode_kernel(meta_ref, tl_ref, *refs, scale: float,
                   kvp: int, rr_block: int, block_s: int, s_true: int,
                   contiguous: bool, quant: bool, append: bool, prune: bool,
                   paged: bool, grouped: bool = False):
    if paged:
        tbl_ref, *refs = refs
    if grouped:
        # suffix pass of the grouped shared-prefix decode: one more prefetch
        # operand (per-request first unshared page) plus the prefix pass's
        # raw online-softmax state, resumed instead of a cold init.
        start_ref, *refs = refs
        acc0_ref, m0_ref, l0_ref, *refs = refs
    q_ref, k_ref, v_ref, *rest = refs
    if append and quant:
        (kscale_ref, vscale_ref, knew_ref, vnew_ref,
         krow_in_ref, vrow_in_ref, ksrow_in_ref, vsrow_in_ref,
         o_ref, lse_ref, krow_out_ref, vrow_out_ref,
         ksrow_out_ref, vsrow_out_ref, acc_ref, m_ref, l_ref) = rest
    elif append:
        (knew_ref, vnew_ref, krow_in_ref, vrow_in_ref, o_ref, lse_ref,
         krow_out_ref, vrow_out_ref, acc_ref, m_ref, l_ref) = rest
    elif quant:
        kscale_ref, vscale_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    bi = pl.program_id(0)
    si = pl.program_id(2)
    n_blocks = pl.num_programs(2)
    rank = meta_ref[0]
    slot_offset = meta_ref[1]
    window = meta_ref[2]
    total_len = tl_ref[bi]

    @pl.when(si == 0)
    def _init():
        if grouped:
            # resume the prefix pass's raw state: blocks < start were
            # already accumulated once per group, in the same block order
            # the ungrouped kernel would have used, so continuing the
            # online softmax from here is bit-exact.
            acc_ref[...] = acc0_ref[0, 0]
            m_ref[...] = m0_ref[0, 0][:, None]
            l_ref[...] = l0_ref[0, 0][:, None]
        else:
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

    if prune:
        lo_blk, nb = prune_block_range(
            total_len, rank, slot_offset, window, kvp=kvp, rr_block=rr_block,
            block_s=block_s, s_true=s_true, contiguous=contiguous)
        if grouped:
            # shared-prefix blocks were streamed by the prefix pass; lift
            # the span above them (mirrors decode_index_maps grouped clamp)
            lo2 = jnp.maximum(lo_blk, start_ref[bi])
            nb = jnp.maximum(lo_blk + nb - lo2, 0)
            lo_blk = lo2
        phys = _phys_block(si, lo_blk, nb, n_blocks)
        active = si < nb
    elif grouped:
        phys, active = si, si >= start_ref[bi]
    else:
        phys, active = si, None

    if append:
        # epilogue: derive the new token's slot/ownership, quantize in quant
        # mode, and persist the row through the aliased (1,1,1,hsz) output
        # windows (idempotent re-write each S step — correct under both
        # write-back policies; non-owners restore the row they read).
        j_new = _append_slot(total_len, kvp, rr_block, n_blocks * block_s)
        owner = (((total_len - 1) // rr_block) % kvp) == rank
        kn = knew_ref[0, 0]                              # [hsz]
        vn = vnew_ref[0, 0]
        if quant:
            kn, ks_new = _quantize_row(kn)               # int8-valued f32
            vn, vs_new = _quantize_row(vn)
            ksrow_out_ref[0, 0, 0] = jnp.where(owner, ks_new,
                                               ksrow_in_ref[0, 0, 0])
            vsrow_out_ref[0, 0, 0] = jnp.where(owner, vs_new,
                                               vsrow_in_ref[0, 0, 0])
        krow_out_ref[0, 0, 0] = jnp.where(
            owner, kn.astype(krow_out_ref.dtype), krow_in_ref[0, 0, 0])
        vrow_out_ref[0, 0, 0] = jnp.where(
            owner, vn.astype(vrow_out_ref.dtype), vrow_in_ref[0, 0, 0])

    def _compute():
        kraw = k_ref[0, 0]                               # [bs, hsz] cache dt
        vraw = v_ref[0, 0]
        if quant:
            kscale = kscale_ref[0, 0]                    # [bs] f32
            vscale = vscale_ref[0, 0]
        if append:
            # substitute the new token's row into the VMEM tile (the
            # streamed HBM block is pre-append); in quant mode the
            # quantized payload + scale are substituted so fusion stays
            # bit-exact with append-then-attend.
            local = j_new - phys * block_s
            rows = jax.lax.broadcasted_iota(jnp.int32, (block_s, 1), 0)
            hit = jnp.logical_and(owner, rows == local)
            kraw = jnp.where(hit, kn[None, :].astype(kraw.dtype), kraw)
            vraw = jnp.where(hit, vn[None, :].astype(vraw.dtype), vraw)
            if quant:
                kscale = jnp.where(hit[:, 0], ks_new, kscale)
                vscale = jnp.where(hit[:, 0], vs_new, vscale)

        q = q_ref[0, 0].astype(jnp.float32) * scale      # [Qp, hsz]
        k = kraw.astype(jnp.float32)                     # [bs, hsz]
        v = vraw.astype(jnp.float32)
        if quant:
            k = k * kscale[:, None]
            v = v * vscale[:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [Qp,bs]

        # Global positions of this block's slots (computed, not read).  jj is
        # the physical (possibly padded) slot index; j the logical one after
        # the sliding-window slice offset.
        jj = phys * block_s + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_s), 1)
        j = jj + slot_offset
        if contiguous:
            pos = rank * s_true + j
        else:
            pos = ((j // rr_block) * kvp + rank) * rr_block + (j % rr_block)
        mask = jnp.logical_and(jj < s_true, pos < total_len)
        mask = jnp.logical_and(
            mask, jnp.where(window > 0, pos >= total_len - window, True))

        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # [Qp, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # exp(NEG_INF - NEG_INF)=1 is harmless (l, acc still 0); but masked
        # lanes must not contribute when m_new == NEG_INF, so gate p.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)      # [Qp, bs]
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if active is not None:
        pl.when(active)(_compute)
    else:
        _compute()

    @pl.when(si == n_blocks - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-37)
        o_ref[0, 0] = jnp.where(l > 0, acc_ref[...] / denom, 0.0).astype(o_ref.dtype)
        lse = jnp.where(l[:, 0] > 0, m_ref[:, 0] + jnp.log(denom[:, 0]), NEG_INF)
        lse_ref[0, 0] = lse.astype(jnp.float32)


def flash_decode_kernel(q, k, v, meta, tl, *, scale: float, kvp: int,
                        rr_block: int, block_s: int, s_true: int,
                        contiguous: bool = False, kscale=None, vscale=None,
                        k_new=None, v_new=None, prune: bool = True,
                        block_tables=None, sfx_start=None, init_state=None,
                        interpret: bool = True):
    """Raw pallas_call.  Shapes must already be padded/blocked (see ops.py).

    q: [B, Kh, Qp, hsz]; k, v: [B, Kh, S_pad, hsz]; meta: [3] int32
    (rank, slot_offset, window); tl: [B] int32 per-request lengths;
    kscale/vscale: [B, Kh, S_pad] f32 (int8-cache mode — k/v are int8);
    k_new/v_new: [B, Kh, hsz] — fused-append mode (excludes contiguous; tl
    must already include the appended token).  fp caches take k_new in the
    cache dtype; int8 caches take the *unquantized* f32 row and quantize it
    in-kernel (payload + per-(B,Kh) scale written through aliased windows).
    s_true: unpadded local capacity (slots >= s_true are masked).
    prune: skip fully-invalid S blocks (index_map clamp + pl.when) instead
    of masking them — bit-exact either way.

    Paged mode (``block_tables`` [B, max_pages] int32, scalar-prefetched):
    k/v are shared *pool* planes ``[n_pool, Kh, block_s, hsz]`` (scales
    ``[n_pool, Kh, block_s]``) instead of per-request rows; grid step ``s``
    streams physical page ``block_tables[b, logical]`` where ``logical`` is
    exactly the fixed layout's (possibly prune-clamped) block id
    (kernels/pruning.table_block).  All masking/position math runs on the
    logical ids, so paged vs fixed is bit-exact; pruning composes (the
    valid-span clamp walks table entries, keeping DMA elision).  The fused
    append writes its row windows through the table too; outputs alias the
    pool planes.  Excludes the contiguous layout and ``slot_offset``.

    Grouped suffix mode (``sfx_start`` [B] int32 + ``init_state`` — paged
    only): this call becomes the *suffix* pass of the grouped shared-prefix
    decode.  ``init_state = (acc0 [B,Kh,Qp,hsz], m0 [B,Kh,Qp], l0
    [B,Kh,Qp])`` f32 is the per-request unstacked raw state from
    ``prefix_pass_kernel`` and seeds the online softmax at the first grid
    step; blocks below ``sfx_start[b]`` are skipped (prune mode lifts the
    span clamp, so the prefix pages' DMAs stay elided).  Because the prefix
    pass visits blocks ``0..start-1`` in the same order and with the same
    masks as the ungrouped kernel, resuming here is bit-exact with a plain
    ungrouped call.

    returns out [B, Kh, Qp, hsz] (q.dtype), lse [B, Kh, Qp] (f32), plus the
    appended caches (aliased with k, v — pool planes in paged mode) and, in
    int8 append mode, the updated kscale, vscale.
    """
    b, kh, qp, hsz = q.shape
    paged = block_tables is not None
    quant = kscale is not None
    assert quant == (vscale is not None)
    append = k_new is not None
    assert append == (v_new is not None)
    assert not (append and contiguous), \
        "fused append excludes the contiguous layout"
    grouped = sfx_start is not None
    assert grouped == (init_state is not None)
    assert not grouped or paged, "grouped suffix mode requires paged mode"
    if paged:
        assert not contiguous, "paged mode excludes the contiguous layout"
        assert k.shape[2] == block_s, (k.shape, block_s)
        n_blocks = block_tables.shape[1]          # logical pages per request
        s_pad = n_blocks * block_s                # logical local capacity
    else:
        s_pad = k.shape[2]
        assert s_pad % block_s == 0
        n_blocks = s_pad // block_s
    assert qp % 8 == 0

    grid = (b, kh, n_blocks)
    kernel = functools.partial(
        _decode_kernel, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_true, contiguous=contiguous, quant=quant,
        append=append, prune=prune, paged=paged, grouped=grouped)

    idx = decode_index_maps(
        kvp=kvp, rr_block=rr_block, block_s=block_s, s_true=s_true,
        n_blocks=n_blocks, contiguous=contiguous, prune=prune, paged=paged,
        grouped=grouped)
    q_idx, kv_idx, scale_idx = idx["q"], idx["kv"], idx["scale"]
    row_idx, srow_idx = idx["row"], idx["srow"]

    in_specs = []
    args = (meta, tl) + ((block_tables,) if paged else ())
    if grouped:
        # the prefix pass's raw state rides in *before* q so the q/k/v
        # positions (and the append aliases below) shift by exactly three
        acc0, m0, l0 = init_state
        args += (sfx_start,)
        in_specs += [
            pl.BlockSpec((1, 1, qp, hsz), q_idx),
            pl.BlockSpec((1, 1, qp), idx["lse"]),
            pl.BlockSpec((1, 1, qp), idx["lse"]),
        ]
    in_specs += [
        pl.BlockSpec((1, 1, qp, hsz), q_idx),
        pl.BlockSpec((1, 1, block_s, hsz), kv_idx),
        pl.BlockSpec((1, 1, block_s, hsz), kv_idx),
    ]
    if grouped:
        args += (acc0.astype(jnp.float32), m0.astype(jnp.float32),
                 l0.astype(jnp.float32), q, k, v)
    else:
        args += (q, k, v)
    out_specs = [
        pl.BlockSpec((1, 1, qp, hsz), q_idx),
        pl.BlockSpec((1, 1, qp), idx["lse"]),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, kh, qp, hsz), q.dtype),
        jax.ShapeDtypeStruct((b, kh, qp), jnp.float32),
    ]
    aliases = {}
    # inputs are numbered including the scalar-prefetch args; paged mode
    # prefetches the block table too, and grouped suffix mode the per-row
    # start page, shifting everything after them
    npre = (3 if paged else 2) + (1 if grouped else 0)
    # the k/v inputs sit right after q, which follows the three init-state
    # arrays in grouped mode
    qoff = npre + (3 if grouped else 0)
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, block_s), scale_idx),
            pl.BlockSpec((1, 1, block_s), scale_idx),
        ]
        args += (kscale.astype(jnp.float32), vscale.astype(jnp.float32))
    if append:
        in_specs += [
            pl.BlockSpec((1, 1, hsz), idx["new"]),
            pl.BlockSpec((1, 1, hsz), idx["new"]),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
        ]
        args += (k_new, v_new, k, v)
        out_specs += [
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
        ]
        out_shape += [
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ]
        # e.g. unpaged: meta=0, tl=1, q=2, k=3, v=4 -> outputs 2/3 are the
        # appended caches (aliased with the K/V inputs)
        aliases = {qoff + 1: 2, qoff + 2: 3}
        if quant:
            in_specs += [
                pl.BlockSpec((1, 1, 1), srow_idx),
                pl.BlockSpec((1, 1, 1), srow_idx),
            ]
            args += (kscale.astype(jnp.float32), vscale.astype(jnp.float32))
            out_specs += [
                pl.BlockSpec((1, 1, 1), srow_idx),
                pl.BlockSpec((1, 1, 1), srow_idx),
            ]
            out_shape += [
                jax.ShapeDtypeStruct(kscale.shape, jnp.float32),
                jax.ShapeDtypeStruct(vscale.shape, jnp.float32),
            ]
            # the scale outputs (4/5) alias the full scale inputs, the
            # cache outputs (2/3) the full K/V inputs
            aliases = {qoff + 1: 2, qoff + 2: 3,
                       qoff + 3: 4, qoff + 4: 5}

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=npre,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((qp, hsz), jnp.float32),
                pltpu.VMEM((qp, 1), jnp.float32),
                pltpu.VMEM((qp, 1), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*args)


def grouped_prefix_index_maps(*, n_blocks: int):
    """Index maps for the grouped shared-prefix pass (CoDec-style, arXiv
    2505.17694).

    Grid is ``(G, Kh, n_blocks)``; each group ``g`` streams its shared
    prefix pages once — span-clamped to ``[0, gnp[g])`` so pruned steps
    re-reference the previous page and the DMA is elided (same property as
    the decode maps).  Prefetch operands are ``(meta [3], gnp [G],
    gtl [G, Gm], gtab [G, max_pages])``; every map is a pure jnp function
    of the grid coordinates and prefetched scalars.
    """

    def kv_idx(g, h, s, meta_ref, gnp_ref, gtl_ref, gtab_ref):
        lg = _phys_block(s, 0, gnp_ref[g], n_blocks)
        return (gtab_ref[g, lg], h, 0, 0)

    def scale_idx(g, h, s, *refs):
        return kv_idx(g, h, s, *refs)[:3]

    def q_idx(g, h, s, *_):
        return (g, h, 0, 0)

    def ml_idx(g, h, s, *_):
        return (g, h, 0)

    return {"kv": kv_idx, "scale": scale_idx, "q": q_idx, "acc": q_idx,
            "ml": ml_idx}


def _prefix_kernel(meta_ref, gnp_ref, gtl_ref, gtab_ref, *refs, scale: float,
                   kvp: int, rr_block: int, block_s: int, s_true: int,
                   quant: bool, gm: int, qp: int):
    if quant:
        (q_ref, k_ref, v_ref, kscale_ref, vscale_ref,
         acc_out, m_out, l_out, acc_ref, m_ref, l_ref) = refs
    else:
        (q_ref, k_ref, v_ref,
         acc_out, m_out, l_out, acc_ref, m_ref, l_ref) = refs
    gi = pl.program_id(0)
    si = pl.program_id(2)
    n_blocks = pl.num_programs(2)
    rank = meta_ref[0]
    window = meta_ref[2]
    np_g = gnp_ref[gi]
    # per-member lengths, broadcast to the stacked Q rows: member m owns
    # rows [m*qp, (m+1)*qp).  gm is static, so this unrolls to SMEM loads.
    tl_g = jnp.stack([gtl_ref[gi, mi] for mi in range(gm)])        # [gm]
    tl_rows = jnp.broadcast_to(tl_g[:, None], (gm, qp)).reshape(gm * qp)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lg = _phys_block(si, 0, np_g, n_blocks)
    active = si < np_g

    @pl.when(active)
    def _compute():
        kraw = k_ref[0, 0]                               # [bs, hsz] cache dt
        vraw = v_ref[0, 0]
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [gm*qp, hsz]
        k = kraw.astype(jnp.float32)
        v = vraw.astype(jnp.float32)
        if quant:
            k = k * kscale_ref[0, 0][:, None]
            v = v * vscale_ref[0, 0][:, None]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        # position math on the *logical* block id — shared prefix pages sit
        # at the same leading logical indices in every member's table, so
        # one block serves all gm members; only the length/window masks
        # differ per member row.
        jj = lg * block_s + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_s), 1)
        pos = ((jj // rr_block) * kvp + rank) * rr_block + (jj % rr_block)
        tl_col = tl_rows[:, None]                        # [gm*qp, 1]
        mask = jnp.logical_and(jj < s_true, pos < tl_col)
        mask = jnp.logical_and(
            mask, jnp.where(window > 0, pos >= tl_col - window, True))

        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # [gm*qp, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _emit():
        # RAW online-softmax state — no normalization; the suffix pass
        # resumes from exactly these (acc, m, l) per member row.
        acc_out[0, 0] = acc_ref[...]
        m_out[0, 0] = m_ref[:, 0]
        l_out[0, 0] = l_ref[:, 0]


def prefix_pass_kernel(q_stacked, k, v, meta, gnp, gtl, gtab, *, scale: float,
                       kvp: int, rr_block: int, block_s: int, s_true: int,
                       kscale=None, vscale=None, interpret: bool = True):
    """Raw pallas_call: shared-prefix pass of the grouped decode.

    q_stacked: [G, Kh, Gm*Qp, hsz] — requests sharing a prefix have their
    query blocks stacked along one row axis (member m at rows [m*Qp,
    (m+1)*Qp)); padding member rows must carry gtl == 0 so they mask to the
    identity update.  k/v: shared pool planes [n_pool, Kh, block_s, hsz]
    (int8 + [n_pool, Kh, block_s] f32 scales in quant mode).  meta: [3]
    int32 (rank, 0, window); gnp: [G] shared prefix pages per group; gtl:
    [G, Gm] per-member total lengths; gtab: [G, max_pages] the group's
    (identical leading) page table.

    Each shared page is streamed from HBM **once per group** instead of
    once per member — the ~1/group_size prefix bytes-read reduction the
    accounting layer proves.  Returns the raw f32 online-softmax state
    (acc [G, Kh, Gm*Qp, hsz], m [G, Kh, Gm*Qp], l [G, Kh, Gm*Qp]) for the
    suffix pass (``flash_decode_kernel(sfx_start=..., init_state=...)``).
    Groups with ``gnp == 0`` (singletons/idle rows) emit the cold state
    (acc = 0, m = -inf, l = 0), so the suffix pass degenerates to the
    ungrouped kernel for them.
    """
    g, kh, rows, hsz = q_stacked.shape
    gm_max = gtl.shape[1]
    assert rows % gm_max == 0, (rows, gm_max)
    qp = rows // gm_max
    quant = kscale is not None
    assert quant == (vscale is not None)
    assert k.shape[2] == block_s, (k.shape, block_s)
    n_blocks = gtab.shape[1]

    idx = grouped_prefix_index_maps(n_blocks=n_blocks)
    kernel = functools.partial(
        _prefix_kernel, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_true, quant=quant, gm=gm_max, qp=qp)

    in_specs = [
        pl.BlockSpec((1, 1, rows, hsz), idx["q"]),
        pl.BlockSpec((1, 1, block_s, hsz), idx["kv"]),
        pl.BlockSpec((1, 1, block_s, hsz), idx["kv"]),
    ]
    args = (meta, gnp, gtl, gtab, q_stacked, k, v)
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, block_s), idx["scale"]),
            pl.BlockSpec((1, 1, block_s), idx["scale"]),
        ]
        args += (kscale.astype(jnp.float32), vscale.astype(jnp.float32))

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(g, kh, n_blocks),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, rows, hsz), idx["acc"]),
                pl.BlockSpec((1, 1, rows), idx["ml"]),
                pl.BlockSpec((1, 1, rows), idx["ml"]),
            ],
            scratch_shapes=[
                pltpu.VMEM((rows, hsz), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
                pltpu.VMEM((rows, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((g, kh, rows, hsz), jnp.float32),
            jax.ShapeDtypeStruct((g, kh, rows), jnp.float32),
            jax.ShapeDtypeStruct((g, kh, rows), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
