"""Pallas TPU flash-decode kernel (Helix attention phase hotspot).

Decode-shape attention: one new query token per sequence against a (possibly
round-robin-sharded) KV cache shard.  Emits the partial output *and* the
log-sum-exp — the Helix combine (core/combine.py) needs both.

TPU mapping
-----------
  grid = (B, Kh, S_cap / block_s)   — S blocks iterated innermost so the
                                      online-softmax state lives in VMEM scratch
  q block   (1, 1, Qp, hsz)  : the Qp = padded Q-per-KV-head group, resident
  k/v block (1, 1, bs, hsz)  : streamed HBM->VMEM, bs a multiple of 128 (MXU)
  scale blk (1, 1, bs)       : int8-cache dequant scales (quant mode only)
  scratch   acc f32 (Qp,hsz), m/l f32 (Qp,1)

The two matmuls per block — (Qp,hsz)@(hsz,bs) and (Qp,bs)@(bs,hsz) — keep the
MXU contraction dims at hsz/bs multiples of 128 (hsz=64 archs pad lanes
internally).  VMEM footprint per step: 2*bs*hsz*2B (K,V) + Qp*hsz*4B + O(Qp),
e.g. bs=512, hsz=128: ~288 KiB — far under the ~16 MiB/core VMEM budget, so the
grid pipeline can double-buffer the K/V streams.

Masking semantics match ref.py and are computed in-kernel from prefetched
scalars only — no per-slot position array is read from HBM:

  meta [3] int32 : (rank, slot_offset, window) — slot_offset shifts the local
                   slot index (the sliding-window cache-slice fast path);
                   window <= 0 disables the sliding-window mask, and is a
                   *runtime* scalar so traced per-layer windows work.
  tl   [B] int32 : per-request global sequence lengths (continuous batching);
                   uniform batches prefetch a broadcast scalar.

Layouts: round-robin (§2.3) pos = ((j//rr)*kvp + rank)*rr + j%rr, or
contiguous (whisper cross-attention KV split) pos = rank*S_true + j.  Slots
j >= S_true (the unpadded local capacity) are masked unconditionally, so S
padding is exact in both layouts.

Quant mode (§Perf kv8): K/V arrive int8 with per-(B, Kh, slot) f32 scales and
are dequantized block-by-block in VMEM — the f32 copy of the shard never
exists in HBM.

Fused KV-append epilogue (append mode)
--------------------------------------
The rr-slot ``append_kv`` update is fused into the kernel: the caller passes
the *pre-append* cache plus the new token's K/V row, and the kernel

  1. substitutes the new row into the streamed K/V tile in VMEM for the
     attention compute (the HBM block containing the target slot is stale),
  2. writes the row back to the cache through a (1, 1, 1, hsz) output window
     whose index_map derives the target slot from the prefetched per-request
     lengths — ``input_output_aliases`` makes these outputs *the same HBM
     buffers* as the K/V inputs, so the rest of the cache is untouched and
     the separate append pass (one full-cache HBM round-trip per layer per
     decode step) disappears.

The row window is re-written (idempotently) at every S-block step, so the
kernel is correct under both write-back policies Pallas implementations use
(every visit, or last visit only).  Non-owner ranks (round-robin: the new
position lives on exactly one KVP rank) write back the unmodified row read
through a matching (1, 1, 1, hsz) *input* window.  Append mode composes with
per-request [B] lengths (each row appends at its own slot) but excludes the
quant/contiguous/slot_offset modes — the Helix caller falls back to the
unfused ``append_kv`` there (core/helix.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import NEG_INF


def _append_slot(total_len, kvp: int, rr_block: int, s_max: int):
    """Local rr slot of the appended token (position total_len - 1), clamped
    to the padded capacity.  Rank-independent (same formula on every rank);
    ownership is a separate check."""
    pos = total_len - 1
    blk = pos // rr_block
    j = (blk // kvp) * rr_block + pos % rr_block
    return jnp.clip(j, 0, s_max - 1)


def _decode_kernel(meta_ref, tl_ref, q_ref, k_ref, v_ref, *rest, scale: float,
                   kvp: int, rr_block: int, block_s: int, s_true: int,
                   contiguous: bool, quant: bool, append: bool):
    if append:
        (knew_ref, vnew_ref, krow_in_ref, vrow_in_ref, o_ref, lse_ref,
         krow_out_ref, vrow_out_ref, acc_ref, m_ref, l_ref) = rest
    elif quant:
        kscale_ref, vscale_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    bi = pl.program_id(0)
    si = pl.program_id(2)
    rank = meta_ref[0]
    slot_offset = meta_ref[1]
    window = meta_ref[2]
    total_len = tl_ref[bi]

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kraw = k_ref[0, 0]                                   # [bs, hsz] cache dtype
    vraw = v_ref[0, 0]
    if append:
        # epilogue part 1: substitute the new token's row into the VMEM tile
        # (the streamed HBM block is pre-append) ...
        j_new = _append_slot(total_len, kvp, rr_block, pl.num_programs(2)
                             * block_s)
        owner = (((total_len - 1) // rr_block) % kvp) == rank
        local = j_new - si * block_s
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_s, 1), 0)
        hit = jnp.logical_and(owner, rows == local)
        kn = knew_ref[0, 0]                              # [hsz] cache dtype
        vn = vnew_ref[0, 0]
        kraw = jnp.where(hit, kn[None, :], kraw)
        vraw = jnp.where(hit, vn[None, :], vraw)
        # ... part 2: persist the row through the aliased (1,1,1,hsz) output
        # window (idempotent re-write each S step; non-owners restore the
        # row they read).
        krow_out_ref[0, 0, 0] = jnp.where(owner, kn, krow_in_ref[0, 0, 0])
        vrow_out_ref[0, 0, 0] = jnp.where(owner, vn, vrow_in_ref[0, 0, 0])

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [Qp, hsz]
    k = kraw.astype(jnp.float32)                         # [bs, hsz]
    v = vraw.astype(jnp.float32)
    if quant:
        k = k * kscale_ref[0, 0][:, None]
        v = v * vscale_ref[0, 0][:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Qp, bs]

    # Global positions of this block's slots (computed, not read).  jj is the
    # physical (possibly padded) slot index; j the logical one after the
    # sliding-window slice offset.
    jj = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    j = jj + slot_offset
    if contiguous:
        pos = rank * s_true + j
    else:
        pos = ((j // rr_block) * kvp + rank) * rr_block + (j % rr_block)
    mask = jnp.logical_and(jj < s_true, pos < total_len)
    mask = jnp.logical_and(
        mask, jnp.where(window > 0, pos >= total_len - window, True))

    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # [Qp, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # exp(NEG_INF - NEG_INF)=1 is harmless (l, acc still 0); but masked lanes
    # must not contribute when m_new == NEG_INF, so gate p by the mask.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)          # [Qp, bs]
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-37)
        o_ref[0, 0] = jnp.where(l > 0, acc_ref[...] / denom, 0.0).astype(o_ref.dtype)
        lse = jnp.where(l[:, 0] > 0, m_ref[:, 0] + jnp.log(denom[:, 0]), NEG_INF)
        lse_ref[0, 0] = lse.astype(jnp.float32)


def flash_decode_kernel(q, k, v, meta, tl, *, scale: float, kvp: int,
                        rr_block: int, block_s: int, s_true: int,
                        contiguous: bool = False, kscale=None, vscale=None,
                        k_new=None, v_new=None, interpret: bool = True):
    """Raw pallas_call.  Shapes must already be padded/blocked (see ops.py).

    q: [B, Kh, Qp, hsz]; k, v: [B, Kh, S_pad, hsz]; meta: [3] int32
    (rank, slot_offset, window); tl: [B] int32 per-request lengths;
    kscale/vscale: [B, Kh, S_pad] f32 (int8-cache mode — k/v are int8);
    k_new/v_new: [B, Kh, hsz] in cache dtype (fused-append mode — excludes
    quant/contiguous; tl must already include the appended token).
    s_true: unpadded local capacity (slots >= s_true are masked).
    returns out [B, Kh, Qp, hsz] (q.dtype), lse [B, Kh, Qp] (f32), plus the
    appended caches kc, vc [B, Kh, S_pad, hsz] (aliased with k, v) in
    fused-append mode.
    """
    b, kh, qp, hsz = q.shape
    s_pad = k.shape[2]
    assert s_pad % block_s == 0 and qp % 8 == 0
    quant = kscale is not None
    assert quant == (vscale is not None)
    append = k_new is not None
    assert append == (v_new is not None)
    assert not (append and (quant or contiguous)), \
        "fused append excludes quant/contiguous modes"

    grid = (b, kh, s_pad // block_s)
    kernel = functools.partial(
        _decode_kernel, scale=scale, kvp=kvp, rr_block=rr_block,
        block_s=block_s, s_true=s_true, contiguous=contiguous, quant=quant,
        append=append)

    def row_idx(b, h, s, meta_ref, tl_ref):
        # target row window of the appended token; depends on the prefetched
        # per-request length only (rank-independent slot formula)
        return (b, h, _append_slot(tl_ref[b], kvp, rr_block, s_pad), 0)

    in_specs = [
        pl.BlockSpec((1, 1, qp, hsz), lambda b, h, s, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, block_s, hsz), lambda b, h, s, *_: (b, h, s, 0)),
        pl.BlockSpec((1, 1, block_s, hsz), lambda b, h, s, *_: (b, h, s, 0)),
    ]
    args = (meta, tl, q, k, v)
    out_specs = [
        pl.BlockSpec((1, 1, qp, hsz), lambda b, h, s, *_: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, qp), lambda b, h, s, *_: (b, h, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, kh, qp, hsz), q.dtype),
        jax.ShapeDtypeStruct((b, kh, qp), jnp.float32),
    ]
    aliases = {}
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, block_s), lambda b, h, s, *_: (b, h, s)),
            pl.BlockSpec((1, 1, block_s), lambda b, h, s, *_: (b, h, s)),
        ]
        args += (kscale.astype(jnp.float32), vscale.astype(jnp.float32))
    if append:
        in_specs += [
            pl.BlockSpec((1, 1, hsz), lambda b, h, s, *_: (b, h, 0)),
            pl.BlockSpec((1, 1, hsz), lambda b, h, s, *_: (b, h, 0)),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
        ]
        args += (k_new, v_new, k, v)
        out_specs += [
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
            pl.BlockSpec((1, 1, 1, hsz), row_idx),
        ]
        out_shape += [
            jax.ShapeDtypeStruct((b, kh, s_pad, hsz), k.dtype),
            jax.ShapeDtypeStruct((b, kh, s_pad, hsz), v.dtype),
        ]
        # inputs are numbered including the 2 scalar-prefetch args:
        # meta=0, tl=1, q=2, k=3, v=4 -> outputs 2/3 are the appended caches
        aliases = {3: 2, 4: 3}

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((qp, hsz), jnp.float32),
                pltpu.VMEM((qp, 1), jnp.float32),
                pltpu.VMEM((qp, 1), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*args)
