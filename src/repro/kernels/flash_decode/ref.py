"""Pure-jnp oracle for the flash_decode kernel.

Semantics (shared with the kernel):

The KV shard on KVP rank ``rank`` holds slots j = 0..S_cap-1.  With the paper's
round-robin concatenation (§2.3, block size ``rr_block``), slot j corresponds to
*global* sequence position

    pos(j) = ((j // rr) * kvp + rank) * rr + (j % rr)

A slot is valid iff pos(j) < total_len.  With a sliding window w > 0, it must
also satisfy pos(j) >= total_len - w (the query is the token at position
total_len - 1).  Invalid slots are masked to -inf before the softmax.

Returns the softmax-normalized partial output together with the log-sum-exp of
this shard's scores (f32), as required by the Helix combine.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.utils import NEG_INF


def shard_positions(s_cap: int, rank, kvp: int, rr_block: int,
                    slot_offset=0):
    """Global positions of the local KV slots on ``rank``.  [S_cap] int32.
    ``slot_offset`` shifts the local slot index (windowed cache slices)."""
    j = jnp.arange(s_cap, dtype=jnp.int32) + slot_offset
    return ((j // rr_block) * kvp + rank) * rr_block + (j % rr_block)


def local_valid_len(total_len, rank, kvp: int, rr_block: int):
    """Number of valid local slots on ``rank`` given global length total_len."""
    cycle = kvp * rr_block
    full = (total_len // cycle) * rr_block
    rem = total_len % cycle
    extra = jnp.clip(rem - rank * rr_block, 0, rr_block)
    return full + extra


def flash_decode_ref(q, k, v, total_len, rank, *, kvp: int = 1, rr_block: int = 16,
                     window: int = 0, scale: float | None = None,
                     slot_offset=0, kscale=None, vscale=None):
    """Oracle decode attention over one KV shard.

    Args:
      q: [B, Qh, hsz] queries for the new token.
      k, v: [B, Kh, S_cap, hsz] local KV shard (Qh % Kh == 0).
      total_len: scalar int — global sequence length including the new token.
      rank: scalar int — this shard's KVP rank.
      kscale/vscale: [B, Kh, S_cap] int8-cache dequant scales (k/v are int8);
        mirrors ops.flash_decode's signature.
    Returns:
      out [B, Qh, hsz] (q.dtype), lse [B, Qh] (f32).
    """
    if kscale is not None:
        k = k.astype(jnp.float32) * kscale[..., None]
        v = v.astype(jnp.float32) * vscale[..., None]
    b, qh, hsz = q.shape
    kh, s_cap = k.shape[1], k.shape[2]
    assert qh % kh == 0
    g = qh // kh
    if scale is None:
        scale = hsz ** -0.5

    pos = shard_positions(s_cap, jnp.asarray(rank, jnp.int32), kvp, rr_block,
                          slot_offset)
    # total_len may be scalar or per-request [B]
    tl = jnp.asarray(total_len)
    tl_b = tl[:, None] if tl.ndim == 1 else tl
    valid = pos[None, :] < tl_b                       # [B?, S] or [1, S]
    w = jnp.asarray(window)
    valid = valid & jnp.where(w > 0, pos[None, :] >= tl_b - w, True)

    qf = q.astype(jnp.float32).reshape(b, kh, g, hsz)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qf * scale, kf)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.where(m <= NEG_INF, 0.0, m)
    p = jnp.where(valid[:, None, None, :], jnp.exp(scores - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, vf) / jnp.maximum(l, 1e-37)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-37)), NEG_INF)
    return (out.reshape(b, qh, hsz).astype(q.dtype), lse.reshape(b, qh))
