"""Shared block-pruning helper for the Pallas attention kernels.

Both flash_decode and flash_prefill prune by clamping their K/V
``index_map``s to a per-request/per-row valid block span ``[lo, lo + nb)``
(see ``flash_decode.kernel.prune_block_range`` /
``flash_prefill.kernel.prefill_block_range``).  The clamp rule lives here
once because the DMA-elision correctness depends on it: a pruned grid step
must reference the *same* physical block as the previous step, so Pallas
TPU skips the HBM->VMEM copy instead of re-fetching a dead block.

Index_map purity requirement
----------------------------
Every ``index_map`` built on these helpers MUST be a *pure jnp function* of
the grid coordinates and the scalar-prefetch operands: no data-dependent
python branching (``if traced_value:``), no host lookups, no side effects.
Pallas requires this to trace the maps once at lowering time, and the
static auditor (``repro.analysis.index_audit``) relies on the same property
to host-evaluate the maps over every grid step with ``jax.vmap`` — a map
that branched in python on a traced scalar would either fail to trace or,
worse, be audited along a different path than the one the kernel runs.
Static *configuration* branches (``if paged:`` on a python bool closed over
at build time) are fine; branches on prefetched values must be expressed
with ``jnp.where``/``jnp.clip`` as below.
"""
from __future__ import annotations

import jax.numpy as jnp


def span_clamp(step, lo, nb, n_blocks: int):
    """Clamp grid step ``step`` into the valid span ``[lo, lo + nb)`` and
    the array bounds ``[0, n_blocks)``.

    The one in-bounds clamp shared by ``phys_block``/``table_block`` (and
    through them every pruned kernel index_map) and replayed by the static
    auditor: ``lo + step`` while inside the span, then pinned to the span's
    last block — the same block as the previous step, so Pallas elides the
    HBM->VMEM copy.  Total (never out of ``[0, n_blocks)``) even for empty
    spans (``nb == 0``).  All of ``step``/``lo``/``nb`` may be traced
    scalars (this runs inside Pallas index_maps); the math is pure jnp per
    the module-level purity requirement.
    """
    last = jnp.maximum(lo + nb - 1, lo)
    return jnp.clip(jnp.minimum(lo + step, last), 0, n_blocks - 1)


def phys_block(step, lo, nb, n_blocks: int):
    """Physical block streamed at grid step ``step``: ``lo + step`` while
    inside the valid span, then clamped to the span's last block (same
    block as the previous step => the copy is elided).  ``lo``/``nb`` may
    be traced scalars; always in ``[0, n_blocks)`` even for empty spans.
    Alias of ``span_clamp`` — the fixed-layout kernels address physical
    blocks directly."""
    return span_clamp(step, lo, nb, n_blocks)


def table_block(step, lo, nb, n_blocks: int, table_row):
    """Paged generalization of ``phys_block``: the *logical* page id walks
    the clamped span exactly as in the fixed layout, then the scalar-
    prefetched block-table row maps it to the physical pool page.  Pruned
    grid steps re-reference the previous step's logical page, hence the
    same table entry, hence the same physical page — so the DMA-elision
    property survives the indirection unchanged.  ``table_row`` is one
    request's ``[max_pages]`` table (a Pallas scalar-prefetch ref slice or
    an array)."""
    return table_row[span_clamp(step, lo, nb, n_blocks)]
