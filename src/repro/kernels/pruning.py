"""Shared block-pruning helper for the Pallas attention kernels.

Both flash_decode and flash_prefill prune by clamping their K/V
``index_map``s to a per-request/per-row valid block span ``[lo, lo + nb)``
(see ``flash_decode.kernel.prune_block_range`` /
``flash_prefill.kernel.prefill_block_range``).  The clamp rule lives here
once because the DMA-elision correctness depends on it: a pruned grid step
must reference the *same* physical block as the previous step, so Pallas
TPU skips the HBM->VMEM copy instead of re-fetching a dead block.
"""
from __future__ import annotations

import jax.numpy as jnp


def phys_block(step, lo, nb, n_blocks: int):
    """Physical block streamed at grid step ``step``: ``lo + step`` while
    inside the valid span, then clamped to the span's last block (same
    block as the previous step => the copy is elided).  ``lo``/``nb`` may
    be traced scalars; always in ``[0, n_blocks)`` even for empty spans."""
    last = jnp.maximum(lo + nb - 1, lo)
    return jnp.clip(jnp.minimum(lo + step, last), 0, n_blocks - 1)
