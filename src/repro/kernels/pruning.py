"""Shared block-pruning helper for the Pallas attention kernels.

Both flash_decode and flash_prefill prune by clamping their K/V
``index_map``s to a per-request/per-row valid block span ``[lo, lo + nb)``
(see ``flash_decode.kernel.prune_block_range`` /
``flash_prefill.kernel.prefill_block_range``).  The clamp rule lives here
once because the DMA-elision correctness depends on it: a pruned grid step
must reference the *same* physical block as the previous step, so Pallas
TPU skips the HBM->VMEM copy instead of re-fetching a dead block.
"""
from __future__ import annotations

import jax.numpy as jnp


def phys_block(step, lo, nb, n_blocks: int):
    """Physical block streamed at grid step ``step``: ``lo + step`` while
    inside the valid span, then clamped to the span's last block (same
    block as the previous step => the copy is elided).  ``lo``/``nb`` may
    be traced scalars; always in ``[0, n_blocks)`` even for empty spans."""
    last = jnp.maximum(lo + nb - 1, lo)
    return jnp.clip(jnp.minimum(lo + step, last), 0, n_blocks - 1)


def table_block(step, lo, nb, n_blocks: int, table_row):
    """Paged generalization of ``phys_block``: the *logical* page id walks
    the clamped span exactly as in the fixed layout, then the scalar-
    prefetched block-table row maps it to the physical pool page.  Pruned
    grid steps re-reference the previous step's logical page, hence the
    same table entry, hence the same physical page — so the DMA-elision
    property survives the indirection unchanged.  ``table_row`` is one
    request's ``[max_pages]`` table (a Pallas scalar-prefetch ref slice or
    an array)."""
    return table_row[phys_block(step, lo, nb, n_blocks)]
