"""Kernel contract descriptions for the static index-space auditor.

A *contract* is a host-side, declarative mirror of one ``pallas_call``: the
grid, the per-operand block shapes and ``index_map`` callables, the scalar
prefetch operands the maps close over, and the aliasing structure.  Each
kernel family exposes a ``contract()`` hook (see ``registry.FAMILIES``) that
returns the contracts for a lattice of configurations; ``repro.analysis``
enumerates every grid step of every contract and host-evaluates the
index_maps to prove in-bounds access, the DMA-elision invariant of pruned
steps, and alias-race freedom of the fused-append row windows.

The contract must reference the *same* index_map callables the kernel passes
to ``pallas_call`` (the families share them via module-level builders such as
``flash_decode.kernel.decode_index_maps``) — auditing a copy would prove
nothing.  Index_maps must be pure jnp functions of the grid coordinates and
prefetched scalars, with no data-dependent python branching; see
``kernels/pruning.py`` for the purity requirement this relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence


@dataclasses.dataclass
class Operand:
    """One ``pallas_call`` operand: a (padded) array, its BlockSpec block
    shape, and the index_map that addresses blocks of it per grid step.

    ``index_map`` receives ``(*grid_coords, *prefetch)`` — grid coordinates
    first, then the scalar-prefetch operands in declaration order — and
    returns a tuple of *block* indices (one per array axis; window axes
    return 0).  ``streamed`` marks HBM->VMEM streamed operands (subject to
    the DMA-elision check); ``alias_of`` names the input operand an output
    writes through (``input_output_aliases``); ``paged_axis`` is the array
    axis addressed through a block-table indirection, whose bounds
    violations are reported as ``bounds.page`` rather than ``bounds.block``.
    """

    name: str
    shape: tuple
    block: tuple
    index_map: Callable
    kind: str = "in"            # "in" | "out"
    streamed: bool = False
    alias_of: str | None = None
    paged_axis: int | None = None

    def grid_limits(self):
        """Number of valid blocks per array axis (ceil-div shape/block)."""
        return tuple(-(-s // b) for s, b in zip(self.shape, self.block))


@dataclasses.dataclass
class KernelContract:
    """Declarative mirror of one ``pallas_call`` configuration.

    ``prefetch`` holds the scalar-prefetch arrays (in declaration order)
    that every index_map closes over.  ``stream_axis`` is the innermost
    grid axis that streams blocks (None when no axis streams).  ``active``,
    when set, maps grid coordinates to a bool — False marks pruned steps
    whose streamed index_maps must repeat the previous step's block (DMA
    elision).  ``expected_row`` maps the non-stream grid coordinates to the
    block-index tuple a fused-append row window must address, letting the
    auditor cross-validate the row index_map against the in-kernel VMEM
    substitution.  ``table``/``n_pool`` describe the paged block table.
    """

    family: str
    case: str
    grid: tuple
    operands: list
    prefetch: tuple = ()
    stream_axis: int | None = None
    aliases: dict = dataclasses.field(default_factory=dict)
    active: Callable | None = None
    expected_row: Callable | None = None
    table: Any = None
    n_pool: int | None = None
    notes: dict = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        """One-line human summary (family, case, grid, operand count)."""
        return (f"{self.family}[{self.case}] grid={self.grid} "
                f"ops={len(self.operands)} aliases={len(self.aliases)}")


def operands_by_name(contract: KernelContract) -> dict:
    """Name -> Operand lookup for one contract."""
    return {op.name: op for op in contract.operands}
