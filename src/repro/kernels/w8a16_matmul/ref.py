"""Pure-jnp oracle for w8a16: int8-weight x bf16-activation matmul with
per-output-channel scales (weight-only quantization)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_w8(w):
    """f32/bf16 [K, N] -> (int8 [K, N], scale f32 [N]) per-channel symmetric."""
    wf = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(wf), axis=0) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(wf / scale[None, :]), -127, 127).astype(jnp.int8)
    return q, scale


def w8a16_matmul_ref(x, qw, scale):
    """x [M, K] bf16/f32; qw [K, N] int8; scale [N] f32 -> [M, N] x.dtype."""
    y = jnp.einsum("mk,kn->mn", x.astype(jnp.float32),
                   qw.astype(jnp.float32))
    return (y * scale[None, :]).astype(x.dtype)
