from repro.kernels.w8a16_matmul.ops import w8a16_matmul
from repro.kernels.w8a16_matmul.ref import quantize_w8, w8a16_matmul_ref

__all__ = ["w8a16_matmul", "quantize_w8", "w8a16_matmul_ref"]
