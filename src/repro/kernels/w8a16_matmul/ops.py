"""jit'd public wrapper for w8a16_matmul with shape padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.w8a16_matmul.kernel import w8a16_matmul_kernel
from repro.kernels.w8a16_matmul.ref import quantize_w8  # noqa: F401
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def w8a16_matmul(x, qw, scale, *, bm: int = 128, bn: int = 128, bk: int = 256,
                 interpret: bool = True):
    """int8-weight x bf16/f32-activation matmul via the Pallas kernel.

    The w8a16_matmul *family* entry point the kernel-backend registry
    routes to (``HelixConfig.matmul_backend``).  Weights are dequantized
    tile-by-tile in VMEM (per-output-column scales); shapes are padded to
    the block sizes and sliced back.

      x [M, K] bf16/f32; qw [K, N] int8; scale [N] f32 -> out [M, N].
    """
    m, k = x.shape
    n = qw.shape[1]
    bm = min(bm, round_up(m, 8))
    bn = min(bn, round_up(n, 128))
    bk = min(bk, round_up(k, 128))
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    qwp = jnp.pad(qw, ((0, kp - k), (0, np_ - n)))
    sp = jnp.pad(scale, (0, np_ - n))[None, :]
    out = w8a16_matmul_kernel(xp, qwp, sp, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    return out[:m, :n]

# --- static-analysis contract -------------------------------------------

from repro.kernels.contract import KernelContract, Operand  # noqa: E402
from repro.kernels.w8a16_matmul.kernel import w8a16_index_maps  # noqa: E402


def w8a16_matmul_contract():
    """Contracts for the w8a16_matmul audit lattice (``repro.analysis``).

    No scalar prefetch or aliasing — the contract pins the static (i, j,
    ki) block addressing (``kernel.w8a16_index_maps``, the same callables
    ``w8a16_matmul_kernel`` uses) over a square and a rectangular blocked
    geometry so the auditor proves every streamed X/W tile and the
    resident scale/out tiles stay in bounds.
    """
    contracts = []
    for case, (m, n, k, bm, bn, bk) in (
            ("square", (8, 8, 8, 4, 4, 4)),
            ("rect", (8, 16, 12, 4, 8, 4))):
        idx = w8a16_index_maps()
        operands = [
            Operand("x", (m, k), (bm, bk), idx["x"], streamed=True),
            Operand("qw", (k, n), (bk, bn), idx["w"], streamed=True),
            Operand("scale", (1, n), (1, bn), idx["scale"]),
            Operand("out", (m, n), (bm, bn), idx["out"], kind="out"),
        ]
        contracts.append(KernelContract(
            family="w8a16_matmul", case=case,
            grid=(m // bm, n // bn, k // bk), operands=operands,
            stream_axis=2, notes=dict(bm=bm, bn=bn, bk=bk)))
    return contracts
