"""Pallas TPU w8a16 matmul: int8 weights dequantized on-the-fly in VMEM.

Beyond-paper optimization for the decode FFN weight-read bottleneck
(§Roofline memory term): weight bytes halve vs bf16 while the MXU still
computes in bf16/f32.  Per-output-channel scales are folded in at the end.

TPU mapping
-----------
  grid = (M/bm, N/bn, K/bk)   — K innermost; f32 accumulator in VMEM scratch
  x block  (bm, bk) bf16      streamed
  w block  (bk, bn) int8      streamed (half the HBM bytes of bf16)
  scale    (1, bn)  f32       resident per N block
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _w8a16_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bm, bn, bk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # [bm, bk]
    w = w_ref[...].astype(jnp.float32)              # [bk, bn] dequant int8
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def w8a16_matmul_kernel(x, qw, scale, *, bm, bn, bk, interpret: bool = True):
    """x [M, K]; qw [K, N] int8; scale [1, N] f32 -> [M, N] (x.dtype).

    M % bm == K % bk == N % bn == 0 (ops.py pads).
    """
    m, k = x.shape
    n = qw.shape[1]
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_w8a16_kernel, bm=bm, bn=bn, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((1, bn), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, qw, scale)
