"""Pallas TPU w8a16 matmul: int8 weights dequantized on-the-fly in VMEM.

Beyond-paper optimization for the decode FFN weight-read bottleneck
(§Roofline memory term): weight bytes halve vs bf16 while the MXU still
computes in bf16/f32.  Per-output-channel scales are folded in at the end.

TPU mapping
-----------
  grid = (M/bm, N/bn, K/bk)   — K innermost; f32 accumulator in VMEM scratch
  x block  (bm, bk) bf16      streamed
  w block  (bk, bn) int8      streamed (half the HBM bytes of bf16)
  scale    (1, bn)  f32       resident per N block
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def w8a16_index_maps():
    """Named index_map callables for the w8a16 matmul kernel.

    The single source of truth for the kernel's block addressing:
    ``w8a16_matmul_kernel`` passes exactly these callables to
    ``pallas_call``, and ``ops.w8a16_matmul_contract`` exposes them to the
    static index-space auditor (``repro.analysis``).  All maps are static
    functions of the grid coordinates ``(i, j, ki)``.  Keys:

      x      activation blocks (bm, bk), streamed along K
      w      int8 weight blocks (bk, bn), streamed along K
      scale  per-N-block dequant scales (1, bn), resident along K
      out    output blocks (bm, bn)
    """
    return {
        "x": lambda i, j, ki: (i, ki),
        "w": lambda i, j, ki: (ki, j),
        "scale": lambda i, j, ki: (0, j),
        "out": lambda i, j, ki: (i, j),
    }


def _w8a16_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, bm, bn, bk):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # [bm, bk]
    w = w_ref[...].astype(jnp.float32)              # [bk, bn] dequant int8
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def w8a16_matmul_kernel(x, qw, scale, *, bm, bn, bk, interpret: bool = True):
    """x [M, K]; qw [K, N] int8; scale [1, N] f32 -> [M, N] (x.dtype).

    M % bm == K % bk == N % bn == 0 (ops.py pads).
    """
    m, k = x.shape
    n = qw.shape[1]
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_w8a16_kernel, bm=bm, bn=bn, bk=bk)
    idx = w8a16_index_maps()
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), idx["x"]),
            pl.BlockSpec((bk, bn), idx["w"]),
            pl.BlockSpec((1, bn), idx["scale"]),
        ],
        out_specs=pl.BlockSpec((bm, bn), idx["out"]),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, qw, scale)
