"""jit'd public wrapper for flash_prefill: natural [B,T,Qh,hsz] layout,
padding to block multiples, GQA head grouping, scalar-prefetch packing —
plus the block-accounting layer that reports how many kv blocks the
causal/window skip (``prune``) actually streams."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_prefill.kernel import (flash_prefill_kernel,
                                                prefill_block_range)
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("causal", "scale", "blk_q",
                                             "blk_k", "prune", "interpret"))
def flash_prefill(q, k, v, *, causal: bool = True, window=0, q_offset=0,
                  seq_lens=None, scale: float | None = None,
                  blk_q: int = 128, blk_k: int = 128, prune: bool = True,
                  block_tables=None, interpret: bool = True):
    """Full-sequence attention via the Pallas flash-prefill kernel.

    The kernel-backed sibling of ``models/attention.chunked_attention`` —
    this is the flash_prefill *family* entry point the kernel-backend
    registry routes to (``HelixConfig.prefill_backend``).

    Args:
      q: ``[B, T, Qh, hsz]`` queries; ``Qh % Kh == 0`` (GQA grouping).
      k, v: ``[B, S, Kh, hsz]`` keys/values.  ``S == T`` for causal
        self-attention; any ``S`` for cross attention (``causal=False``).
        In paged mode (``block_tables`` given) the K/V are shared pool
        planes ``[n_pool, Kh, page_k, hsz]`` instead — kernel layout, page
        size ``page_k`` pinned as ``blk_k``.
      causal: static — mask ``kpos > qpos`` (decoder self-attention).
      window: sliding window (``<= 0`` disables).  May be a *traced* scalar
        (per-layer local/global windows under ``lax.scan``).
      q_offset: global position of query row 0 (prefill continuation); may
        be traced, and may be a *per-request* ``[B]`` vector — the ragged
        chunk-packing contract that lets the serving engine pack prefills
        at different (offset, length) progress into one call.
      seq_lens: optional ``[B]`` int32 per-request valid KV lengths
        (continuous-batching prefill over right-padded prompts); kv positions
        ``>= seq_lens[b]`` are masked.  ``None`` means all ``S`` positions
        are live.  Rows with ``seq_lens[b] == 0`` emit zeros.
      scale: score scale; defaults to ``hsz ** -0.5``.
      blk_q, blk_k: kernel block sizes (static; see docs/kernels.md).
      prune: skip kv blocks that are causally/window/length-dead instead of
        masking them (index_map clamp + ``pl.when``; bit-exact either way).
        Causal T = S sweeps ~the lower triangle of the (T/blk_q, S/blk_k)
        rectangle; ``flash_prefill_accounting`` reports the exact counts.
      block_tables: optional ``[B, max_pages]`` int32 — paged KV: kv-block
        ``i`` of request ``b`` streams from pool plane
        ``block_tables[b, i]`` (scalar-prefetched indirection; composes
        with the causal/window skip, bit-exact vs the fixed layout).
        Requires ``seq_lens``: table entries beyond a request's allocation
        point at the shared sink page, whose contents are arbitrary — only
        the per-request length mask keeps them out of the softmax.
      interpret: run the kernel through the Pallas interpreter (any JAX
        backend) instead of compiling for TPU.

    Returns:
      ``[B, T, Qh, hsz]`` attention output in ``q.dtype``.
    """
    b, t, qh, hsz = q.shape
    paged = block_tables is not None
    kh = k.shape[1] if paged else k.shape[2]
    assert qh % kh == 0
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5

    blk_q = min(blk_q, round_up(t, 8))
    t_pad = round_up(t, blk_q)

    # [B,T,Kh,G,hsz] -> [B,Kh,T,G*hsz]
    qg = q.reshape(b, t, kh, g, hsz).transpose(0, 2, 1, 3, 4).reshape(
        b, kh, t, g * hsz)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    if paged:
        # sink-page table entries hold arbitrary data; only the per-request
        # length mask keeps them out of the reduction
        assert seq_lens is not None, "paged flash_prefill requires seq_lens"
        blk_k = k.shape[2]                    # page size is the kv block
        s = np.shape(block_tables)[1] * blk_k
        kg, vg = k, v                         # pool planes, kernel layout
        tables = jnp.asarray(block_tables, jnp.int32)
    else:
        s = k.shape[1]
        blk_k = min(blk_k, round_up(s, 8))
        s_pad = round_up(s, blk_k)
        kg = k.transpose(0, 2, 1, 3)
        vg = v.transpose(0, 2, 1, 3)
        kg = jnp.pad(kg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
        tables = None
    # kv rows beyond the true S are masked in-kernel (s_true); pad q rows
    # produce well-defined garbage and are sliced away below.

    meta = jnp.asarray(window, jnp.int32).reshape(1)
    offs = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1), (b,))
    if seq_lens is None:
        lens = jnp.full((b,), s, jnp.int32)
    else:
        lens = jnp.broadcast_to(jnp.asarray(seq_lens, jnp.int32), (b,))

    out = flash_prefill_kernel(qg, kg, vg, meta, lens, offs, scale=scale,
                               causal=causal, blk_q=blk_q, blk_k=blk_k,
                               s_true=s, prune=prune, block_tables=tables,
                               interpret=interpret)
    out = out[:, :, :t].reshape(b, kh, t, g, hsz).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, qh, hsz)


def flash_prefill_accounting(q, k, v, *, causal: bool = True, window=0,
                             q_offset=0, seq_lens=None, blk_q: int = 128,
                             blk_k: int = 128, prune: bool = True,
                             block_tables=None, **_ignored):
    """KV blocks/bytes the matching ``flash_prefill`` call streams from HBM.

    Replays the kernel's skip range (``prefill_block_range`` — the same
    function its K/V ``index_map``s clamp with) over the (B, Kh, T-blocks,
    S-blocks) grid and counts distinct block fetches (consecutive steps on
    the same block are one DMA).  ``q_offset`` may be per-request ([B]) —
    the ragged-packing contract.  Paged mode (``block_tables``): ``k``/``v``
    are pool planes; the replay walks the same logical kv-block ranges
    through the table (distinct logical pages are distinct planes, so the
    count is unchanged; ``blk_k`` pins to the page size).  Pure host-side
    arithmetic; accepts any ``flash_prefill`` argument set (extra kwargs
    are ignored).

    Returns ``{"blocks_visited", "blocks_total", "bytes_read",
    "bytes_total", "blk_q", "blk_k", "n_qblocks", "n_kblocks"}``.
    """
    b, t, _, hsz = q.shape
    paged = block_tables is not None
    if paged:
        kh = k.shape[1]
        blk_k = k.shape[2]
        n_k = np.shape(block_tables)[1]
        s = n_k * blk_k
    else:
        s, kh = k.shape[1], k.shape[2]
        blk_k = min(blk_k, round_up(s, 8))
        n_k = round_up(s, blk_k) // blk_k
    blk_q = min(blk_q, round_up(t, 8))
    n_q = round_up(t, blk_q) // blk_q

    lens = np.broadcast_to(
        np.full((b,), s, np.int32) if seq_lens is None
        else np.asarray(seq_lens, np.int32).reshape(-1), (b,))
    offs = np.broadcast_to(
        np.asarray(q_offset, np.int32).reshape(-1), (b,))
    if prune:
        # prefill_block_range is elementwise jnp: one vectorized call over
        # the [b, n_q] grid instead of b*n_q eager dispatch loops
        _, nb = prefill_block_range(
            jnp.arange(n_q, dtype=jnp.int32)[None, :],
            jnp.asarray(lens)[:, None], jnp.asarray(offs)[:, None],
            jnp.asarray(window, jnp.int32), causal=causal,
            blk_q=blk_q, blk_k=blk_k, s_true=s)
        # a fully-skipped row still fetches one (clamped) block
        visited = int(np.maximum(np.asarray(nb), 1).sum())
    else:
        visited = b * n_q * n_k
    blocks_visited = kh * visited
    blocks_total = b * kh * n_q * n_k
    blk_bytes = 2 * blk_k * hsz * jnp.dtype(k.dtype).itemsize   # K + V
    return {
        "blocks_visited": blocks_visited,
        "blocks_total": blocks_total,
        "bytes_read": blocks_visited * blk_bytes,
        "bytes_total": blocks_total * blk_bytes,
        "blk_q": blk_q,
        "blk_k": blk_k,
        "n_qblocks": n_q,
        "n_kblocks": n_k,
    }

# --- static-analysis contract -------------------------------------------

from repro.kernels.contract import KernelContract, Operand  # noqa: E402
from repro.kernels.flash_prefill.kernel import prefill_index_maps  # noqa: E402

# default audit lattice: causal x window x prune x paged x ragged packing
_CONTRACT_LATTICE = (
    dict(case="causal-prune"),
    dict(case="causal-dense", prune=False),
    dict(case="causal-window", window=6),
    dict(case="causal-ragged", q_offset=(0, 3), seq_lens=(5, 16)),
    dict(case="cross-dense", causal=False, prune=False),
    dict(case="cross-lens", causal=False, seq_lens=(5, 16)),
    dict(case="paged-prune", paged=True, seq_lens=(5, 16)),
    dict(case="paged-window", paged=True, window=6, seq_lens=(5, 16)),
    dict(case="paged-sink-tail", paged=True, seq_lens=(5, 12),
         sink_tail=True),
)


def prefill_case_contract(case="causal-prune", *, b=2, kh=2, g=2, hsz=8,
                          t=8, s=16, blk_q=4, blk_k=4, causal=True,
                          window=0, q_offset=0, seq_lens=None, prune=True,
                          paged=False, sink_tail=False, seed=0):
    """Build the ``KernelContract`` for one flash_prefill configuration.

    Mirrors ``flash_prefill``'s geometry resolution (block sizing, padding,
    prefetch layout) and binds the *same* index_map callables the kernel
    passes to ``pallas_call`` (``kernel.prefill_index_maps``).  Returns one
    ``KernelContract``; ``flash_prefill_contract`` assembles the lattice.
    """
    blk_q = min(blk_q, round_up(t, 8))
    t_pad = round_up(t, blk_q)
    if paged:
        n_kblocks = s // blk_k
        s_pad = n_kblocks * blk_k
    else:
        blk_k = min(blk_k, round_up(s, 8))
        s_pad = round_up(s, blk_k)
        n_kblocks = s_pad // blk_k

    meta = np.array([window], np.int32)
    lens = (np.full((b,), s, np.int32) if seq_lens is None
            else np.broadcast_to(np.asarray(seq_lens, np.int32), (b,)))
    offs = np.broadcast_to(np.asarray(q_offset, np.int32).reshape(-1), (b,))
    prefetch = (meta, lens, offs)

    table = None
    n_pool = None
    if paged:
        rng = np.random.RandomState(seed)
        n_pool = 1 + b * n_kblocks           # page 0 is the reserved sink
        table = (1 + rng.permutation(b * n_kblocks)
                 .reshape(b, n_kblocks)).astype(np.int32)
        if sink_tail:
            need = (lens + blk_k - 1) // blk_k
            for i in range(b):
                table[i, max(int(need[i]), 1):] = 0
        prefetch = prefetch + (table,)

    idx = prefill_index_maps(causal=causal, blk_q=blk_q, blk_k=blk_k,
                             s_true=s, n_kblocks=n_kblocks, prune=prune,
                             paged=paged)

    kv_shape = ((n_pool, kh, blk_k, hsz) if paged
                else (b, kh, s_pad, hsz))
    pax = 0 if paged else None
    operands = [
        Operand("q", (b, kh, t_pad, g * hsz), (1, 1, blk_q, g * hsz),
                idx["q"]),
        Operand("k", kv_shape, (1, 1, blk_k, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
        Operand("v", kv_shape, (1, 1, blk_k, hsz), idx["kv"],
                streamed=True, paged_axis=pax),
        Operand("out", (b, kh, t_pad, g * hsz), (1, 1, blk_q, g * hsz),
                idx["q"], kind="out"),
    ]

    active = None
    if prune:
        _, nb = prefill_block_range(
            jnp.arange(t_pad // blk_q, dtype=jnp.int32)[None, :],
            jnp.asarray(lens)[:, None], jnp.asarray(offs)[:, None],
            jnp.asarray(window, jnp.int32), causal=causal, blk_q=blk_q,
            blk_k=blk_k, s_true=s)
        nb_np = np.asarray(nb)

        def active(bi, h, qi, ki, _nb=nb_np):
            return bool(ki < _nb[bi, qi])

    return KernelContract(
        family="flash_prefill", case=case,
        grid=(b, kh, t_pad // blk_q, n_kblocks), operands=operands,
        prefetch=prefetch, stream_axis=3, active=active, table=table,
        n_pool=n_pool,
        notes=dict(causal=causal, window=window, prune=prune, paged=paged,
                   blk_q=blk_q, blk_k=blk_k, s_true=s))


def flash_prefill_contract():
    """Contracts for the flash_prefill audit lattice (``repro.analysis``).

    One ``KernelContract`` per configuration in the default lattice —
    causal x window x prune x paged x ragged chunk packing — each binding
    the kernel's real index_map callables at toy shapes.
    """
    return [prefill_case_contract(**dict(c)) for c in _CONTRACT_LATTICE]
