"""jit'd public wrapper for flash_prefill: natural [B,T,Qh,hsz] layout,
padding to block multiples, GQA head grouping, scalar-prefetch packing."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_kernel
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("causal", "scale", "blk_q",
                                             "blk_k", "interpret"))
def flash_prefill(q, k, v, *, causal: bool = True, window=0, q_offset=0,
                  seq_lens=None, scale: float | None = None,
                  blk_q: int = 128, blk_k: int = 128, interpret: bool = True):
    """Full-sequence attention via the Pallas flash-prefill kernel.

    The kernel-backed sibling of ``models/attention.chunked_attention`` —
    this is the flash_prefill *family* entry point the kernel-backend
    registry routes to (``HelixConfig.prefill_backend``).

    Args:
      q: ``[B, T, Qh, hsz]`` queries; ``Qh % Kh == 0`` (GQA grouping).
      k, v: ``[B, S, Kh, hsz]`` keys/values.  ``S == T`` for causal
        self-attention; any ``S`` for cross attention (``causal=False``).
      causal: static — mask ``kpos > qpos`` (decoder self-attention).
      window: sliding window (``<= 0`` disables).  May be a *traced* scalar
        (per-layer local/global windows under ``lax.scan``).
      q_offset: global position of query row 0 (prefill continuation); may
        be traced.
      seq_lens: optional ``[B]`` int32 per-request valid KV lengths
        (continuous-batching prefill over right-padded prompts); kv positions
        ``>= seq_lens[b]`` are masked.  ``None`` means all ``S`` positions
        are live.  Rows with ``seq_lens[b] == 0`` emit zeros.
      scale: score scale; defaults to ``hsz ** -0.5``.
      blk_q, blk_k: kernel block sizes (static; see docs/kernels.md).
      interpret: run the kernel through the Pallas interpreter (any JAX
        backend) instead of compiling for TPU.

    Returns:
      ``[B, T, Qh, hsz]`` attention output in ``q.dtype``.
    """
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    assert qh % kh == 0
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5

    blk_q = min(blk_q, round_up(t, 8))
    blk_k = min(blk_k, round_up(s, 8))
    t_pad, s_pad = round_up(t, blk_q), round_up(s, blk_k)

    # [B,T,Kh,G,hsz] -> [B,Kh,T,G*hsz]
    qg = q.reshape(b, t, kh, g, hsz).transpose(0, 2, 1, 3, 4).reshape(
        b, kh, t, g * hsz)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    kg = jnp.pad(kg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vg = jnp.pad(vg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    # kv rows beyond the true S are masked in-kernel (s_true); pad q rows
    # produce well-defined garbage and are sliced away below.

    meta = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(window, jnp.int32)])
    if seq_lens is None:
        lens = jnp.full((b,), s, jnp.int32)
    else:
        lens = jnp.broadcast_to(jnp.asarray(seq_lens, jnp.int32), (b,))

    out = flash_prefill_kernel(qg, kg, vg, meta, lens, scale=scale,
                               causal=causal, blk_q=blk_q, blk_k=blk_k,
                               s_true=s, interpret=interpret)
    out = out[:, :, :t].reshape(b, kh, t, g, hsz).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, qh, hsz)
