"""jit'd public wrapper for flash_prefill: natural [B,T,Qh,hsz] layout,
padding to block multiples, GQA head grouping."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_prefill.kernel import flash_prefill_kernel
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("window", "scale", "blk_q",
                                             "blk_k", "interpret"))
def flash_prefill(q, k, v, *, window: int = 0, scale: float | None = None,
                  blk_q: int = 128, blk_k: int = 128, interpret: bool = True):
    """q [B, T, Qh, hsz]; k, v [B, S, Kh, hsz] -> [B, T, Qh, hsz] (causal)."""
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    assert qh % kh == 0
    g = qh // kh
    if scale is None:
        scale = float(hsz) ** -0.5

    blk_q = min(blk_q, round_up(t, 8))
    blk_k = min(blk_k, round_up(s, 8))
    t_pad, s_pad = round_up(t, blk_q), round_up(s, blk_k)

    # [B,T,Kh,G,hsz] -> [B,Kh,T,G*hsz]
    qg = q.reshape(b, t, kh, g, hsz).transpose(0, 2, 1, 3, 4).reshape(
        b, kh, t, g * hsz)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    kg = jnp.pad(kg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    vg = jnp.pad(vg, ((0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    # pad rows beyond S are masked by causality for q<t; pad q rows produce
    # garbage but are sliced away below.

    out = flash_prefill_kernel(qg, kg, vg, scale=scale, window=window,
                               blk_q=blk_q, blk_k=blk_k, interpret=interpret)
    out = out[:, :, :t].reshape(b, kh, t, g, hsz).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, qh, hsz)
