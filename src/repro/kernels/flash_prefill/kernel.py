"""Pallas TPU flash-prefill kernel (full-sequence attention).

TPU mapping
-----------
  grid = (B, Kh, T/blk_q, S/blk_k)   — kv blocks innermost; online-softmax
                                       state (m, l, acc) lives in VMEM scratch
                                       and persists across the kv loop.
  q block   (blk_q, G*hsz)  resident per (b, h, qi)
  k/v block (blk_k, hsz)    streamed HBM->VMEM
  out       written at the last kv step (full row normalized)

Masking semantics (shared with ref.py) are computed in-kernel from prefetched
scalars only — no per-position mask array is read from HBM:

  meta [2] int32 : (q_offset, window) — q_offset shifts the query positions
                   (prefill continuation); window <= 0 disables the sliding-
                   window mask and is a *runtime* scalar, so traced per-layer
                   windows (gemma3 local/global scan) work.
  lens [B] int32 : per-request valid KV lengths (continuous-batching prefill
                   over right-padded prompts); kv positions >= lens[b] are
                   masked.  Uniform batches prefetch a broadcast scalar.

``causal`` is a static kernel parameter: True for decoder self-attention
(key <= query), False for encoder-decoder cross attention (whisper), where
T != S and only the lens/capacity masks apply.  Slots >= the true (unpadded)
S are masked unconditionally, so S padding is exact even without causality.
Fully-masked rows (lens[b] == 0) emit zeros, not NaNs.

Causal block skipping: blocks entirely above the diagonal contribute
nothing; the kernel masks them (grid still visits them — revisited in the
perf pass via a triangular index_map when it matters on real hw).  MXU
contraction dims are hsz / blk_k (multiples of 128 for aligned configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import NEG_INF


def _prefill_kernel(meta_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref,
                    m_ref, l_ref, *, scale: float, causal: bool, blk_q: int,
                    blk_k: int, g: int, hsz: int, s_true: int):
    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_offset = meta_ref[0]
    window = meta_ref[1]
    kv_len = len_ref[bi]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [blq, G*hsz]
    k = k_ref[0, 0].astype(jnp.float32)                  # [blk, hsz]
    v = v_ref[0, 0].astype(jnp.float32)                  # [blk, hsz]

    qg = q.reshape(blk_q, g, hsz)
    s = jax.lax.dot_general(qg.reshape(blk_q * g, hsz), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s.reshape(blk_q, g, blk_k)

    qpos = q_offset + qi * blk_q \
        + jax.lax.broadcasted_iota(jnp.int32, (blk_q, 1, 1), 0)
    kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk_k), 2)
    # true-capacity + per-request-length masks apply in every mode; the
    # causal / sliding-window masks only relate q and kv positions.
    mask = jnp.logical_and(kpos < s_true, kpos < kv_len)
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    mask = jnp.logical_and(
        mask, jnp.where(window > 0, kpos > qpos - window, True))
    s = jnp.where(mask, s, NEG_INF)

    s2 = s.reshape(blk_q * g, blk_k)
    mask2 = jnp.broadcast_to(mask, (blk_q, g, blk_k)).reshape(blk_q * g, blk_k)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # masked lanes must not contribute when a whole row is masked
    # (m_new == NEG_INF => exp(0) == 1 would pollute l), so gate p.
    p = jnp.where(mask2, jnp.exp(s2 - m_new), 0.0)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-37)
        out = jnp.where(l > 0, acc_ref[...] / denom, 0.0)
        o_ref[0, 0] = out.reshape(blk_q, g * hsz).astype(o_ref.dtype)


def flash_prefill_kernel(q, k, v, meta, lens, *, scale: float, causal: bool,
                         blk_q: int, blk_k: int, s_true: int,
                         interpret: bool = True):
    """Raw pallas_call.  Shapes must already be padded/blocked (see ops.py).

    q [B, Kh, T_pad, G*hsz]; k, v [B, Kh, S_pad, hsz]; meta [2] int32
    (q_offset, window); lens [B] int32 per-request valid KV lengths;
    s_true: unpadded S (slots >= s_true are masked).

    Returns out [B, Kh, T_pad, G*hsz] in q.dtype.
    """
    b, kh, t, ghsz = q.shape
    s, hsz = k.shape[2], k.shape[3]
    g = ghsz // hsz
    assert t % blk_q == 0 and s % blk_k == 0

    grid = (b, kh, t // blk_q, s // blk_k)
    kernel = functools.partial(_prefill_kernel, scale=scale, causal=causal,
                               blk_q=blk_q, blk_k=blk_k, g=g, hsz=hsz,
                               s_true=s_true)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, blk_q, ghsz),
                             lambda b, h, qi, ki, *_: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, blk_k, hsz),
                             lambda b, h, qi, ki, *_: (b, h, ki, 0)),
                pl.BlockSpec((1, 1, blk_k, hsz),
                             lambda b, h, qi, ki, *_: (b, h, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, blk_q, ghsz),
                                   lambda b, h, qi, ki, *_: (b, h, qi, 0)),
            scratch_shapes=[
                pltpu.VMEM((blk_q * g, hsz), jnp.float32),
                pltpu.VMEM((blk_q * g, 1), jnp.float32),
                pltpu.VMEM((blk_q * g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, t, ghsz), q.dtype),
        interpret=interpret,
    )(meta, lens, q, k, v)
