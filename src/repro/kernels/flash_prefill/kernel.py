"""Pallas TPU flash-prefill kernel (full-sequence attention).

TPU mapping
-----------
  grid = (B, Kh, T/blk_q, S/blk_k)   — kv blocks innermost; online-softmax
                                       state (m, l, acc) lives in VMEM scratch
                                       and persists across the kv loop.
  q block   (blk_q, G*hsz)  resident per (b, h, qi)
  k/v block (blk_k, hsz)    streamed HBM->VMEM
  out       written at the last kv step (full row normalized)

Masking semantics (shared with ref.py) are computed in-kernel from prefetched
scalars only — no per-position mask array is read from HBM:

  meta [1] int32 : (window,) — window <= 0 disables the sliding-window mask
                   and is a *runtime* scalar, so traced per-layer windows
                   (gemma3 local/global scan) work.
  lens [B] int32 : per-request valid KV lengths (continuous-batching prefill
                   over right-padded prompts); kv positions >= lens[b] are
                   masked.  Uniform batches prefetch a broadcast scalar.
  offs [B] int32 : *per-request* q_offset — the global position of query
                   row 0 (prefill continuation).  Per-row offsets are what
                   let the serving engine pack requests at different
                   (offset, length) prefill progress into ONE ragged chunk
                   call (docs/serving.md); uniform batches prefetch a
                   broadcast scalar.

``causal`` is a static kernel parameter: True for decoder self-attention
(key <= query), False for encoder-decoder cross attention (whisper), where
T != S and only the lens/capacity masks apply.  Slots >= the true (unpadded)
S are masked unconditionally, so S padding is exact even without causality.
Fully-masked rows (lens[b] == 0) emit zeros, not NaNs.

Causal/window block skipping (``prune=True``, the default)
----------------------------------------------------------
For one query block the contributing kv positions form a contiguous span:
``kpos < min(s_true, lens[b])`` and, causally, ``kpos <= qpos_max``; with a
sliding window additionally ``kpos > qpos_min - window``.  The kernel clamps
the K/V ``index_map`` to that span — grid step ``ki`` streams physical block
``min(lo + ki, hi - 1)``, so every skipped step re-references the previous
block and Pallas TPU elides the HBM->VMEM DMA — and skips the compute body
with ``pl.when``.  For causal T = S this drops the visited rectangle to its
lower triangle (~(n+1)/2n of the full sweep); a window caps it at
O(window/blk_k) blocks per query row.  Bit-exact vs the masked sweep (a
fully-masked block contributes the identity online-softmax update).
``prefill_block_range`` is the single source of truth; the accounting layer
(ops.py) replays it to report visited blocks/bytes.  MXU contraction dims
are hsz / blk_k (multiples of 128 for aligned configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.utils import NEG_INF
from repro.kernels.pruning import phys_block as _phys_block
from repro.kernels.pruning import table_block as _table_block  # noqa: F401


def prefill_block_range(qi, kv_len, q_offset, window, *, causal: bool,
                        blk_q: int, blk_k: int, s_true: int):
    """(first_kv_block, n_valid_kv_blocks) for query block ``qi``.

    The single source of truth for prefill block skipping: the kernel's K/V
    ``index_map``s clamp to this range and its body skips compute outside
    it; ``ops.flash_prefill_accounting`` replays it to count streamed
    blocks.  ``qi``/``kv_len``/``q_offset``/``window`` may be traced scalars
    (grid index + scalar-prefetch values).
    """
    kv_len = jnp.asarray(kv_len, jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    hi_slot = jnp.minimum(s_true, kv_len)
    if causal:
        # a kv slot is causally reachable iff kpos <= the block's last qpos
        hi_slot = jnp.minimum(hi_slot, q_offset + (qi + 1) * blk_q)
    lo_slot = jnp.where(
        window > 0,
        jnp.clip(q_offset + qi * blk_q - window + 1, 0, s_true), 0)
    lo = lo_slot // blk_k
    hi = (hi_slot + blk_k - 1) // blk_k
    return lo, jnp.maximum(hi - lo, 0)


def prefill_index_maps(*, causal: bool, blk_q: int, blk_k: int, s_true: int,
                       n_kblocks: int, prune: bool, paged: bool):
    """Named index_map callables for one prefill-kernel configuration.

    The single source of truth for the kernel's DMA addressing:
    ``flash_prefill_kernel`` passes exactly these callables to
    ``pallas_call``, and ``ops.flash_prefill_contract`` exposes the same
    callables to the static index-space auditor (``repro.analysis``).

    Every map takes ``(b, h, qi, ki, meta_ref, len_ref, off_ref,
    [tables_ref])`` and is a pure jnp function of its arguments (no
    data-dependent python branches; see ``kernels/pruning.py``).  Keys:

      kv  streamed K/V blocks (1, 1, blk_k, hsz); skip-clamped, and
          table-indirected in paged mode
      q   resident query / output blocks (constant along the kv axis)
    """

    def kv_idx(b, h, qi, ki, meta_ref, len_ref, off_ref, *rest):
        if prune:
            lo, nb = prefill_block_range(
                qi, len_ref[b], off_ref[b], meta_ref[0], causal=causal,
                blk_q=blk_q, blk_k=blk_k, s_true=s_true)
            lg = _phys_block(ki, lo, nb, n_kblocks)
        else:
            lg = ki
        if paged:
            return (rest[0][b, lg], h, 0, 0)
        return (b, h, lg, 0)

    def q_idx(b, h, qi, ki, *_):
        return (b, h, qi, 0)

    return {"kv": kv_idx, "q": q_idx}


def _prefill_kernel(meta_ref, len_ref, off_ref, *refs, scale: float,
                    causal: bool, blk_q: int, blk_k: int, g: int, hsz: int,
                    s_true: int, prune: bool, paged: bool):
    if paged:
        _tbl_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    bi = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kblocks = pl.num_programs(3)
    q_offset = off_ref[bi]
    window = meta_ref[0]
    kv_len = len_ref[bi]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if prune:
        lo_blk, nb = prefill_block_range(qi, kv_len, q_offset, window,
                                         causal=causal, blk_q=blk_q,
                                         blk_k=blk_k, s_true=s_true)
        phys = _phys_block(ki, lo_blk, nb, n_kblocks)
        active = ki < nb
    else:
        phys, active = ki, None

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # [blq, G*hsz]
        k = k_ref[0, 0].astype(jnp.float32)              # [blk, hsz]
        v = v_ref[0, 0].astype(jnp.float32)              # [blk, hsz]

        qg = q.reshape(blk_q, g, hsz)
        s = jax.lax.dot_general(qg.reshape(blk_q * g, hsz), k,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s.reshape(blk_q, g, blk_k)

        qpos = q_offset + qi * blk_q \
            + jax.lax.broadcasted_iota(jnp.int32, (blk_q, 1, 1), 0)
        kpos = phys * blk_k \
            + jax.lax.broadcasted_iota(jnp.int32, (1, 1, blk_k), 2)
        # true-capacity + per-request-length masks apply in every mode; the
        # causal / sliding-window masks only relate q and kv positions.
        mask = jnp.logical_and(kpos < s_true, kpos < kv_len)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        mask = jnp.logical_and(
            mask, jnp.where(window > 0, kpos > qpos - window, True))
        s = jnp.where(mask, s, NEG_INF)

        s2 = s.reshape(blk_q * g, blk_k)
        mask2 = jnp.broadcast_to(mask, (blk_q, g, blk_k)).reshape(
            blk_q * g, blk_k)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s2, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # masked lanes must not contribute when a whole row is masked
        # (m_new == NEG_INF => exp(0) == 1 would pollute l), so gate p.
        p = jnp.where(mask2, jnp.exp(s2 - m_new), 0.0)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if prune:
        pl.when(active)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kblocks - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-37)
        out = jnp.where(l > 0, acc_ref[...] / denom, 0.0)
        o_ref[0, 0] = out.reshape(blk_q, g * hsz).astype(o_ref.dtype)


def flash_prefill_kernel(q, k, v, meta, lens, offs, *, scale: float,
                         causal: bool, blk_q: int, blk_k: int, s_true: int,
                         prune: bool = True, block_tables=None,
                         interpret: bool = True):
    """Raw pallas_call.  Shapes must already be padded/blocked (see ops.py).

    q [B, Kh, T_pad, G*hsz]; k, v [B, Kh, S_pad, hsz]; meta [1] int32
    (window,); lens [B] int32 per-request valid KV lengths; offs [B] int32
    per-request q_offset (ragged chunk packing);
    s_true: unpadded S (slots >= s_true are masked); prune: skip (don't
    mask) kv blocks that are causally/window/length-dead (bit-exact).

    Paged mode (``block_tables`` [B, max_pages] int32, scalar-prefetched):
    k/v are shared pool planes ``[n_pool, Kh, blk_k, hsz]``; grid step
    ``ki`` streams physical page ``block_tables[b, logical]`` where
    ``logical`` is the fixed layout's (possibly skip-clamped) kv-block id.
    All masking runs on logical positions, so paged == fixed bit-exactly.

    Returns out [B, Kh, T_pad, G*hsz] in q.dtype.
    """
    b, kh, t, ghsz = q.shape
    hsz = k.shape[3]
    g = ghsz // hsz
    paged = block_tables is not None
    if paged:
        assert k.shape[2] == blk_k, (k.shape, blk_k)
        n_kblocks = block_tables.shape[1]
        s = n_kblocks * blk_k
    else:
        s = k.shape[2]
        assert s % blk_k == 0
        n_kblocks = s // blk_k
    assert t % blk_q == 0

    grid = (b, kh, t // blk_q, n_kblocks)
    kernel = functools.partial(_prefill_kernel, scale=scale, causal=causal,
                               blk_q=blk_q, blk_k=blk_k, g=g, hsz=hsz,
                               s_true=s_true, prune=prune, paged=paged)

    idx = prefill_index_maps(causal=causal, blk_q=blk_q, blk_k=blk_k,
                             s_true=s_true, n_kblocks=n_kblocks, prune=prune,
                             paged=paged)
    kv_idx, q_idx = idx["kv"], idx["q"]

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4 if paged else 3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, blk_q, ghsz), q_idx),
                pl.BlockSpec((1, 1, blk_k, hsz), kv_idx),
                pl.BlockSpec((1, 1, blk_k, hsz), kv_idx),
            ],
            out_specs=pl.BlockSpec((1, 1, blk_q, ghsz), q_idx),
            scratch_shapes=[
                pltpu.VMEM((blk_q * g, hsz), jnp.float32),
                pltpu.VMEM((blk_q * g, 1), jnp.float32),
                pltpu.VMEM((blk_q * g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, t, ghsz), q.dtype),
        interpret=interpret,
    )(*((meta, lens, offs) + ((block_tables,) if paged else ())
        + (q, k, v)))
