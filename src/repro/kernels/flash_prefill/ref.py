"""Pure-jnp oracle for the flash_prefill kernel: full-sequence attention with
GQA grouping — causal or cross, optional sliding window, query-position
offset and per-request KV lengths (the complete model-caller contract)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.utils import NEG_INF


def flash_prefill_ref(q, k, v, *, causal: bool = True, window=0, q_offset=0,
                      seq_lens=None, scale: float | None = None):
    """Oracle full-sequence attention (defines the flash_prefill contract).

    Args:
      q: ``[B, T, Qh, hsz]``; k, v: ``[B, S, Kh, hsz]`` (``Qh % Kh == 0``).
      causal: query ``t`` attends keys ``<= t`` (positions offset by
        ``q_offset``); ``False`` = cross attention (whisper), any ``S``.
      window: sliding window of the ``w`` latest positions (``<= 0``
        disables; may be traced).
      q_offset: global position of query row 0 (may be traced).
      seq_lens: optional ``[B]`` int32 valid-KV lengths; kv positions
        ``>= seq_lens[b]`` are masked, fully-masked rows emit zeros.
      scale: score scale; defaults to ``hsz ** -0.5``.

    Returns: ``[B, T, Qh, hsz]`` in ``q.dtype``.
    """
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = qh // kh
    if scale is None:
        scale = hsz ** -0.5
    qf = q.astype(jnp.float32).reshape(b, t, kh, g, hsz) * scale
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, k.astype(jnp.float32))
    qpos = jnp.arange(t)[:, None] + jnp.asarray(q_offset)
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    w = jnp.asarray(window)
    mask = mask & jnp.where(w > 0, kpos > qpos - w, True)
    mask = jnp.broadcast_to(mask[None], (b, t, s))
    if seq_lens is not None:
        lens = jnp.asarray(seq_lens, jnp.int32)
        mask = mask & (kpos[None] < lens[:, None, None])
    maskh = mask[:, None, None, :, :]                    # [B,1,1,T,S]
    scores = jnp.where(maskh, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m_safe = jnp.where(m <= NEG_INF, 0.0, m)
    p = jnp.where(maskh, jnp.exp(scores - m_safe), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgts,bskd->btkgd",
                     p / jnp.maximum(l, 1e-37), v.astype(jnp.float32))
    return out.reshape(b, t, qh, hsz).astype(q.dtype)
