"""Pure-jnp oracle for the flash_prefill kernel: causal (optionally
sliding-window) full-sequence attention with GQA grouping."""
from __future__ import annotations

import jax.numpy as jnp

from repro.utils import NEG_INF


def flash_prefill_ref(q, k, v, *, window: int = 0, scale: float | None = None):
    """q [B, T, Qh, hsz]; k, v [B, S, Kh, hsz] -> out [B, T, Qh, hsz].

    Causal: query t attends keys <= t (+ optional window of w latest).
    """
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = qh // kh
    if scale is None:
        scale = hsz ** -0.5
    qf = q.astype(jnp.float32).reshape(b, t, kh, g, hsz) * scale
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, k.astype(jnp.float32))
    qpos = jnp.arange(t)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, qh, hsz).astype(q.dtype)
