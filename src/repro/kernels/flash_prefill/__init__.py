from repro.kernels.flash_prefill.ops import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref

__all__ = ["flash_prefill", "flash_prefill_ref"]
