from repro.kernels.flash_prefill.ops import (flash_prefill,
                                             flash_prefill_accounting)
from repro.kernels.flash_prefill.kernel import prefill_block_range
from repro.kernels.flash_prefill.ref import flash_prefill_ref

__all__ = ["flash_prefill", "flash_prefill_accounting", "flash_prefill_ref",
           "prefill_block_range"]
