"""Unified kernel-backend registry: one switchboard for every kernel family.

Every compute hotspot in this repo ships as a *family* of interchangeable
implementations of one contract:

  * ``ref``              — the pure-jnp oracle (always available, defines the
                           semantics; also the gradient path).
  * ``pallas-interpret`` — the Pallas TPU kernel executed by the Pallas
                           interpreter.  Runs on any JAX backend (CPU CI),
                           proves the kernel's *semantics*, not its speed.
  * ``pallas``           — the same kernel compiled for real TPU hardware.

The four registered families (see ``FAMILIES``):

  ============== ============================== ==============================
  family         used by                        oracle
  ============== ============================== ==============================
  flash_decode   Helix decode attention         kernels/flash_decode/ref.py
                 (core/helix.py::_local_attend)
  flash_prefill  full-sequence attention        kernels/flash_prefill/ref.py
                 (models/attention.py prefill)
  ssd_prefill    Mamba2 SSD scan core           kernels/ssd_prefill/ref.py
                 (models/ssm.py::ssd_chunked)
  w8a16_matmul   int8-weight matmul             kernels/w8a16_matmul/ref.py
                 (weight-quantized projections)
  ============== ============================== ==============================

Selection is per-family via ``HelixConfig`` (core/sharding.py):
``attn_backend`` (flash_decode), ``prefill_backend`` (flash_prefill),
``ssd_backend`` (ssd_prefill), ``matmul_backend`` (w8a16_matmul) — plumbed
through ``build_serve_step`` / ``make_prefill_step`` / ``make_train_step``,
``launch/serve.py`` / ``launch/train.py`` CLI flags and the serving engine.

This module is intentionally free of model imports (kernels are the bottom
layer); call sites ask the registry to *validate* and *describe* backends and
to map a backend string to the ``interpret`` flag of the family's Pallas op.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax

BACKENDS = ("ref", "pallas-interpret", "pallas")

# HelixConfig field name -> kernel family routed by it.
FAMILY_FIELDS = {
    "attn_backend": "flash_decode",
    "prefill_backend": "flash_prefill",
    "ssd_backend": "ssd_prefill",
    "matmul_backend": "w8a16_matmul",
}


@dataclasses.dataclass(frozen=True)
class KernelFamily:
    """One kernel family: a contract with a ref oracle and a Pallas kernel.

    ``ref`` / ``kernel`` are import paths resolved lazily (the registry must
    import before any kernel module so families can self-describe without
    cycles).  ``grad`` records how gradients flow through the Pallas path:
    ``"ref-vjp"`` = custom_vjp whose backward re-runs the oracle;
    ``"none"`` = forward-only (decode has no backward pass).

    ``accounting`` (optional) names the family's block/bytes accounting
    function — host-side arithmetic that replays the kernel's pruning
    ``index_map`` and reports the HBM blocks/bytes a call streams
    (benchmarks and the CI pruning smoke consume it via
    ``registry.accounting``).

    ``contract`` names the family's static-analysis contract hook: a
    zero-argument function returning the ``KernelContract`` list
    (``kernels/contract.py``) the index-space auditor (``repro.analysis``)
    proves bounds/DMA-elision/alias-race properties over.  Every family
    must carry one — ``scripts/analyze.py --strict`` fails loudly
    (``contract.missing``) for a family without it rather than silently
    skipping it.
    """
    name: str
    ref: str                  # "module:function" of the pure-jnp oracle
    kernel: str               # "module:function" of the Pallas op wrapper
    used_by: str              # call-site summary for the backend table
    grad: str = "none"        # "none" | "ref-vjp"
    accounting: str | None = None   # "module:function" block accounting
    contract: str | None = None     # "module:function" analysis contracts

    def _load(self, spec: str) -> Callable:
        import importlib
        mod, fn = spec.split(":")
        return getattr(importlib.import_module(mod), fn)

    def resolve(self, backend: str) -> Callable:
        """Return the family's callable for ``backend``.

        ``ref`` returns the oracle; the Pallas backends return the op wrapper
        (call it with ``interpret=interpret_flag(backend)``).  Call sites that
        need backend-specific argument mapping keep doing it themselves — the
        registry's job is routing and validation, not signature unification.
        """
        validate(self.name, backend)
        return self._load(self.ref if backend == "ref" else self.kernel)


FAMILIES: dict[str, KernelFamily] = {
    f.name: f for f in (
        KernelFamily(
            name="flash_decode",
            ref="repro.kernels.flash_decode.ref:flash_decode_ref",
            kernel="repro.kernels.flash_decode.ops:flash_decode",
            used_by="Helix decode attention (core/helix._local_attend)",
            grad="none",
            accounting="repro.kernels.flash_decode.ops:"
                       "flash_decode_accounting",
            contract="repro.kernels.flash_decode.ops:"
                     "flash_decode_contract"),
        KernelFamily(
            name="flash_prefill",
            ref="repro.kernels.flash_prefill.ref:flash_prefill_ref",
            kernel="repro.kernels.flash_prefill.ops:flash_prefill",
            used_by="prefill/train attention (models/attention."
                    "prefill_attention)",
            grad="ref-vjp",
            accounting="repro.kernels.flash_prefill.ops:"
                       "flash_prefill_accounting",
            contract="repro.kernels.flash_prefill.ops:"
                     "flash_prefill_contract"),
        KernelFamily(
            name="ssd_prefill",
            ref="repro.kernels.ssd_prefill.ref:ssd_prefill_ref",
            kernel="repro.kernels.ssd_prefill.ops:ssd_prefill",
            used_by="Mamba2 SSD prefill core (models/ssm.ssd_chunked)",
            grad="ref-vjp",
            contract="repro.kernels.ssd_prefill.ops:ssd_prefill_contract"),
        KernelFamily(
            name="w8a16_matmul",
            ref="repro.kernels.w8a16_matmul.ref:w8a16_matmul_ref",
            kernel="repro.kernels.w8a16_matmul.ops:w8a16_matmul",
            used_by="int8-weight lm_head matmul (decode_model, "
                    "HelixConfig.lm_head_w8); its logits feed the fused "
                    "on-device sampling epilogue (serving/sampling.py)",
            grad="none",
            contract="repro.kernels.w8a16_matmul.ops:"
                     "w8a16_matmul_contract"),
    )
}


def validate(family: str, backend: str) -> str:
    """Assert ``family``/``backend`` are registered; returns ``backend``."""
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"registered: {sorted(FAMILIES)}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r} for family "
                         f"{family!r}; choose from {BACKENDS}")
    return backend


def resolve(family: str, backend: str) -> Callable:
    """Shorthand for ``FAMILIES[family].resolve(backend)``."""
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"registered: {sorted(FAMILIES)}")
    return FAMILIES[family].resolve(backend)


def accounting(family: str) -> Callable:
    """The family's block/bytes accounting function (see ``KernelFamily``).

    Raises ``ValueError`` for unknown families and families without an
    accounting layer (only the pruning attention kernels carry one).
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"registered: {sorted(FAMILIES)}")
    fam = FAMILIES[family]
    if fam.accounting is None:
        raise ValueError(f"kernel family {family!r} has no accounting layer")
    return fam._load(fam.accounting)


def contract_suite(family: str) -> list:
    """The family's ``KernelContract`` list for the static auditor.

    Loads and calls the family's ``contract`` hook (see ``KernelFamily``).
    Raises ``ValueError`` for unknown families and for families without a
    contract hook — the analyzer turns the latter into a
    ``contract.missing`` finding instead of silently skipping the family.
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown kernel family {family!r}; "
                         f"registered: {sorted(FAMILIES)}")
    fam = FAMILIES[family]
    if fam.contract is None:
        raise ValueError(f"kernel family {family!r} has no analysis "
                         f"contract hook (see docs/analysis.md)")
    return fam._load(fam.contract)()


def interpret_flag(backend: str) -> bool:
    """The ``interpret=`` value for a Pallas backend string."""
    assert backend in ("pallas-interpret", "pallas"), backend
    return backend != "pallas"


def uses_kernel(backend: str) -> bool:
    """True when ``backend`` routes to the Pallas kernel (either mode)."""
    return backend in ("pallas-interpret", "pallas")


def available(family: str, backend: str) -> tuple[bool, str]:
    """(is_available_here, reason).  ``pallas`` needs a real TPU device;
    ``ref`` and ``pallas-interpret`` run on every JAX backend."""
    validate(family, backend)
    if backend != "pallas":
        return True, "any backend"
    plat = jax.devices()[0].platform
    if plat == "tpu":
        return True, "tpu detected"
    return False, f"needs TPU (this host: {plat})"


def backend_table() -> str:
    """Human-readable per-family backend availability matrix.

    Printed by ``launch/serve.py --list-backends`` and doubles as a CI smoke
    target (scripts/ci.sh) — it imports every registered family lazily, so a
    broken kernel module fails the listing.
    """
    rows = [f"{'family':<14s} {'grad':<8s} "
            + "".join(f"{b:<18s}" for b in BACKENDS)
            + f"{'contract':<10s}" + "  used by"]
    rows.append("-" * 88)
    for name, fam in FAMILIES.items():
        cells = []
        for b in BACKENDS:
            ok, why = available(name, b)
            cells.append("yes" if ok else f"no: {why.split(' (')[0]}")
        for backend in ("ref", "pallas-interpret"):
            # resolving imports the module: a broken kernel fails loudly here
            fam.resolve(backend)
        if fam.contract is not None:
            # same loud-failure policy for the analysis contract hook
            fam._load(fam.contract)
            contract_cell = "yes"
        else:
            contract_cell = "MISSING"
        rows.append(f"{name:<14s} {fam.grad:<8s} "
                    + "".join(f"{c:<18s}" for c in cells)
                    + f"{contract_cell:<10s}" + f"  {fam.used_by}")
    return "\n".join(rows)
