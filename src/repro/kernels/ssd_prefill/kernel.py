"""Pallas TPU SSD-prefill kernel: Mamba2 chunked state-space scan.

TPU mapping
-----------
  grid = (B, nh, T/Lc)  — chunks innermost; the running state [hd, ds] lives
                          in VMEM scratch, carried across chunk iterations
                          (sequential TPU grid), so HBM traffic is O(T) not
                          O(T·ds).
  per chunk (Lc tokens): the SSD block-matrix form —
    intra:  Y += (tril(C Bᵀ ∘ decay) · diag(dt)) X          (two MXU matmuls)
    inter:  Y += (C · h_in) ∘ exp(cum)
    state:  h_out = exp(cum_last) h_in + Σ_j exp(cum_last-cum_j) dt_j B_j⊗X_j

  Lc and hd/ds are chosen MXU-friendly (Lc=64/128, hd=64, ds=64/128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def ssd_index_maps():
    """Named index_map callables for the SSD-prefill kernel.

    The single source of truth for the kernel's block addressing:
    ``ssd_prefill_kernel`` passes exactly these callables to
    ``pallas_call``, and ``ops.ssd_prefill_contract`` exposes them to the
    static index-space auditor (``repro.analysis``).  All maps are static
    functions of the grid coordinates ``(b, h, c)`` — the SSD scan
    prefetches no scalars.  Keys:

      chunk  token-chunk streams (x, dt, B, C, y) — block (1, 1, lc, ·)
      head   per-head constants (a, d) — block (1, 1)
      state  chunk-carry state (h0, h_out) — resident along the chunk axis
    """
    return {
        "chunk": lambda b, h, c: (b, h, c, 0),
        "head": lambda b, h, c: (h, 0),
        "state": lambda b, h, c: (b, h, 0, 0),
    }


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref, y_ref,
                hout_ref, h_ref, *, lc: int, hd: int, ds: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        # chunk-carry state seeded from the caller's initial state (prefill
        # continuation / engine re-prefill); zeros for a fresh sequence.
        h_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)                  # [lc, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)                # [lc, 1]
    a = a_ref[0]                                         # [1] f32
    bm = b_ref[0, 0].astype(jnp.float32)                 # [lc, ds]
    cm = c_ref[0, 0].astype(jnp.float32)                 # [lc, ds]
    dskip = d_ref[0]                                     # [1]

    dta = dt[:, 0] * a[0]                                # [lc]
    cum = jnp.cumsum(dta)                                # [lc]

    # intra-chunk: w[i,j] = (C_i·B_j) exp(cum_i - cum_j) dt_j  (i >= j)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [lc, lc]
    decay = jnp.exp(cum[:, None] - cum[None, :])
    tri = jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1)
    w = jnp.where(tri, cb * decay, 0.0) * dt[:, 0][None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [lc, hd]

    # inter-chunk: y += exp(cum_i) * C_i · h_in
    h_in = h_ref[...]                                    # [hd, ds]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y + dskip[0] * x).astype(y_ref.dtype)

    # state update: h_out = exp(cum_last) h_in + sum_j seg_j dt_j x_j ⊗ B_j
    seg = jnp.exp(cum[-1] - cum) * dt[:, 0]              # [lc]
    dbx = jax.lax.dot_general(x * seg[:, None], bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [hd, ds]
    h_ref[...] = jnp.exp(cum[-1]) * h_in + dbx

    @pl.when(ci == pl.num_programs(2) - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_prefill_kernel(x, dt, a, bmat, cmat, d, h0, *, lc: int,
                       interpret: bool = True):
    """Pre-blocked shapes: x [B, nh, T, hd]; dt [B, nh, T, 1];
    a, d [nh, 1] f32; bmat, cmat [B, nh, T, ds]; h0 [B, nh, hd, ds] f32
    initial state.  T % lc == 0.

    Returns (y [B, nh, T, hd] f32, h_final [B, nh, hd, ds] f32).
    """
    b, nh, t, hd = x.shape
    ds = bmat.shape[-1]
    assert t % lc == 0
    grid = (b, nh, t // lc)
    kernel = functools.partial(_ssd_kernel, lc=lc, hd=hd, ds=ds)
    idx = ssd_index_maps()
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, lc, hd), idx["chunk"]),
            pl.BlockSpec((1, 1, lc, 1), idx["chunk"]),
            pl.BlockSpec((1, 1), idx["head"]),
            pl.BlockSpec((1, 1, lc, ds), idx["chunk"]),
            pl.BlockSpec((1, 1, lc, ds), idx["chunk"]),
            pl.BlockSpec((1, 1), idx["head"]),
            pl.BlockSpec((1, 1, hd, ds), idx["state"]),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, lc, hd), idx["chunk"]),
            pl.BlockSpec((1, 1, hd, ds), idx["state"]),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, t, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, bmat, cmat, d, h0)
