from repro.kernels.ssd_prefill.ops import ssd_prefill
from repro.kernels.ssd_prefill.ref import ssd_prefill_ref

__all__ = ["ssd_prefill", "ssd_prefill_ref"]
