"""jit'd public wrapper for ssd_prefill: natural layouts + group expansion."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_prefill.kernel import ssd_prefill_kernel
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("lc", "interpret"))
def ssd_prefill(x, dt, a, bmat, cmat, d, *, lc: int = 64,
                interpret: bool = True):
    """Natural shapes (matching ssd_prefill_ref):

    x [B, T, nh, hd], dt [B, T, nh], a [nh], bmat/cmat [B, T, nh, ds],
    d [nh] -> (y [B, T, nh, hd] f32, h [B, nh, hd, ds] f32).
    """
    b, t, nh, hd = x.shape
    ds = bmat.shape[-1]
    lc = min(lc, round_up(t, 8))
    t_pad = round_up(t, lc)
    pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
    # pad timesteps with dt=0 => da=1, no state contribution; y rows sliced
    xb = jnp.pad(x, pad).transpose(0, 2, 1, 3)
    dtb = jnp.pad(dt, pad[:3]).transpose(0, 2, 1)[..., None]
    bb = jnp.pad(bmat, pad).transpose(0, 2, 1, 3)
    cb = jnp.pad(cmat, pad).transpose(0, 2, 1, 3)
    y, h = ssd_prefill_kernel(
        xb, dtb, a.astype(jnp.float32)[:, None],
        bb, cb, d.astype(jnp.float32)[:, None], lc=lc, interpret=interpret)
    return y.transpose(0, 2, 1, 3)[:, :t], h
