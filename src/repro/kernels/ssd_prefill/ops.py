"""jit'd public wrapper for ssd_prefill: natural layouts + group expansion."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_prefill.kernel import ssd_prefill_kernel
from repro.utils import round_up


@functools.partial(jax.jit, static_argnames=("lc", "interpret"))
def ssd_prefill(x, dt, a, bmat, cmat, d, *, h0=None, lc: int = 64,
                interpret: bool = True):
    """Mamba2 SSD prefill scan core via the Pallas kernel.

    The kernel-backed sibling of the ``models/ssm.ssd_chunked`` scan core —
    this is the ssd_prefill *family* entry point the kernel-backend registry
    routes to (``HelixConfig.ssd_backend``).  Natural shapes (matching
    ``ssd_prefill_ref``):

    Args:
      x: ``[B, T, nh, hd]`` inputs (post conv + silu).
      dt: ``[B, T, nh]`` softplus'd timestep.
      a: ``[nh]`` negative decay rate (``A = -exp(A_log)``).
      bmat, cmat: ``[B, T, nh, ds]`` in/out projections (group-expanded).
      d: ``[nh]`` skip.
      h0: optional ``[B, nh, hd, ds]`` initial state (prefill continuation);
        ``None`` = zeros.
      lc: chunk length (static; MXU-friendly 64/128).
      interpret: Pallas interpreter (any backend) vs compiled TPU kernel.

    Returns:
      ``(y [B, T, nh, hd] f32, h_final [B, nh, hd, ds] f32)``.
    """
    b, t, nh, hd = x.shape
    ds = bmat.shape[-1]
    lc = min(lc, round_up(t, 8))
    t_pad = round_up(t, lc)
    pad = ((0, 0), (0, t_pad - t), (0, 0), (0, 0))
    # pad timesteps with dt=0 => da=1, no state contribution; y rows sliced
    xb = jnp.pad(x, pad).transpose(0, 2, 1, 3)
    dtb = jnp.pad(dt, pad[:3]).transpose(0, 2, 1)[..., None]
    bb = jnp.pad(bmat, pad).transpose(0, 2, 1, 3)
    cb = jnp.pad(cmat, pad).transpose(0, 2, 1, 3)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    y, h = ssd_prefill_kernel(
        xb, dtb, a.astype(jnp.float32)[:, None],
        bb, cb, d.astype(jnp.float32)[:, None], h0.astype(jnp.float32),
        lc=lc, interpret=interpret)
    return y.transpose(0, 2, 1, 3)[:, :t], h

# --- static-analysis contract -------------------------------------------

from repro.kernels.contract import KernelContract, Operand  # noqa: E402
from repro.kernels.ssd_prefill.kernel import ssd_index_maps  # noqa: E402


def ssd_prefill_contract():
    """Contracts for the ssd_prefill audit lattice (``repro.analysis``).

    The SSD scan has no scalar prefetch, pruning, or aliasing — the
    contract pins the static chunk/head/state block addressing
    (``kernel.ssd_index_maps``, the same callables ``ssd_prefill_kernel``
    passes to ``pallas_call``) over a small chunked and a single-chunk
    geometry so the auditor proves in-bounds access and that the
    chunk-carry state stays resident along the scan axis.
    """
    contracts = []
    for case, (b, nh, t, hd, ds, lc) in (
            ("chunked", (2, 2, 8, 8, 8, 4)),
            ("one-chunk", (1, 2, 4, 8, 8, 4))):
        idx = ssd_index_maps()
        operands = [
            Operand("x", (b, nh, t, hd), (1, 1, lc, hd), idx["chunk"],
                    streamed=True),
            Operand("dt", (b, nh, t, 1), (1, 1, lc, 1), idx["chunk"],
                    streamed=True),
            Operand("a", (nh, 1), (1, 1), idx["head"]),
            Operand("bmat", (b, nh, t, ds), (1, 1, lc, ds), idx["chunk"],
                    streamed=True),
            Operand("cmat", (b, nh, t, ds), (1, 1, lc, ds), idx["chunk"],
                    streamed=True),
            Operand("d", (nh, 1), (1, 1), idx["head"]),
            Operand("h0", (b, nh, hd, ds), (1, 1, hd, ds), idx["state"]),
            Operand("y", (b, nh, t, hd), (1, 1, lc, hd), idx["chunk"],
                    kind="out"),
            Operand("h_out", (b, nh, hd, ds), (1, 1, hd, ds), idx["state"],
                    kind="out"),
        ]
        contracts.append(KernelContract(
            family="ssd_prefill", case=case, grid=(b, nh, t // lc),
            operands=operands, stream_axis=2,
            notes=dict(lc=lc, hd=hd, ds=ds)))
    return contracts
