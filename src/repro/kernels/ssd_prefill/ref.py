"""Pure-jnp oracle for the ssd_prefill kernel: the exact SSD recurrence
over pre-projected inputs (post conv/act/split — the kernel covers the scan
core, which is the compute hotspot of mamba2 prefill)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_prefill_ref(x, dt, a, bmat, cmat, d, *, h0=None):
    """Sequential-scan oracle.

    x    [B, T, nh, hd]   inputs (post conv+silu)
    dt   [B, T, nh]       softplus'd timestep
    a    [nh]             negative decay rate (A = -exp(A_log))
    bmat [B, T, nh, ds]   input projection (already group-expanded)
    cmat [B, T, nh, ds]   output projection
    d    [nh]             skip
    h0   [B, nh, hd, ds]  optional initial state

    Returns (y [B, T, nh, hd] f32, h_final [B, nh, hd, ds] f32).
    """
    b, t, nh, hd = x.shape
    ds = bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    da = jnp.exp(dtf * a)                                 # [B,T,nh]
    h = jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None else h0

    def step(h, inp):
        da_t, dt_t, x_t, b_t, c_t = inp
        h = da_t[:, :, None, None] * h \
            + (dt_t[:, :, None] * x_t)[..., None] * b_t[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    hf, ys = jax.lax.scan(
        step, h,
        (da.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
         xf.transpose(1, 0, 2, 3),
         bmat.astype(jnp.float32).transpose(1, 0, 2, 3),
         cmat.astype(jnp.float32).transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3) + d[None, None, :, None] * xf
    return y, hf
