"""int8 gradient compression with error feedback for the DCN pod axis.

Motivation (DESIGN.md §3): the cross-pod ("pod" axis) all-reduce crosses
DCN/optical links with ~10x less bandwidth than intra-pod ICI.  Compressing
the pod-axis gradient exchange to int8 (per-tensor max scaling) quarters the
bytes vs f32 / halves vs bf16; error feedback keeps the *accumulated*
quantization error bounded so convergence is unaffected (standard EF-SGD
result).

``compressed_pod_mean`` is the real collective: used inside a
``shard_map(..., axis_names={'pod'})`` region (manual over 'pod' only, GSPMD
elsewhere) so the int8 tensors are what crosses the pod axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_compress(g, error):
    """(g + error) -> (q int8, scale f32, new_error).  Per-tensor scaling."""
    gf = g.astype(jnp.float32) + error
    scale = jnp.max(jnp.abs(gf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_error = gf - q.astype(jnp.float32) * scale
    return q, scale, new_error


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_pod_mean(grads, errors, axis_name: str = "pod"):
    """Mean-reduce a gradient pytree across ``axis_name`` in int8.

    Per leaf: all ranks agree on the max scale (one scalar psum), quantize,
    psum the int8 payload in int32, dequantize.  Returns (mean_grads,
    new_errors).  Error feedback buffers live in the optimizer state.
    """
    # jax.lax.axis_size is missing on older JAX; psum(1) is the portable form
    npods = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        local_scale = jnp.max(jnp.abs(gf)) / 127.0
        scale = jax.lax.pmax(jnp.maximum(local_scale, 1e-30), axis_name)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_e = gf - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = total.astype(jnp.float32) * scale / npods
        return mean.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
