"""AdamW + cosine schedule + global-norm clipping (pure jnp, pytree-wise).

Moment dtype is configurable: f32 default; bf16 halves optimizer memory for
the largest archs (arctic-480b single-pod train fits under 16 GiB/chip with
bf16 moments — DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: Any = jnp.float32


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(
        g.dtype), grads), gnorm


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    lr = cosine_schedule(cfg, step)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.zeros(())
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
