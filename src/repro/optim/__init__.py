from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule, clip_by_global_norm)
from repro.optim.compression import (int8_compress, int8_decompress,
                                     compressed_pod_mean)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "clip_by_global_norm", "int8_compress", "int8_decompress",
           "compressed_pod_mean"]
