"""Helix decode path: one full autoregressive step for every architecture.

``build_serve_step(cfg, mesh, hx)`` returns a jit-able

    serve_step(params, state, tokens) -> (next_tokens, new_state)

implementing the paper's per-layer temporal pipeline:

  attention phase — QKV projected per-rank (replicated batch), round-robin
  KV append (§2.3), helix_attention (shard_map: flash-decode over the local
  KV shard + single all-to-all over the query-head axis + LSE combine,
  optionally HOP-B batch-chunked, §2.1.3);

  FFN phase — the *same* device pool re-provisioned via GSPMD sharding
  constraints: dense FFN with TPF = N, or MoE with EP×TPF (§2.2).

``build_serve_multistep(cfg, mesh, hx, window=N)`` wraps the same forward
core in a ``lax.scan`` over N tokens — sample (serving/sampling.py fused
epilogue) -> fused KV append -> next step — entirely on device, with
per-row EOS / budget / forced-token control carried as masks, so the
serving engine's host round-trip drops from once per token to once per
window (``DecodeEngine --decode-window``).

Everything outside helix_attention is GSPMD (pjit constraints); that is the
TPU-idiomatic equivalent of the paper's GPU-pool reconfiguration.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.helix import (append_kv, append_kv_quant,
                              fuse_append_applicable, helix_attention)
from repro.core.sharding import HelixConfig
from repro.models import ssm as ssm_lib
from repro.models.layers import (activation, apply_rope, rms_norm,
                                 sinusoidal_at, softcap)
from repro.models.moe import MoEParams, moe_ffn
from repro.models.transformer import layer_windows


def quantize_lm_head(params):
    """Pre-quantize the lm_head (or tied-embedding) weights for the
    ``HelixConfig.lm_head_w8`` decode path: returns a copy of ``params``
    with ``lm_head_q8`` (int8 [H, V]) and ``lm_head_scale`` (f32 [V])
    added, so ``serve_step`` skips the per-step re-quantization.  Done once
    by the serving engine; decoding with unaugmented params still works
    (the step falls back to quantizing in-jit)."""
    from repro.kernels.w8a16_matmul.ref import quantize_w8
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    qw, scale = quantize_w8(head)
    out = dict(params)
    out["lm_head_q8"], out["lm_head_scale"] = qw, scale
    return out


def prepare_decode_params(params, hx: HelixConfig | None):
    """One-time decode-param preparation every ``serve_step`` caller should
    run before stepping: with ``hx.lm_head_w8`` it pre-quantizes the lm_head
    (``quantize_lm_head``) so the step doesn't re-quantize the ``[H, V]``
    matrix every token; otherwise it is the identity.  Idempotent — params
    already carrying ``lm_head_q8`` pass through untouched — so the serving
    engine, the launch/serve one-shot path and the benchmarks can all call
    it unconditionally."""
    if hx is not None and hx.lm_head_w8 and "lm_head_q8" not in params:
        return quantize_lm_head(params)
    return params


def _constrainer(mesh: Mesh):
    def c(x, *axes):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*axes)))
    return c


def _resolve_overrides(hx: HelixConfig, **overrides_in) -> HelixConfig:
    """Apply the per-builder HelixConfig field overrides (None = keep)."""
    import dataclasses
    overrides = {field: val for field, val in overrides_in.items()
                 if val is not None and val != getattr(hx, field)}
    return dataclasses.replace(hx, **overrides) if overrides else hx


def _next_token(logits, state):
    """The decode epilogue's token decision: the on-device sampler
    (serving/sampling.py — greedy/temperature/top-k/top-p from the per-row
    ``sample_*`` state leaves) when the state carries sampling leaves,
    otherwise the historical plain argmax.  Structural gating on
    ``sample_seed`` mirrors the grouped-decode ``group_id`` pattern: engines
    built without sampling never pay for (or trace) the sampler."""
    if "sample_seed" in state:
        from repro.serving.sampling import sample_tokens
        return sample_tokens(logits, state["sample_temp"],
                             state["sample_topk"], state["sample_topp"],
                             state["sample_seed"], state["sample_idx"])
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _build_step_logits(cfg: ArchConfig, mesh: Mesh, hx: HelixConfig, *,
                       hopb_chunks: int = 4, unroll: bool = False):
    """The shared forward core behind ``build_serve_step`` and
    ``build_serve_multistep``: returns

        step_logits(params, state, tokens) -> (logits, new_caches)

    one full decode forward pass — embed, layer-period scan (attention /
    SSM / FFN phases), final norm, (w8a16) lm_head matmul, softcap and
    vocab pad mask — *without* the token decision or state-dict rebuild, so
    the two builders can attach their own epilogues (single-step sampler vs
    the windowed ``lax.scan``)."""
    import math

    from repro.core.helix import helix_out_dim
    from repro.core.sharding import dense_ffn_mode

    kvp = hx.kvp(mesh)
    tpa_ax = hx.tpa_axis
    all_ax = hx.all_axes()
    n_all = math.prod(mesh.shape[a] for a in all_ax)
    tpf = tuple(a for a in ("pod", "model") if a in all_ax) or None
    windows = layer_windows(cfg)
    act = activation(cfg.act)
    cst = _constrainer(mesh)
    o_dim = helix_out_dim(cfg.q_dim, n_all)       # padded a2a output dim
    ffn2d = cfg.d_ff and dense_ffn_mode(cfg, mesh, hx) == "2d"
    dp_ish = tuple(a for a in mesh.axis_names if a != "model")
    kv8 = hx.kv_cache_bits == 8                   # int8 KV cache (§Perf)

    def head_matmul(x, head, params):
        """Logits matmul; ``hx.lm_head_w8`` routes it through the
        w8a16_matmul kernel family (the registry's end-to-end consumer):
        per-column int8 weight quantization, backend per
        ``hx.matmul_backend``.  Weight-only quantization — activations stay
        fp, so this changes numerics (unlike the exact kernel knobs).
        Pre-quantized weights (``lm_head_q8``/``lm_head_scale`` in params —
        ``quantize_lm_head``, done once by the serving engine) are used when
        present; otherwise the head is quantized in-step, which re-runs the
        O(d_model * vocab) quantization every token."""
        if not hx.lm_head_w8:
            return x @ head
        from repro.kernels import registry
        from repro.kernels.w8a16_matmul.ref import quantize_w8
        qw, scale = params.get("lm_head_q8"), params.get("lm_head_scale")
        if qw is None:
            qw, scale = quantize_w8(head)
        fn = registry.resolve("w8a16_matmul", hx.matmul_backend)
        if registry.uses_kernel(hx.matmul_backend):
            return fn(x, qw, scale,
                      interpret=registry.interpret_flag(hx.matmul_backend))
        return fn(x, qw, scale)

    def out_proj(out, wo):
        """Post-attention projection; pads wo rows when the a2a flat dim was
        padded (exact: pad rows multiply the zero pad lanes)."""
        if o_dim != wo.shape[0]:
            wo = jnp.pad(wo, ((0, o_dim - wo.shape[0]), (0, 0)))
        return cst(out @ wo, None, None)

    def attn_phase(lp, h, kc, vc, ks, vs, tl_attn, win, tables, groups=None):
        """Helix attention phase for one layer.  h [B,H] (replicated).
        ``tables`` is the paged pool's [B, max_pages] block table (None in
        the fixed-cap layout); kc/vc/ks/vs are then pool planes.
        ``groups`` is the grouped shared-prefix decode's (group_id,
        group_np) [B] pair (None = ungrouped; forces hopb_chunks=1)."""
        b = h.shape[0]
        # qkv_shard (§Perf, beyond-paper): weights over 'model', all-gather
        # the tiny activations — vs the paper's replicated per-rank QKV.
        qkv_ax = "model" if hx.qkv_shard and not tpa_ax else tpa_ax
        q = cst(cst(h @ lp["wq"], None, qkv_ax),
                None, tpa_ax).reshape(b, cfg.n_heads, cfg.hsz)
        kn = cst(cst(h @ lp["wk"], None, qkv_ax),
                 None, tpa_ax).reshape(b, cfg.n_kv_heads, cfg.hsz)
        vn = cst(cst(h @ lp["wv"], None, qkv_ax),
                 None, tpa_ax).reshape(b, cfg.n_kv_heads, cfg.hsz)
        if cfg.use_rope:
            pos = (tl_attn - 1)
            pos = pos[..., None] if jnp.ndim(pos) else pos[None]  # [B,1]/[1]
            q = apply_rope(q[:, None], pos, cfg.rope_theta)[:, 0]
            kn = apply_rope(kn[:, None], pos, cfg.rope_theta)[:, 0]
        chunks = hopb_chunks if b % hopb_chunks == 0 else 1
        if groups is not None:
            chunks = 1      # groups span the batch; chunks would split them
        paged = tables is not None
        # Fused KV-append epilogue (§Perf, roadmap): on the Pallas backends
        # the decode kernel writes kn/vn into the cache itself, skipping the
        # separate append pass (one cache HBM round-trip per layer per
        # step).  Static decision; int8 caches quantize the new token
        # in-kernel, and with block pruning on there is no cache-slice
        # conflict left to fall back over.
        if fuse_append_applicable(hx, kvp, win, tl_attn, kc.shape[2],
                                  quant=kv8, paged=paged):
            if kv8:
                out, kc, vc, ks, vs = helix_attention(
                    mesh, hx, q, kc, vc, tl_attn, window=win,
                    hopb_chunks=chunks, kscale=ks, vscale=vs,
                    k_new=kn, v_new=vn, block_tables=tables, groups=groups)
            else:
                out, kc, vc = helix_attention(
                    mesh, hx, q, kc, vc, tl_attn, window=win,
                    hopb_chunks=chunks, k_new=kn, v_new=vn,
                    block_tables=tables, groups=groups)
        else:
            if kv8:
                kc, vc, ks, vs = append_kv_quant(
                    kc, vc, ks, vs, kn, vn, tl_attn, kvp=kvp,
                    rr_block=hx.rr_block, block_tables=tables)
            else:
                kc, vc = append_kv(kc, vc, kn, vn, tl_attn, kvp=kvp,
                                   rr_block=hx.rr_block, block_tables=tables)
            out = helix_attention(mesh, hx, q, kc, vc, tl_attn, window=win,
                                  hopb_chunks=chunks,
                                  kscale=ks if kv8 else None,
                                  vscale=vs if kv8 else None,
                                  block_tables=tables, groups=groups)
        # post-attention projection: TP = N over the combined (tpa, kvp)
        # layout; the All-Reduce the paper describes is emitted by GSPMD from
        # wo's input-dim sharding.
        return out_proj(out, lp["wo"]), kc, vc, ks, vs

    def cross_phase(lp, h, xk, xv, s_enc):
        b = h.shape[0]
        q = cst(h @ lp["wq"], None, tpa_ax).reshape(b, cfg.n_heads, cfg.hsz)
        chunks = hopb_chunks if b % hopb_chunks == 0 else 1
        out = helix_attention(mesh, hx, q, xk, xv,
                              jnp.asarray(s_enc, jnp.int32),
                              contiguous=True, hopb_chunks=chunks)
        return out_proj(out, lp["wo"])

    def ssm_phase(lp, h, conv, sstate):
        # batch over 'data' (when divisible), heads/channels over 'model'
        # (DESIGN §4 mamba2: Helix's FFN half applies; KVP is inapplicable —
        # no KV cache).
        bax = "data" if h.shape[0] % mesh.shape["data"] == 0 else None
        hax = "model" if cfg.ssm_heads % mesh.shape["model"] == 0 else None
        cax = "model" if cfg.conv_dim % mesh.shape["model"] == 0 else None
        y, new = ssm_lib.ssm_decode_step(
            ssm_lib.SSMParams(**lp), cfg,
            cst(h, bax, None),
            ssm_lib.SSMState(cst(conv, bax, cax, None),
                             cst(sstate, bax, hax, None, None)))
        return cst(y, None, None), new

    def ffn_phase(lp_ffn, lp_moe, h2):
        delta = 0.0
        if lp_ffn is not None:
            # dense FFN: TPF = N — all devices amortize the weight read.
            # '2d' fallback (F % N != 0): H over dp-ish axes x F over model;
            # the contraction over the H shard emits a small all-reduce.
            fax = ("model",) if ffn2d else all_ax
            y = act(cst(h2 @ lp_ffn["w1"], None, fax))
            if "w3" in lp_ffn:
                y = y * cst(h2 @ lp_ffn["w3"], None, fax)
            delta = cst(y @ lp_ffn["w2"], None, None)
        if lp_moe is not None:
            m, _aux = moe_ffn(
                MoEParams(**lp_moe), h2, cfg.moe, activation("silu"),
                capacity_factor=cfg.moe.decode_capacity_factor, groups=1,
                c_disp=lambda v: cst(v, None, hx.ep_axis, None, None),
                c_exp=lambda v: cst(v, None, hx.ep_axis, None, None))
            delta = delta + cst(m, None, None)
        return delta

    def layer_fn(x, lp, win, kc, vc, ks, vs, conv, sstate, xk, xv, tl_attn,
                 s_enc, tables, groups=None):
        h = rms_norm(x, lp["ln1"])
        new_caches: dict[str, Any] = {}
        if cfg.has_attention and cfg.has_ssm:          # hybrid (hymba)
            a_out, kc, vc, ks, vs = attn_phase(lp["attn"], h, kc, vc, ks, vs,
                                               tl_attn, win, tables, groups)
            s_out, new_s = ssm_phase(lp["ssm"], h, conv, sstate)
            x = x + 0.5 * (a_out + s_out)
            new_caches.update(kcache=kc, vcache=vc, ssm_conv=new_s.conv,
                              ssm_state=new_s.ssm)
        elif cfg.has_attention:
            a_out, kc, vc, ks, vs = attn_phase(lp["attn"], h, kc, vc, ks, vs,
                                               tl_attn, win, tables, groups)
            x = x + a_out
            new_caches.update(kcache=kc, vcache=vc)
        else:                                          # pure ssm (mamba2)
            s_out, new_s = ssm_phase(lp["ssm"], h, conv, sstate)
            x = x + s_out
            new_caches.update(ssm_conv=new_s.conv, ssm_state=new_s.ssm)
        if kv8 and cfg.has_attention:
            new_caches.update(kscale=ks, vscale=vs)

        if cfg.is_encdec:
            hxn = rms_norm(x, lp["lnx"])
            x = x + cross_phase(lp["xattn"], hxn, xk, xv, s_enc)

        if cfg.d_ff or cfg.moe:
            h2 = rms_norm(x, lp["ln2"])
            x = x + ffn_phase(lp.get("ffn"), lp.get("moe"), h2)
        return x, new_caches

    def step_logits(params, state, tokens):
        """tokens [B] int32 -> (logits [B, padded_vocab], new_caches)."""
        tl = state["total_len"]
        tl_attn = tl + 1                                # includes new token
        # paged pool: the [B, max_pages] block table rides in the state and
        # is shared by every layer (pool planes are per-layer, tables per
        # request); it passes through the step unchanged — the host-side
        # engine/scheduler owns page allocation.
        tables = state.get("block_tables") if hx.paged_kv else None
        # grouped shared-prefix decode: the engine recomputes the [B]
        # group_id/group_np leaves each step from the pool's page sharing
        groups = None
        if hx.grouped_decode and hx.paged_kv and "group_id" in state:
            groups = (state["group_id"], state["group_np"])
        x = params["embed"][tokens]                     # [B, H]
        x = cst(x, None, None)
        if not cfg.use_rope:
            pos = tl if jnp.ndim(tl) else tl[None]
            pe = sinusoidal_at(pos.astype(jnp.float32), cfg.d_model)
            x = x + pe.astype(x.dtype)

        L = cfg.n_layers
        s_enc = state.get("enc_len", 0) if cfg.is_encdec else 0

        # Scan over layer *periods* (gemma3: 5 local + 1 global) so each
        # sub-layer's sliding window is a STATIC python int — this lets the
        # helix local attend slice O(window/KVP) cache bytes (§Perf).
        p = (cfg.local_ratio + 1) if cfg.local_ratio else 1
        nper = L // p
        win_static = [int(w) for w in windows[:p]]

        dummy = jnp.zeros((L, 1), jnp.int32)  # placeholder for absent leaves
        xs = (params["layers"],
              state.get("kcache", dummy), state.get("vcache", dummy),
              state.get("kscale", dummy), state.get("vscale", dummy),
              state.get("ssm_conv", dummy), state.get("ssm_state", dummy),
              state.get("xk", dummy), state.get("xv", dummy))
        xs = jax.tree.map(lambda a: a.reshape(nper, p, *a.shape[1:]), xs)

        def body(carry, xs_p):
            xcur = carry
            outs = []
            for i in range(p):
                leaf_i = jax.tree.map(lambda a: a[i], xs_p)
                lp, kc, vc, ks, vs, conv, sstate, xk, xv = leaf_i
                xcur, nc = layer_fn(xcur, lp, win_static[i], kc, vc, ks, vs,
                                    conv, sstate, xk, xv, tl_attn, s_enc,
                                    tables, groups)
                outs.append(nc)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *outs)
            return xcur, stacked

        x, new_caches = jax.lax.scan(body, x, xs,
                                     unroll=nper if unroll else 1)
        new_caches = jax.tree.map(
            lambda a: a.reshape(L, *a.shape[2:]), new_caches)

        x = rms_norm(x, params["ln_f"])
        head = params.get("lm_head")
        if head is None:
            head = params["embed"].T
        logits = head_matmul(x, head, params)
        logits = cst(logits, None, all_ax)
        if cfg.softcap:
            logits = softcap(logits, cfg.softcap)
        vmask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab,
                          0.0, -1e30)
        logits = logits + vmask.astype(logits.dtype)
        return logits, new_caches

    return step_logits


def build_serve_step(cfg: ArchConfig, mesh: Mesh, hx: HelixConfig, *,
                     hopb_chunks: int = 4, return_logits: bool = False,
                     unroll: bool = False, attn_backend: str | None = None,
                     fuse_append: bool | None = None,
                     prune_blocks: bool | None = None,
                     matmul_backend: str | None = None,
                     lm_head_w8: bool | None = None,
                     paged_kv: bool | None = None):
    """Build one autoregressive Helix decode step for ``cfg`` on ``mesh``.

    Returns ``serve_step(params, state, tokens) -> (next_tokens, new_state)``
    (jit-able; ``state`` from ``make_prefill_step`` or
    ``core/kvcache.init_decode_state``).

    The token decision is the fused on-device epilogue ``_next_token``:
    plain argmax normally, or the serving/sampling.py sampler when the
    state carries the per-row ``sample_*`` leaves
    (``core/kvcache.sampling_leaf_shapes``) — in which case
    ``sample_idx`` also advances by one per step.

    Args:
      hopb_chunks: HOP-B batch chunking inside helix_attention (§2.1.3);
        degrades to 1 automatically when the batch doesn't divide.
      return_logits: also return the full next-token logits.
      unroll: unroll the layer-period scan (dry-run cost analysis).
      attn_backend: overrides ``hx.attn_backend`` (``ref`` |
        ``pallas-interpret`` | ``pallas``) — the flash_decode kernel family
        backend used inside helix_attention (kernels/registry.py).
      fuse_append: overrides ``hx.fuse_append`` — fuse the rr-slot KV append
        into the decode kernel epilogue (Pallas backends only).
      prune_blocks: overrides ``hx.prune_blocks`` — length/causality-aware
        K/V block pruning inside the Pallas decode kernel (bit-exact).
      matmul_backend: overrides ``hx.matmul_backend`` — the w8a16_matmul
        family backend for the quantized lm_head matmul.
      lm_head_w8: overrides ``hx.lm_head_w8`` — int8-quantize the lm_head
        weights and route the logits matmul through w8a16_matmul.
      paged_kv: overrides ``hx.paged_kv`` — shared-pool paged KV cache: the
        state carries pool planes ``[L, n_blocks, Kh, block_s, hsz]`` plus a
        ``block_tables`` [B, max_pages] leaf instead of fixed per-slot rows
        (core/kvcache.py paged layout; bit-exact vs fixed at the same
        ``attn_block_s`` partition).
    """
    hx = _resolve_overrides(hx, attn_backend=attn_backend,
                            fuse_append=fuse_append,
                            prune_blocks=prune_blocks,
                            matmul_backend=matmul_backend,
                            lm_head_w8=lm_head_w8, paged_kv=paged_kv)
    step_logits = _build_step_logits(cfg, mesh, hx, hopb_chunks=hopb_chunks,
                                     unroll=unroll)

    def serve_step(params, state, tokens):
        """tokens [B] int32 -> (next_tokens [B], new state)."""
        logits, new_caches = step_logits(params, state, tokens)
        next_tokens = _next_token(logits, state)
        new_state = dict(state)
        new_state.update(new_caches)
        new_state["total_len"] = state["total_len"] + 1
        if "sample_idx" in state:
            new_state["sample_idx"] = state["sample_idx"] + 1
        if cfg.is_encdec:                               # static cross KV
            new_state["xk"], new_state["xv"] = state["xk"], state["xv"]
        if return_logits:
            return (next_tokens, logits), new_state
        return next_tokens, new_state

    return serve_step


def build_serve_multistep(cfg: ArchConfig, mesh: Mesh, hx: HelixConfig, *,
                          window: int, hopb_chunks: int = 4,
                          unroll: bool = False,
                          attn_backend: str | None = None,
                          fuse_append: bool | None = None,
                          prune_blocks: bool | None = None,
                          matmul_backend: str | None = None,
                          lm_head_w8: bool | None = None,
                          paged_kv: bool | None = None):
    """Build the windowed decode inner loop: ``window`` tokens per call
    entirely on device (sample -> fused KV append -> next step via
    ``lax.scan``), so the host only intervenes — one blocking transfer,
    scheduling, admission — once per window instead of once per token.

    Returns

        serve_multistep(params, state, tokens, budgets, eos_ids,
                        forced, n_forced)
            -> (out_block [B, window], cur_tokens [B], new_state)

    with per-row control carried as data (no host round-trips inside the
    window):

      * ``budgets`` [B] i32 — device steps this row may take (its page /
        capacity grant from ``Scheduler.grow_for_window``; 0 freezes the
        row for the whole window, e.g. idle slots).
      * ``eos_ids`` [B] i32 — per-row EOS token (< 0 = none).  A row that
        *emits* EOS freezes for the rest of the window: state stops
        advancing (``total_len`` and the SSM recurrences hold; KV appends
        degenerate to masked-off rewrites of the frozen position) and its
        remaining ``out_block`` entries are the pad value ``-1``.
      * ``forced`` [B, window] + ``n_forced`` [B] — restore/session-KV
        catch-up tokens fed *instead of* the sampled token for the first
        ``n_forced[b]`` active steps of row ``b`` (they consume budget but
        emit pad and do not advance ``sample_idx``, exactly like the
        single-step engine's host-side forced replay).

    ``out_block[b, j]`` is the token row ``b`` emitted at in-window step
    ``j`` (pad ``-1`` where frozen/forced) — EOS itself is emitted so the
    host replay can observe it.  ``total_len`` must be per-row [B].
    Rows frozen mid-window (EOS / exhausted budget < window) must be
    retired by the caller at the window boundary — their in-flight
    activations are discarded, which is what makes windowed streams
    bit-identical to ``window`` single steps.

    Same builder knobs as ``build_serve_step``; grouped shared-prefix
    decode is rejected (the [B] group leaves are host-recomputed per token
    and would go stale mid-window)."""
    if window < 1:
        raise ValueError(f"window must be >= 1 (got {window})")
    hx = _resolve_overrides(hx, attn_backend=attn_backend,
                            fuse_append=fuse_append,
                            prune_blocks=prune_blocks,
                            matmul_backend=matmul_backend,
                            lm_head_w8=lm_head_w8, paged_kv=paged_kv)
    if hx.grouped_decode:
        raise ValueError("serve_multistep is incompatible with "
                         "grouped_decode: group_id/group_np are recomputed "
                         "by the host every token and would go stale inside "
                         "a multi-token window")
    step_logits = _build_step_logits(cfg, mesh, hx, hopb_chunks=hopb_chunks,
                                     unroll=unroll)

    def serve_multistep(params, state, tokens, budgets, eos_ids,
                        forced, n_forced):
        b = tokens.shape[0]
        sampling = "sample_seed" in state
        # SSM recurrences have no total_len masking protecting them, so
        # frozen rows must explicitly hold their previous value
        ssm_keys = [k for k in ("ssm_conv", "ssm_state") if k in state]

        def body(carry, j):
            st, cur, fpos, eos_seen = carry
            active = (j < budgets) & ~eos_seen
            logits, new_caches = step_logits(params, st, cur)
            sampled = _next_token(logits, st)
            is_forced = fpos < n_forced
            fvals = jnp.take_along_axis(
                forced, jnp.minimum(fpos, forced.shape[1] - 1)[:, None],
                axis=1)[:, 0]
            emit = active & ~is_forced
            out_j = jnp.where(emit, sampled, -1)
            new_state = dict(st)
            new_state.update(new_caches)
            for key in ssm_keys:
                sel = active.reshape((1, b) + (1,) * (st[key].ndim - 2))
                new_state[key] = jnp.where(sel, new_state[key], st[key])
            new_state["total_len"] = st["total_len"] + active.astype(jnp.int32)
            if sampling:
                new_state["sample_idx"] = (st["sample_idx"]
                                           + emit.astype(jnp.int32))
            if cfg.is_encdec:                           # static cross KV
                new_state["xk"], new_state["xv"] = st["xk"], st["xv"]
            eos_hit = emit & (eos_ids >= 0) & (sampled == eos_ids)
            nxt = jnp.where(is_forced, fvals, sampled)
            carry2 = (new_state,
                      jnp.where(active, nxt, cur),
                      fpos + (active & is_forced).astype(jnp.int32),
                      eos_seen | eos_hit)
            return carry2, out_j

        init = (state, tokens, jnp.zeros((b,), jnp.int32),
                jnp.zeros((b,), bool))
        (new_state, cur, _, _), outs = jax.lax.scan(
            body, init, jnp.arange(window))
        return outs.T, cur, new_state

    return serve_multistep
