"""Attention: reference + memory-bounded chunked implementations (pure jnp).

Also: the static head-layout machinery that pads/permutes GQA heads so that
tensor-parallel sharding respects KV-group boundaries (DESIGN.md §5).

Conventions
-----------
  q        [B, T, Qh, hsz]
  k, v     [B, S, Kh, hsz]     with Qh % Kh == 0 (after layout)
  output   [B, T, Qh, hsz]

The train/prefill path uses ``chunked_attention`` (lax.scan over query
chunks — memory O(B·h·cq·S) instead of O(B·h·T·S)).  The decode path lives
in core/helix.py (sharded) and kernels/flash_decode (TPU hotspot).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.utils import NEG_INF, round_up, cdiv


# ------------------------------------------------------------- head layout
@dataclasses.dataclass(frozen=True)
class HeadLayout:
    """Static padded/permuted GQA head layout for width-W head sharding.

    q_src[i]  — original q head feeding padded slot i (== Qh ⇒ zero pad)
    kv_src[j] — original kv head replicated into padded slot j
    """
    q_heads: int
    kv_heads: int
    q_pad: int
    kv_pad: int
    q_src: tuple[int, ...]
    kv_src: tuple[int, ...]

    @property
    def group(self) -> int:
        return self.q_pad // self.kv_pad

    @property
    def is_identity(self) -> bool:
        return (self.q_pad == self.q_heads and self.kv_pad == self.kv_heads
                and self.q_src == tuple(range(self.q_heads)))


@functools.lru_cache(maxsize=None)
def head_layout(q_heads: int, kv_heads: int, width: int) -> HeadLayout:
    """Pad Kh to a multiple-or-divisor-aligned count and Qh to match, so a
    width-way shard of the padded q-head axis never crosses a kv group."""
    assert q_heads % kv_heads == 0, (q_heads, kv_heads)
    g0 = q_heads // kv_heads
    # Kh -> smallest Kp >= Kh that is a divisor or multiple of width (dummy
    # zero kv heads fill the gap); group g0 -> smallest gp with W | Kp*gp.
    # Together these guarantee a width-way shard of the padded q-head axis
    # never splits a kv group across ranks.  Dummy kv heads are attended only
    # by pad q slots whose out-projection rows are zero => numerically exact.
    kv_pad = kv_heads
    while not (width % kv_pad == 0 or kv_pad % width == 0):
        kv_pad += 1
    gp = g0
    while (kv_pad * gp) % width:
        gp += 1
    q_pad = kv_pad * gp
    q_src, kv_src = [], []
    for j in range(kv_pad):
        kv_src.append(j if j < kv_heads else kv_heads)       # dummy sentinel
        for t in range(gp):
            real = j < kv_heads and t < g0
            q_src.append(j * g0 + t if real else q_heads)    # pad sentinel
    return HeadLayout(q_heads, kv_heads, q_pad, kv_pad,
                      tuple(q_src), tuple(kv_src))


def apply_q_layout(wq: jax.Array, layout: HeadLayout, hsz: int) -> jax.Array:
    """[H, Qh*hsz] -> [H, Qp*hsz] padded/permuted view (zero pads)."""
    if layout.is_identity:
        return wq
    h = wq.shape[0]
    w = wq.reshape(h, layout.q_heads, hsz)
    w = jnp.concatenate([w, jnp.zeros((h, 1, hsz), wq.dtype)], axis=1)
    return w[:, np.array(layout.q_src)].reshape(h, layout.q_pad * hsz)


def apply_o_layout(wo: jax.Array, layout: HeadLayout, hsz: int) -> jax.Array:
    """[Qh*hsz, H] -> [Qp*hsz, H] (zero rows at pads — padding is exact)."""
    if layout.is_identity:
        return wo
    h = wo.shape[-1]
    w = wo.reshape(layout.q_heads, hsz, h)
    w = jnp.concatenate([w, jnp.zeros((1, hsz, h), wo.dtype)], axis=0)
    return w[np.array(layout.q_src)].reshape(layout.q_pad * hsz, h)


def apply_kv_layout(wkv: jax.Array, layout: HeadLayout, hsz: int) -> jax.Array:
    """[H, Kh*hsz] -> [H, Kp*hsz] padded view (dummy kv heads are zero)."""
    if layout.is_identity:
        return wkv
    h = wkv.shape[0]
    w = wkv.reshape(h, layout.kv_heads, hsz)
    w = jnp.concatenate([w, jnp.zeros((h, 1, hsz), wkv.dtype)], axis=1)
    return w[:, np.array(layout.kv_src)].reshape(h, layout.kv_pad * hsz)


# ------------------------------------------------------------- reference
def ref_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int | jax.Array = 0):
    """Naive full-matrix attention (small tests only)."""
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = qh // kh
    qf = q.astype(jnp.float32).reshape(b, t, kh, g, hsz) * (hsz ** -0.5)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qf, kf)
    qpos = jnp.arange(t) + q_offset
    kpos = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    # window may be a traced per-layer scalar (gemma3 local/global scan);
    # 0 means "no window"
    weff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window), t + s + 10)
    mask &= kpos[None, :] > qpos[:, None] - weff
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, qh, hsz).astype(q.dtype)


# ------------------------------------------------------------- chunked
def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      chunk_q: int = 512, q_offset: int | jax.Array = 0,
                      unroll: bool = False, seq_lens=None):
    """Memory-bounded attention: lax.scan over query chunks.

    Each chunk computes its full score row (the row fits: cq × S), so no
    online-softmax state is needed.  Used by train_step / prefill_step; the
    TPU hotspot equivalent is kernels/flash_prefill.  ``unroll`` emits the
    chunk loop inline — required by the dry-run because cost_analysis counts
    a while-loop body once, not x trip-count.  ``seq_lens`` ([B] int32,
    optional) masks kv positions ``>= seq_lens[b]`` per request — the ref
    side of flash_prefill's ragged continuous-batching contract.  For causal
    self-attention over right-padded prompts the extra mask only affects pad
    *query* rows (valid rows never see later positions), so passing it keeps
    the valid rows bit-identical.  ``q_offset`` may be a per-request ``[B]``
    vector (ragged chunk packing: every row attends at its own prefill
    progress) — masking then runs per row, bit-identical per row to the
    scalar-offset call.
    """
    b, t, qh, hsz = q.shape
    s, kh = k.shape[1], k.shape[2]
    g = qh // kh
    cq = min(chunk_q, t)
    t_pad = round_up(t, cq)
    if t_pad != t:
        q = jnp.pad(q, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    nchunk = t_pad // cq

    qc = q.reshape(b, nchunk, cq, kh, g, hsz).transpose(1, 0, 3, 4, 2, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kpos = jnp.arange(s)

    off = jnp.asarray(q_offset, jnp.int32)
    ragged_off = off.ndim == 1                            # [B] per-request

    def one_chunk(ci, qi):
        qf = qi.astype(jnp.float32) * (hsz ** -0.5)       # [B,Kh,G,cq,hsz]
        scores = jnp.einsum("bkgtd,bskd->bkgts", qf, kf)  # [B,Kh,G,cq,S]
        weff = jnp.where(jnp.asarray(window) > 0, jnp.asarray(window),
                         t + s + 10)
        if ragged_off:
            qpos = ci * cq + jnp.arange(cq)[None, :] + off[:, None]  # [B,cq]
            mask = jnp.ones((b, cq, s), bool)
            if causal:
                mask &= kpos[None, None, :] <= qpos[..., None]
            mask &= kpos[None, None, :] > qpos[..., None] - weff
        else:
            qpos = ci * cq + jnp.arange(cq) + off
            mask = jnp.ones((cq, s), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            mask &= kpos[None, :] > qpos[:, None] - weff
        per_row = ragged_off or seq_lens is not None
        if seq_lens is not None:
            lens = jnp.broadcast_to(jnp.asarray(seq_lens, jnp.int32), (b,))
            if not ragged_off:
                mask = jnp.broadcast_to(mask[None], (b, cq, s))
            mask = mask & (kpos[None, None, :] < lens[:, None, None])
        if per_row:
            mask = mask[:, None, None]                    # [B,1,1,cq,S]
        scores = jnp.where(mask, scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1)
        # fully-masked rows (seq_lens[b] == 0) produce uniform p over -inf
        # scores; zero them so dead rows emit zeros, matching the kernel
        if per_row:
            p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
        return jnp.einsum("bkgts,bskd->bkgtd", p, vf).astype(q.dtype)

    _, outs = jax.lax.scan(
        lambda _, args: (None, one_chunk(*args)),
        None, (jnp.arange(nchunk), qc),
        unroll=nchunk if unroll else 1)                   # [n,B,Kh,G,cq,hsz]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, t_pad, qh, hsz)
    return out[:, :t]


def cross_attention(q, k, v, *, chunk_q: int = 512):
    """Non-causal encoder-decoder cross attention (whisper)."""
    return chunked_attention(q, k, v, causal=False, window=0, chunk_q=chunk_q)


# --------------------------------------------------- kernel-backed prefill
@functools.lru_cache(maxsize=None)
def _kernel_prefill_fn(causal: bool, interpret: bool, chunk_q: int,
                       unroll: bool, prune: bool, ragged: bool):
    """flash_prefill with a custom VJP whose backward re-runs the jnp
    reference (``chunked_attention``) — Pallas kernels define no transpose
    rule, so this is what lets the pallas backends run under
    ``value_and_grad`` (train_step).  Forward values come from the kernel;
    gradients are the oracle's (identical up to fp summation order, since
    the forwards agree to that order).  ``ragged`` statically selects the
    per-request ``seq_lens`` variant (continuous-batching prefill)."""

    @jax.custom_vjp
    def f(q, k, v, window, q_offset, seq_lens):
        from repro.kernels.flash_prefill.ops import flash_prefill
        return flash_prefill(q, k, v, causal=causal, window=window,
                             q_offset=q_offset,
                             seq_lens=seq_lens if ragged else None,
                             prune=prune, interpret=interpret)

    def fwd(q, k, v, window, q_offset, seq_lens):
        return (f(q, k, v, window, q_offset, seq_lens),
                (q, k, v, window, q_offset, seq_lens))

    def bwd(res, g):
        q, k, v, window, q_offset, seq_lens = res
        _, vjp = jax.vjp(
            lambda q, k, v: chunked_attention(
                q, k, v, causal=causal, window=window, chunk_q=chunk_q,
                q_offset=q_offset, unroll=unroll,
                seq_lens=seq_lens if ragged else None), q, k, v)
        dq, dk, dv = vjp(g)
        zero = lambda x: np.zeros(np.shape(x), jax.dtypes.float0)
        return dq, dk, dv, zero(window), zero(q_offset), zero(seq_lens)

    f.defvjp(fwd, bwd)
    return f


def prefill_attention(q, k, v, *, causal: bool = True, window=0,
                      q_offset: int | jax.Array = 0, chunk_q: int = 512,
                      unroll: bool = False, backend: str = "ref",
                      prune: bool = True, seq_lens=None):
    """Full-sequence attention with kernel-backend selection.

    The prefill/train sibling of ``decode_attention``: ``backend`` routes the
    flash_prefill family through the registry lattice — ``"ref"`` is the
    memory-bounded ``chunked_attention`` scan, ``"pallas-interpret"`` /
    ``"pallas"`` the flash-prefill kernel (interpreted / compiled) with a
    ref-VJP backward so training works.  ``window`` and ``q_offset`` may be
    traced (per-layer windows under ``lax.scan``; ``q_offset`` is also how a
    chunked-prefill slice attends to its already-cached prefix — see
    docs/serving.md).  ``prune`` (kernel backends): skip causally/window-dead
    kv blocks instead of masking them (bit-exact; see docs/kernels.md "Block
    pruning").  ``seq_lens`` ([B] int32, optional) masks kv positions
    ``>= seq_lens[b]`` per request (ragged continuous-batching prefill),
    uniformly across backends.

      q [B, T, Qh, hsz]; k, v [B, S, Kh, hsz] -> out [B, T, Qh, hsz].
    """
    if backend == "ref":
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 chunk_q=chunk_q, q_offset=q_offset,
                                 unroll=unroll, seq_lens=seq_lens)
    from repro.kernels import registry
    registry.validate("flash_prefill", backend)
    ragged = seq_lens is not None
    fn = _kernel_prefill_fn(causal, registry.interpret_flag(backend),
                            chunk_q, unroll, prune, ragged)
    lens = (jnp.asarray(seq_lens, jnp.int32) if ragged
            else jnp.zeros((), jnp.int32))
    return fn(q, k, v, jnp.asarray(window, jnp.int32),
              jnp.asarray(q_offset, jnp.int32), lens)


# ------------------------------------------------------------- decode
def decode_attention(q, k, v, total_len, *, window=0, backend: str = "ref",
                     kvp: int = 1, rr_block: int = 16, rank=0,
                     kscale=None, vscale=None, block_s: int = 512,
                     prune: bool = True, block_tables=None):
    """Single-shard decode-shape attention with backend selection.

    The unsharded sibling of core/helix.py's per-rank local attend —
    benchmarks and single-device decode use it directly.  ``backend`` picks
    the implementation: "ref" (pure-jnp oracle), "pallas-interpret" (the
    flash-decode kernel through the Pallas interpreter — runs anywhere), or
    "pallas" (compiled TPU kernel).  All are exact up to fp summation order.

      q [B, Qh, hsz]; k, v [B, Kh, S, hsz]; total_len scalar or [B] int32.

    ``block_tables`` ([B, max_pages] int32) switches to the shared-pool
    paged layout: k/v are pool planes ``[n_pool, Kh, page_s, hsz]`` and the
    kernel streams each request's pages through the table (the ref backend
    gathers them into the dense equivalent first) — bit-exact vs the fixed
    layout at ``block_s == page_s``.

    Returns (out [B, Qh, hsz], lse [B, Qh] f32).
    """
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import flash_decode_ref
    if backend == "ref":
        if block_tables is not None:
            from repro.core.kvcache import gather_pages
            k = gather_pages(k, block_tables)
            v = gather_pages(v, block_tables)
            if kscale is not None:
                kscale = gather_pages(kscale, block_tables)
                vscale = gather_pages(vscale, block_tables)
        return flash_decode_ref(q, k, v, total_len, rank, kvp=kvp,
                                rr_block=rr_block, window=window,
                                kscale=kscale, vscale=vscale)
    return flash_decode(q, k, v, total_len, rank, kvp=kvp, rr_block=rr_block,
                        window=window, block_s=block_s,
                        kscale=kscale, vscale=vscale, prune=prune,
                        block_tables=block_tables,
                        interpret=backend != "pallas")
