"""Public model API: step-function builders + dry-run input specs.

  make_train_step(cfg, mesh, optcfg)   -> train_step(params, opt, batch)
  make_prefill_step(cfg, mesh, hx)     -> prefill(params, batch) -> (logits,
                                          decode-state in round-robin layout)
  build_serve_step (re-export)         -> decode (models/decode_model.py)
  data_specs(cfg, shape)               -> ShapeDtypeStructs for batch inputs
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.helix import prefill_to_rr_layout
from repro.core.kvcache import cache_capacity
from repro.core.sharding import HelixConfig, MeshPolicy, train_roles
from repro.models.decode_model import (  # noqa: F401 re-export
    build_serve_multistep, build_serve_step)
from repro.models.transformer import (NO_POLICY, chunked_prefill_supported,
                                      forward, init_params, lm_loss)
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.utils import round_up

__all__ = ["make_train_step", "make_prefill_step", "build_serve_step",
           "build_serve_multistep",
           "make_chunk_prefill_step", "init_prefill_buffers",
           "finalize_chunked_prefill", "chunked_prefill_supported",
           "data_specs", "data_partition_specs", "init_params", "adamw_init"]


def _dp_size(mesh: Mesh | None) -> int:
    if mesh is None:
        return 1
    return math.prod(mesh.shape[a] for a in mesh.axis_names if a != "model")


def _forward_kwargs(cfg: ArchConfig, batch: dict[str, Any], mesh, policy,
                    moe_groups: int):
    kw: dict[str, Any] = dict(policy=policy, moe_groups=moe_groups,
                              tp_width=mesh.shape["model"] if mesh else 1)
    if cfg.vision_patches:
        kw["patch_embeds"] = batch["patch_embeds"]
    if cfg.is_encdec:
        kw["enc_frames"] = batch["enc_frames"]
    return kw


# ------------------------------------------------------------------ train
def make_train_step(cfg: ArchConfig, mesh: Mesh | None = None,
                    optcfg: AdamWConfig = AdamWConfig(), chunk_q: int = 512,
                    unroll: bool = False, prefill_backend: str = "ref",
                    ssd_backend: str = "ref", prune_blocks: bool = True):
    """Build ``train_step(params, opt_state, batch)`` for one architecture.

    ``prefill_backend`` / ``ssd_backend`` route the full-sequence attention
    and SSD-scan hotspots through the kernel registry (kernels/registry.py);
    the pallas backends carry a ref-VJP backward, so the same knob works
    under ``value_and_grad``.  ``prune_blocks`` is flash_prefill's
    causal/window block skip (kernel backends; bit-exact on/off).
    """
    policy = MeshPolicy(mesh, train_roles(mesh)) if mesh else NO_POLICY
    moe_groups = _dp_size(mesh) if cfg.moe else 1

    def loss_fn(params, batch):
        logits, extras = forward(
            cfg, params, batch["tokens"], chunk_q=chunk_q, unroll=unroll,
            prefill_backend=prefill_backend, ssd_backend=ssd_backend,
            prune_blocks=prune_blocks,
            **_forward_kwargs(cfg, batch, mesh, policy, moe_groups))
        loss = lm_loss(cfg, logits, batch["labels"])
        return loss + extras["aux_loss"], loss

    def train_step(params, opt_state, batch):
        (_, loss), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  optcfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------- prefill
def prefill_cache_to_rr(cfg: ArchConfig, hx: HelixConfig, kc_raw, vc_raw,
                        t: int, cap: int, kvp: int):
    """Prefill-layout K/V caches -> round-robin decode layout.

    ``kc_raw``/``vc_raw`` are ``[L, B, T', Kp, hsz]`` (``forward``'s
    ``return_cache`` extras — possibly padded query rows / padded GQA heads;
    only the first ``t`` rows and ``cfg.n_kv_heads`` heads are live).
    Returns ``(kcache, vcache)`` as ``[L, B, Kh, cap, hsz]`` in the
    round-robin slot layout (core/helix.prefill_to_rr_layout).  Shared by
    the one-shot ``make_prefill_step`` handoff and the chunked-prefill
    finalize so the two paths cannot drift."""
    kc = kc_raw[:, :, :t, :cfg.n_kv_heads].transpose(0, 1, 3, 2, 4)
    vc = vc_raw[:, :, :t, :cfg.n_kv_heads].transpose(0, 1, 3, 2, 4)
    pad = [(0, 0)] * 5
    pad[3] = (0, cap - t)
    kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
    kcache = jax.vmap(lambda c: prefill_to_rr_layout(c, kvp, hx.rr_block))(kc)
    vcache = jax.vmap(lambda c: prefill_to_rr_layout(c, kvp, hx.rr_block))(vc)
    return kcache, vcache


def make_prefill_step(cfg: ArchConfig, mesh: Mesh | None, hx: HelixConfig,
                      s_cap: int | None = None, chunk_q: int = 512,
                      unroll: bool = False):
    """Prefill + handoff: contiguous caches -> round-robin decode layout.

    Kernel backends come from ``hx``: ``hx.prefill_backend`` routes the
    full-sequence attention (flash_prefill family), ``hx.ssd_backend``
    the Mamba2 SSD scan core (ssd_prefill family) and ``hx.prune_blocks``
    flash_prefill's causal/window block skip.
    """
    policy = MeshPolicy(mesh, train_roles(mesh)) if mesh else NO_POLICY
    kvp = hx.kvp(mesh) if mesh else 1
    moe_groups = _dp_size(mesh) if cfg.moe else 1

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b, t = tokens.shape
        cap = s_cap or cache_capacity(t, kvp, hx.rr_block)
        logits, extras = forward(
            cfg, params, tokens, return_cache=True, chunk_q=chunk_q,
            unroll=unroll, prefill_backend=hx.prefill_backend,
            ssd_backend=hx.ssd_backend, prune_blocks=hx.prune_blocks,
            **_forward_kwargs(cfg, batch, mesh, policy, moe_groups))
        state: dict[str, Any] = {"total_len": jnp.asarray(t, jnp.int32)}
        if cfg.has_attention:
            state["kcache"], state["vcache"] = prefill_cache_to_rr(
                cfg, hx, extras["kcache"], extras["vcache"], t, cap, kvp)
        if cfg.has_ssm:
            state["ssm_conv"] = extras["ssm_conv"]
            state["ssm_state"] = extras["ssm_state"]
        if cfg.is_encdec:
            from repro.models.encdec import cross_kv
            kx, vx = cross_kv(cfg, params["layers"], extras["enc_out"])
            s_enc = kx.shape[2]
            s_enc_pad = round_up(s_enc, kvp)
            padx = [(0, 0)] * 5
            padx[3] = (0, s_enc_pad - s_enc)
            state["xk"] = jnp.pad(kx.transpose(0, 1, 3, 2, 4), padx)
            state["xv"] = jnp.pad(vx.transpose(0, 1, 3, 2, 4), padx)
            state["enc_len"] = jnp.asarray(s_enc, jnp.int32)
        return logits[:, -1], state

    return prefill_step


# ------------------------------------------------------- chunked prefill
def init_prefill_buffers(cfg: ArchConfig, batch: int, t: int, *,
                         tp_width: int = 1,
                         dtype=jnp.float32) -> dict[str, Any]:
    """Zero K/V carry buffers for a chunked prefill of length ``t``.

    Returns {"kcache"/"vcache": [L, batch, t, Kp, hsz]} in ``forward``'s
    prefill cache layout (Kp = the GQA head layout's padded kv head count
    for ``tp_width``, the mesh's 'model' axis size).  ``t`` must equal the
    one-shot prefill length for the chunked run to be bit-exact
    (docs/serving.md)."""
    from repro.models.attention import head_layout
    kp = head_layout(cfg.n_heads, cfg.n_kv_heads, tp_width).kv_pad
    shape = (cfg.n_layers, batch, t, kp, cfg.hsz)
    return {"kcache": jnp.zeros(shape, dtype), "vcache": jnp.zeros(shape, dtype)}


def make_chunk_prefill_step(cfg: ArchConfig, mesh: Mesh | None,
                            hx: HelixConfig, chunk_q: int = 512,
                            unroll: bool = False,
                            return_last_logits: bool = False):
    """Build the prefix-aware chunked-prefill step (docs/serving.md).

    Returns ``chunk_step(params, tokens, buffers, q_offset) ->
    (next_tokens, new_buffers)``: ``tokens`` is the ``[B, C]`` chunk at
    global positions ``[q_offset, q_offset + C)``, ``buffers`` the carry
    dict from ``init_prefill_buffers`` with ``[0, q_offset)`` already
    filled, and ``next_tokens`` the ``[B, C]`` greedy next token after each
    chunk position (row ``t - 1 - q_offset`` of the final chunk is the
    request's first generated token, bit-identical to the one-shot
    ``prefill_step`` argmax).  ``q_offset`` may be a scalar or a *per-row*
    ``[B]`` vector — ragged chunk packing: each request's chunk lands at
    its own prefill progress (per-row rope positions, buffer writes and
    flash_prefill masking), so requests at different (offset, length) pack
    into one call bit-exactly.  Jit-able; ``q_offset`` may be traced so
    every chunk of a prefill shares one trace.  Only
    ``chunked_prefill_supported`` archs are accepted.

    ``return_last_logits`` makes the step return a 3-tuple
    ``(next_tokens, last_logits, new_buffers)`` where ``last_logits`` is
    the full ``[B, padded_vocab]`` logits row of each request's final chunk
    position (already softcapped + vocab-masked by ``forward``) — the
    serving engine's on-device first-token sampler consumes these instead
    of the greedy ``next_tokens``."""
    assert chunked_prefill_supported(cfg), \
        f"chunked prefill unsupported for {cfg.name} ({cfg.family})"
    policy = MeshPolicy(mesh, train_roles(mesh)) if mesh else NO_POLICY

    def chunk_step(params, tokens, buffers, q_offset):
        logits, extras = forward(
            cfg, params, tokens, return_cache=True, chunk_q=chunk_q,
            unroll=unroll, prefill_backend=hx.prefill_backend,
            ssd_backend=hx.ssd_backend, prune_blocks=hx.prune_blocks,
            prefix_state=buffers, q_offset=q_offset, policy=policy,
            tp_width=mesh.shape["model"] if mesh else 1)
        next_tokens = jnp.argmax(logits[:, :, :cfg.vocab],
                                 axis=-1).astype(jnp.int32)
        new_buffers = {"kcache": extras["kcache"],
                       "vcache": extras["vcache"]}
        if return_last_logits:
            return next_tokens, logits[:, -1], new_buffers
        return next_tokens, new_buffers

    return chunk_step


def finalize_chunked_prefill(cfg: ArchConfig, hx: HelixConfig, buffers,
                             t: int, s_cap: int | None = None,
                             kvp: int = 1) -> dict[str, Any]:
    """Fully-filled chunked-prefill buffers -> round-robin decode state.

    The exact handoff ``make_prefill_step`` performs (shared
    ``prefill_cache_to_rr``), so a chunked prefill's final decode state is
    bit-identical to the one-shot path's."""
    cap = s_cap or cache_capacity(t, kvp, hx.rr_block)
    kcache, vcache = prefill_cache_to_rr(
        cfg, hx, buffers["kcache"], buffers["vcache"], t, cap, kvp)
    return {"total_len": jnp.asarray(t, jnp.int32),
            "kcache": kcache, "vcache": vcache}


# ------------------------------------------------------------- input data
def data_specs(cfg: ArchConfig, cell: ShapeCell) -> dict[str, Any]:
    """ShapeDtypeStructs for the *data* inputs of one (arch x shape) cell."""
    b, t = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        d: dict[str, Any] = {"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)}
        return d
    d = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
    if cell.kind == "train":
        d["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    if cfg.vision_patches:
        d["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        d["enc_frames"] = jax.ShapeDtypeStruct(
            (b, t * cfg.enc_seq_ratio, cfg.d_model), jnp.bfloat16)
    return d


def data_partition_specs(cfg: ArchConfig, cell: ShapeCell,
                         mesh: Mesh) -> dict[str, Any]:
    dp = tuple(n for n in mesh.axis_names if n != "model")
    if cell.kind == "decode":
        return {"tokens": P(None)}
    d = {"tokens": P(dp, None)}
    if cell.kind == "train":
        d["labels"] = P(dp, None)
    if cfg.vision_patches:
        d["patch_embeds"] = P(dp, None, None)
    if cfg.is_encdec:
        d["enc_frames"] = P(dp, None, None)
    return d
