"""Model zoo core: param init + full-sequence forward for every family.

One parameter tree / one forward covers: dense GQA (granite, starcoder2,
gemma3 local:global, phi-3-vision), pure SSM (mamba2), hybrid (hymba),
MoE (granite-moe, arctic incl. dense residual), and enc-dec (whisper,
via encdec.py driving the same decoder stack).

The forward here is the *reference / GSPMD* path used by train_step and
prefill_step (sharding injected through a ShardingPolicy); the explicit-SPMD
Helix decode path (core/helix.py + models/decode_model.py) consumes the same
parameter tree.

Simplifications vs. upstream checkpoints (documented in DESIGN.md §6): all
norms are RMSNorm, single RoPE theta per model, sinusoidal positions for
whisper.  These do not affect the paper's contribution (sharding strategy).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm as ssm_lib
from repro.models.attention import (HeadLayout, apply_kv_layout, apply_o_layout,
                                    apply_q_layout, chunked_attention,
                                    head_layout, prefill_attention)
from repro.models.layers import (activation, apply_rope, dense_init, embed_init,
                                 rms_norm, sinusoidal_positions, softcap)
from repro.models.moe import MoEParams, init_moe, moe_ffn


class NoPolicy:
    """Sharding policy stub: identity constraints (single-device paths)."""

    def __call__(self, x, *axes):
        return x


NO_POLICY = NoPolicy()


# ===================================================================== init
def _init_attn(cfg: ArchConfig, key, dtype):
    ks = jax.random.split(key, 4)
    h = cfg.d_model
    return {
        "wq": dense_init(ks[0], (h, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (h, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (h, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, h), dtype,
                         scale=(cfg.q_dim ** -0.5) / np.sqrt(2 * cfg.n_layers)),
    }


def _init_ffn(cfg: ArchConfig, key, dtype):
    ks = jax.random.split(key, 3)
    h, f = cfg.d_model, cfg.d_ff
    p = {"w1": dense_init(ks[0], (h, f), dtype),
         "w2": dense_init(ks[1], (f, h), dtype,
                          scale=(f ** -0.5) / np.sqrt(2 * cfg.n_layers))}
    if cfg.act != "gelu":  # gated variants carry w3
        p["w3"] = dense_init(ks[2], (h, f), dtype)
    return p


def _init_layer(cfg: ArchConfig, key, dtype, with_cross: bool):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.has_attention:
        p["attn"] = _init_attn(cfg, ks[0], dtype)
    if cfg.has_ssm:
        p["ssm"] = ssm_lib.init_ssm(cfg, ks[1], dtype)._asdict()
    if with_cross:
        p["lnx"] = jnp.zeros((cfg.d_model,), dtype)
        p["xattn"] = _init_attn(cfg, ks[2], dtype)
    if cfg.d_ff or cfg.moe:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.d_ff:
        p["ffn"] = _init_ffn(cfg, ks[3], dtype)
    if cfg.moe:
        p["moe"] = init_moe(cfg.moe, cfg.d_model, ks[4], dtype)._asdict()
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    """Full parameter tree; per-layer leaves stacked on axis 0 (scan-ready)."""
    ks = jax.random.split(key, 8)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(
        lambda k: _init_layer(cfg, k, dtype, with_cross=cfg.is_encdec)
    )(layer_keys)
    params: dict[str, Any] = {
        "embed": embed_init(ks[1], (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_f": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.padded_vocab),
                                       dtype)
    if cfg.is_encdec:
        enc_keys = jax.random.split(ks[3], cfg.enc_layers)
        params["enc"] = {
            "layers": jax.vmap(
                lambda k: _init_layer(cfg, k, dtype, with_cross=False)
            )(enc_keys),
            "ln_f": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# =============================================================== layer fwd
def _attn_block(cfg: ArchConfig, ap, h, *, layout: HeadLayout, window,
                policy, causal=True, kv_override=None, q_offset=0,
                chunk_q=512, unroll=False, attn_backend="ref", prune=True,
                kv_buffer=None, seq_lens=None):
    """Projection + (optionally cross-) attention + out-proj.  h [B,T,H].

    ``attn_backend`` routes the attention core through the flash_prefill
    kernel family (models/attention.prefill_attention); ``prune`` is its
    causal/window block-skipping knob (kernel backends, bit-exact).

    ``kv_buffer`` (chunked prefill, docs/serving.md): a pair of
    ``[B, S_buf, Kp, hsz]`` carry buffers holding the K/V of the already-
    prefilled prefix ``[0, q_offset)``.  The chunk's freshly projected K/V
    rows are written at ``[q_offset, q_offset + T)`` and attention runs over
    the *whole* buffer — causal masking hides the yet-unfilled tail, so with
    ``S_buf`` equal to the one-shot sequence length the chunk is bit-exact
    with the one-shot prefill.  The updated buffers are returned as the
    cache pair.  ``seq_lens`` masks kv positions per request (ragged
    packing)."""
    b, t, _ = h.shape
    hsz = cfg.hsz
    wq = apply_q_layout(ap["wq"], layout, hsz)
    wo = apply_o_layout(ap["wo"], layout, hsz)
    q = policy(h @ wq, "dp", None, "tp").reshape(b, t, layout.q_pad, hsz)
    if kv_override is None:
        wk = apply_kv_layout(ap["wk"], layout, hsz)
        wv = apply_kv_layout(ap["wv"], layout, hsz)
        k = policy(h @ wk, "dp", None, "tp").reshape(b, t, layout.kv_pad, hsz)
        v = policy(h @ wv, "dp", None, "tp").reshape(b, t, layout.kv_pad, hsz)
        off = jnp.asarray(q_offset, jnp.int32)
        ragged = off.ndim == 1                 # [B] per-request offsets
        if cfg.use_rope:
            pos = (off[:, None] + jnp.arange(t)[None, :] if ragged
                   else (jnp.arange(t) + off)[None, :])
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        if kv_buffer is not None:
            kbuf, vbuf = kv_buffer
            if ragged:
                # ragged chunk packing: every request writes its chunk rows
                # at its own prefill progress
                upd = jax.vmap(lambda bu, nu, o: jax.lax.dynamic_update_slice(
                    bu, nu, (o, 0, 0)))
                kbuf = upd(kbuf, k.astype(kbuf.dtype), off)
                vbuf = upd(vbuf, v.astype(vbuf.dtype), off)
            else:
                kbuf = jax.lax.dynamic_update_slice(
                    kbuf, k.astype(kbuf.dtype), (0, off, 0, 0))
                vbuf = jax.lax.dynamic_update_slice(
                    vbuf, v.astype(vbuf.dtype), (0, off, 0, 0))
            k, v = kbuf, vbuf
    else:
        k, v = kv_override                     # cross-attn: precomputed enc KV
    out = prefill_attention(q, k, v, causal=causal, window=window,
                            chunk_q=chunk_q, q_offset=q_offset,
                            unroll=unroll, backend=attn_backend, prune=prune,
                            seq_lens=seq_lens)
    out = out.reshape(b, t, layout.q_pad * hsz)
    proj = policy(out, "dp", None, "tp") @ wo
    return policy(proj, "dp", None, None), (k, v)


def _ffn_block(cfg: ArchConfig, fp, h, policy):
    act = activation(cfg.act)
    if "w3" in fp:
        y = act(h @ fp["w1"]) * (h @ fp["w3"])
    else:
        y = act(h @ fp["w1"])
    y = policy(y, "dp", None, "tp")
    return policy(y @ fp["w2"], "dp", None, None)


def decoder_layer(cfg: ArchConfig, lp, x, *, layout, window, policy,
                  enc_out=None, moe_groups=1, chunk_q=512, unroll=False,
                  attn_backend="ref", ssd_backend="ref", prune=True,
                  kv_buffer=None, q_offset=0, seq_lens=None):
    """One decoder layer.  Returns (x, (kcache, vcache, ssm_state, aux)).

    ``attn_backend`` / ``ssd_backend`` select the flash_prefill and
    ssd_prefill kernel backends (kernels/registry.py); ``prune`` the
    flash_prefill block-skipping knob.  ``kv_buffer`` / ``q_offset`` /
    ``seq_lens`` are the chunked-prefill carry contract (see
    ``_attn_block``): when given, the returned kcache/vcache are the
    *updated full-prefix buffers* instead of the chunk's own rows."""
    b, t, _ = x.shape
    h = rms_norm(x, lp["ln1"])
    cache_kv = (jnp.zeros((b, t, 0, cfg.hsz), x.dtype),) * 2
    ssm_state = None
    if cfg.has_attention and cfg.has_ssm:                       # hybrid
        a_out, cache_kv = _attn_block(cfg, lp["attn"], h, layout=layout,
                                      window=window, policy=policy,
                                      chunk_q=chunk_q, unroll=unroll,
                                      attn_backend=attn_backend, prune=prune)
        s_out, ssm_state = ssm_lib.ssd_chunked(
            ssm_lib.SSMParams(**lp["ssm"]), cfg, h, unroll=unroll,
            backend=ssd_backend)
        x = x + 0.5 * (a_out + s_out)
    elif cfg.has_attention:
        a_out, cache_kv = _attn_block(cfg, lp["attn"], h, layout=layout,
                                      window=window, policy=policy,
                                      chunk_q=chunk_q, unroll=unroll,
                                      attn_backend=attn_backend, prune=prune,
                                      kv_buffer=kv_buffer, q_offset=q_offset,
                                      seq_lens=seq_lens)
        x = x + a_out
    else:                                                        # pure ssm
        s_out, ssm_state = ssm_lib.ssd_chunked(
            ssm_lib.SSMParams(**lp["ssm"]), cfg, h, unroll=unroll,
            backend=ssd_backend)
        x = x + s_out

    if enc_out is not None:                                      # cross-attn
        hx = rms_norm(x, lp["lnx"])
        xl = head_layout(cfg.n_heads, cfg.n_kv_heads, 1)
        kx = (enc_out @ lp["xattn"]["wk"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.hsz)
        vx = (enc_out @ lp["xattn"]["wv"]).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.hsz)
        x_out, _ = _attn_block(cfg, lp["xattn"], hx, layout=xl, window=0,
                               policy=policy, causal=False,
                               kv_override=(kx, vx), chunk_q=chunk_q,
                               unroll=unroll, attn_backend=attn_backend,
                               prune=prune)
        x = x + x_out

    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff or cfg.moe:
        h2 = rms_norm(x, lp["ln2"])
        delta = 0.0
        if cfg.d_ff:
            delta = _ffn_block(cfg, lp["ffn"], h2, policy)
        if cfg.moe:
            y, aux = moe_ffn(
                MoEParams(**lp["moe"]), h2.reshape(b * t, -1),
                cfg.moe, activation("silu"), groups=moe_groups,
                c_disp=lambda v: policy(v, "dp", None, None, None),
                c_exp=lambda v: policy(v, "pod", "ep", None, None))
            delta = delta + policy(y.reshape(b, t, -1), "dp", None, None)
        x = x + delta
    return x, (cache_kv[0], cache_kv[1], ssm_state, aux)


def layer_windows(cfg: ArchConfig) -> np.ndarray:
    """Per-layer sliding-window sizes ([L] int32; 0 = global attention)."""
    w = np.zeros((cfg.n_layers,), np.int32)
    if cfg.local_window and cfg.local_ratio:
        period = cfg.local_ratio + 1
        for i in range(cfg.n_layers):
            if (i + 1) % period != 0:          # 5 local then 1 global
                w[i] = cfg.local_window
    return w


# =============================================================== full fwd
def chunked_prefill_supported(cfg: ArchConfig) -> bool:
    """Whether ``cfg`` can prefill in prefix-attending chunks *bit-exactly*.

    Requires every cross-position interaction to be causal attention: pure
    SSM / hybrid scans and MoE capacity routing mix information across the
    whole sequence in chunk-boundary-dependent fp orders, and enc-dec /
    vision prefixes need the full prompt up front.  The serving engine falls
    back to one-shot prefill for unsupported archs."""
    return (cfg.has_attention and not cfg.has_ssm and not cfg.is_encdec
            and cfg.moe is None and not cfg.vision_patches)


def forward(cfg: ArchConfig, params, tokens, *, policy=NO_POLICY,
            patch_embeds=None, enc_frames=None, return_cache: bool = False,
            moe_groups: int = 1, chunk_q: int = 512, tp_width: int = 1,
            remat: bool = True, unroll: bool = False,
            prefill_backend: str = "ref", ssd_backend: str = "ref",
            prune_blocks: bool = True, prefix_state=None, q_offset=0,
            seq_lens=None):
    """Full-sequence forward.  tokens [B, T] int32 -> (logits, extras).

    extras = {"aux_loss": scalar, "kcache"/"vcache": [L,B,T,Kh_p,hsz],
              "ssm_conv"/"ssm_state": [L,...]} (caches when return_cache).

    ``prefill_backend`` / ``ssd_backend`` route the attention and SSD-scan
    hotspots through the kernel registry (ref | pallas-interpret | pallas);
    the pallas backends use a ref-VJP backward, so gradients flow (train).
    ``prune_blocks`` is flash_prefill's causal/window block-skipping knob
    (kernel backends only; bit-exact on/off).

    Chunked prefill (``chunked_prefill_supported`` archs only, see
    docs/serving.md): ``prefix_state`` = {"kcache"/"vcache":
    [L, B, S_buf, Kp, hsz]} carry buffers whose rows ``[0, q_offset)`` hold
    the already-prefilled prefix; ``tokens`` is then the ``[B, T]`` chunk at
    global positions ``[q_offset, q_offset + T)``.  The chunk's K/V rows are
    written into the buffers and attention runs over the whole buffer
    (causal masking hides the unfilled tail), so extras' kcache/vcache are
    the *updated full buffers* — bit-exact with the one-shot prefill when
    ``S_buf`` equals the one-shot sequence length.  ``seq_lens`` masks kv
    positions per request (ragged packing).
    """
    b, t = tokens.shape
    if prefix_state is not None:
        assert chunked_prefill_supported(cfg), \
            f"chunked prefill unsupported for {cfg.name} ({cfg.family})"
        assert return_cache, "chunked prefill needs return_cache=True"
    x = params["embed"][tokens]                                 # [B,T,H]
    x = policy(x, "dp", None, None)
    if patch_embeds is not None:                                # vlm stub
        p = patch_embeds.shape[1]
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x[:, p:]], axis=1)
    if not cfg.use_rope and not cfg.is_encdec:
        from repro.models.layers import sinusoidal_at
        off = jnp.asarray(q_offset, jnp.int32)
        if off.ndim == 1:                      # ragged per-request offsets
            pos = (off[:, None] + jnp.arange(t)[None, :]).astype(jnp.float32)
            x = x + sinusoidal_at(pos, cfg.d_model).astype(x.dtype)
        else:
            pos = (jnp.arange(t) + off).astype(jnp.float32)
            x = x + sinusoidal_at(pos, cfg.d_model)[None].astype(x.dtype)

    enc_out = None
    if cfg.is_encdec:
        from repro.models.encdec import encode                  # lazy: cycle
        enc_out = encode(cfg, params["enc"], enc_frames, policy=policy,
                         chunk_q=chunk_q, unroll=unroll,
                         attn_backend=prefill_backend, prune=prune_blocks)
        x = x + sinusoidal_positions(t, cfg.d_model)[None].astype(x.dtype)

    layout = (head_layout(cfg.n_heads, cfg.n_kv_heads, tp_width)
              if cfg.has_attention else None)
    windows = jnp.asarray(layer_windows(cfg))

    def body(carry, xs):
        lp, win, buf = xs
        y, (kc, vc, sst, aux) = decoder_layer(
            cfg, lp, carry, layout=layout, window=win, policy=policy,
            enc_out=enc_out, moe_groups=moe_groups, chunk_q=chunk_q,
            unroll=unroll, attn_backend=prefill_backend,
            ssd_backend=ssd_backend, prune=prune_blocks,
            kv_buffer=buf, q_offset=q_offset, seq_lens=seq_lens)
        outs = (kc, vc, sst, aux) if return_cache else \
            (None, None, None, aux)
        return y, outs

    bufs = (None if prefix_state is None
            else (prefix_state["kcache"], prefix_state["vcache"]))
    body_fn = jax.checkpoint(body) if remat else body
    x, (kc, vc, sst, aux) = jax.lax.scan(
        body_fn, x, (params["layers"], windows, bufs),
        unroll=cfg.n_layers if unroll else 1)

    x = rms_norm(x, params["ln_f"])
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    logits = policy(logits, "dp", None, "tp")
    if cfg.softcap:
        logits = softcap(logits, cfg.softcap)
    # mask padded vocab rows so softmax/loss are exact
    vmask = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, 0.0, -1e30)
    logits = logits + vmask.astype(logits.dtype)

    extras = {"aux_loss": jnp.sum(aux)}
    if return_cache:
        extras.update(kcache=kc, vcache=vc)
        if sst is not None:
            extras.update(ssm_conv=sst.conv, ssm_state=sst.ssm)
    if enc_out is not None:
        extras["enc_out"] = enc_out
    return logits, extras


def lm_loss(cfg: ArchConfig, logits, labels):
    """Mean next-token cross-entropy; labels [B,T] with -100 = ignore."""
    valid = labels >= 0
    lbl = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
