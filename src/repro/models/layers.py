"""Shared layer primitives: norms, RoPE, activations, embeddings, init."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, weight, bias=None, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_gated": jax.nn.gelu}[name]


# ---------------------------------------------------------------- RoPE
def rope_freqs(hsz: int, theta: float):
    """[hsz/2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, hsz, 2, dtype=jnp.float32) / hsz))


def apply_rope(x, positions, theta: float = 10_000.0):
    """Rotate head vectors.  x [..., T, n_heads, hsz], positions [..., T]."""
    hsz = x.shape[-1]
    inv = rope_freqs(hsz, theta)                         # [hsz/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., T, hsz/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., T, 1, hsz/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int):
    """Whisper-style sinusoidal embeddings [length, dim]."""
    log_timescale = jnp.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)


def sinusoidal_at(pos, dim: int):
    """Sinusoidal embedding at dynamic position(s).  pos [...] -> [..., dim]."""
    log_timescale = jnp.log(10_000.0) / (dim // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(dim // 2, dtype=jnp.float32))
    t = pos[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)


# ---------------------------------------------------------------- init
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
