"""Mamba2 (SSD — state-space duality) block, pure JAX.

Three entry points sharing one parameter set:

  * ``ssd_scan_ref``   — exact sequential recurrence (oracle; lax.scan over T)
  * ``ssd_chunked``    — the SSD block-matrix algorithm (train/prefill path;
                         O(T·Lc) work in chunks of Lc, matmul-friendly).
                         The TPU hotspot version is kernels/ssd_prefill.
  * ``ssm_decode_step``— O(1)-state single-token decode update

Recurrence (per head h, state n, channel p):

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t ⊗ x_t
    y_t = C_t · h_t + D * x_t

with A < 0 scalar per head (mamba2), B,C shared across heads per group.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rms_norm, dense_init


class SSMParams(NamedTuple):
    w_in: jax.Array      # [H, d_in_proj]  (z, xBC, dt)
    conv_w: jax.Array    # [conv_dim, d_conv] depthwise
    conv_b: jax.Array    # [conv_dim]
    A_log: jax.Array     # [nheads]
    D: jax.Array         # [nheads]
    dt_bias: jax.Array   # [nheads]
    norm_w: jax.Array    # [d_inner]  gated RMSNorm before out-proj
    w_out: jax.Array     # [d_inner, H]


def d_in_proj(cfg: ArchConfig) -> int:
    return 2 * cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state + cfg.ssm_heads


def init_ssm(cfg: ArchConfig, key, dtype) -> SSMParams:
    ks = jax.random.split(key, 4)
    h = cfg.d_model
    nh = cfg.ssm_heads
    return SSMParams(
        w_in=dense_init(ks[0], (h, d_in_proj(cfg)), dtype),
        conv_w=dense_init(ks[1], (cfg.conv_dim, cfg.ssm_conv), dtype, scale=0.5),
        conv_b=jnp.zeros((cfg.conv_dim,), dtype),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        D=jnp.ones((nh,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, nh))).astype(jnp.float32),
        norm_w=jnp.zeros((cfg.d_inner,), dtype),
        w_out=dense_init(ks[2], (cfg.d_inner, h), dtype),
    )


class SSMState(NamedTuple):
    conv: jax.Array   # [B, conv_dim, d_conv - 1] shift register
    ssm: jax.Array    # [B, nheads, headdim, dstate] f32


def init_ssm_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> SSMState:
    return SSMState(
        conv=jnp.zeros((batch, cfg.conv_dim, cfg.ssm_conv - 1), dtype),
        ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                      jnp.float32),
    )


# ------------------------------------------------------------------ shared
def _project(p: SSMParams, cfg: ArchConfig, x):
    """x [..., H] -> (z [..., d_inner], xBC [..., conv_dim], dt [..., nh])."""
    proj = x @ p.w_in
    di, cd = cfg.d_inner, cfg.conv_dim
    z, xbc, dt = jnp.split(proj, [di, di + cd], axis=-1)
    return z, xbc, dt


def _split_xbc(cfg: ArchConfig, xbc):
    di, gs = cfg.d_inner, cfg.ssm_ngroups * cfg.ssm_state
    xs, b, c = jnp.split(xbc, [di, di + gs], axis=-1)
    return xs, b, c


def _dt_act(dt, dt_bias):
    return jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)


def _gate_out(p: SSMParams, y, z):
    """Gated RMSNorm + out-projection.  y,z [..., d_inner]."""
    g = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p.norm_w)
    return g.astype(p.w_out.dtype) @ p.w_out


def _conv_full(p: SSMParams, xbc):
    """Causal depthwise conv over T.  xbc [B, T, conv_dim]."""
    dc = p.conv_w.shape[-1]
    pad = jnp.pad(xbc, ((0, 0), (dc - 1, 0), (0, 0)))
    # depthwise: sum_k w[c,k] * x[t - (dc-1) + k, c]
    stacked = jnp.stack([pad[:, k:k + xbc.shape[1], :] for k in range(dc)],
                        axis=-1)                       # [B,T,conv_dim,dc]
    out = jnp.einsum("btck,ck->btc", stacked.astype(jnp.float32),
                     p.conv_w.astype(jnp.float32)) + p.conv_b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


# ------------------------------------------------------------------ oracle
def ssd_scan_ref(p: SSMParams, cfg: ArchConfig, x, state: SSMState | None = None):
    """Exact sequential recurrence.  x [B, T, H] -> (y [B, T, H], SSMState)."""
    b, t, _ = x.shape
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    if state is None:
        state = init_ssm_state(cfg, b)
    z, xbc_raw, dt = _project(p, cfg, x)

    # conv with carried shift-register state
    dc = cfg.ssm_conv
    hist = jnp.concatenate([state.conv.transpose(0, 2, 1), xbc_raw], axis=1)
    stacked = jnp.stack([hist[:, k:k + t, :] for k in range(dc)], axis=-1)
    xbc = jnp.einsum("btck,ck->btc", stacked.astype(jnp.float32),
                     p.conv_w.astype(jnp.float32)) + p.conv_b.astype(jnp.float32)
    xbc = jax.nn.silu(xbc).astype(x.dtype)
    new_conv = hist[:, t:, :].transpose(0, 2, 1) if dc > 1 else state.conv

    xs, bb, cc = _split_xbc(cfg, xbc)
    xs = xs.reshape(b, t, nh, hd).astype(jnp.float32)
    bb = bb.reshape(b, t, cfg.ssm_ngroups, ds).astype(jnp.float32)
    cc = cc.reshape(b, t, cfg.ssm_ngroups, ds).astype(jnp.float32)
    heads_per_group = nh // cfg.ssm_ngroups
    bb = jnp.repeat(bb, heads_per_group, axis=2)       # [B,T,nh,ds]
    cc = jnp.repeat(cc, heads_per_group, axis=2)
    dtv = _dt_act(dt, p.dt_bias)                       # [B,T,nh]
    a = -jnp.exp(p.A_log)                              # [nh]
    da = jnp.exp(dtv * a)                              # [B,T,nh]

    def step(h, inp):
        da_t, dt_t, x_t, b_t, c_t = inp
        h = da_t[:, :, None, None] * h + (dt_t[:, :, None] * x_t)[..., None] \
            * b_t[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    xs_t = xs.transpose(1, 0, 2, 3)
    bb_t = bb.transpose(1, 0, 2, 3)
    cc_t = cc.transpose(1, 0, 2, 3)
    da_t = da.transpose(1, 0, 2)
    dt_t = dtv.transpose(1, 0, 2)
    h_fin, ys = jax.lax.scan(step, state.ssm, (da_t, dt_t, xs_t, bb_t, cc_t))
    ys = ys.transpose(1, 0, 2, 3) + p.D[None, None, :, None] * xs  # [B,T,nh,hd]
    y = _gate_out(p, ys.reshape(b, t, cfg.d_inner).astype(x.dtype), z)
    return y, SSMState(new_conv, h_fin)


# ------------------------------------------------- kernel-backed scan core
@functools.lru_cache(maxsize=None)
def _kernel_ssd_core(lc: int, interpret: bool):
    """ssd_prefill kernel with a custom VJP whose backward re-runs the jnp
    sequential-scan oracle — Pallas kernels define no transpose rule, so this
    is what lets the pallas ssd backends run under ``value_and_grad``."""
    from repro.kernels.ssd_prefill.ops import ssd_prefill
    from repro.kernels.ssd_prefill.ref import ssd_prefill_ref

    @jax.custom_vjp
    def f(x, dt, a, bm, cm, d, h0):
        return ssd_prefill(x, dt, a, bm, cm, d, h0=h0, lc=lc,
                           interpret=interpret)

    def fwd(x, dt, a, bm, cm, d, h0):
        return f(x, dt, a, bm, cm, d, h0), (x, dt, a, bm, cm, d, h0)

    def bwd(res, g):
        primals = res
        _, vjp = jax.vjp(lambda *args: ssd_prefill_ref(*args[:6], h0=args[6]),
                         *primals)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


# ------------------------------------------------------------------ chunked
def ssd_chunked(p: SSMParams, cfg: ArchConfig, x, state: SSMState | None = None,
                chunk: int = 64, unroll: bool = False, backend: str = "ref"):
    """SSD block-matrix algorithm (Mamba2 paper §6); matmul-dominated.

    Within each chunk of Lc tokens:  Y_intra = (L ∘ (C Bᵀ)) · (dt·X)  with
    L[i,j] = exp(cum[i] - cum[j]) for i >= j; chunk states are carried by a
    scan over T/Lc chunks for the inter-chunk contribution.

    ``backend`` routes the scan *core* (everything between the input split
    and the gated out-projection) through the ssd_prefill kernel family:
    ``"ref"`` keeps the inline jnp block-matrix math; ``"pallas-interpret"``
    / ``"pallas"`` call kernels/ssd_prefill (interpreted / compiled) with a
    ref-VJP backward so training works.  The projection, causal conv and
    gated out-projection stay jnp either way (they are GSPMD-sharded
    matmuls, not scan work).
    """
    b, t, _ = x.shape
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    if state is None:
        state = init_ssm_state(cfg, b)
    lc = min(chunk, t)
    assert t % lc == 0, (t, lc)
    nc = t // lc

    z, xbc_raw, dt = _project(p, cfg, x)
    dc = cfg.ssm_conv
    hist = jnp.concatenate([state.conv.transpose(0, 2, 1), xbc_raw], axis=1)
    stacked = jnp.stack([hist[:, k:k + t, :] for k in range(dc)], axis=-1)
    xbc = jnp.einsum("btck,ck->btc", stacked.astype(jnp.float32),
                     p.conv_w.astype(jnp.float32)) + p.conv_b.astype(jnp.float32)
    xbc = jax.nn.silu(xbc).astype(x.dtype)
    new_conv = hist[:, t:, :].transpose(0, 2, 1) if dc > 1 else state.conv

    xs, bb, cc = _split_xbc(cfg, xbc)
    g = cfg.ssm_ngroups
    hpg_flat = nh // g
    a_neg = -jnp.exp(p.A_log)

    if backend != "ref":
        from repro.kernels import registry
        registry.validate("ssd_prefill", backend)
        xs_f = xs.reshape(b, t, nh, hd).astype(jnp.float32)
        bb_f = jnp.repeat(bb.reshape(b, t, g, ds), hpg_flat,
                          axis=2).astype(jnp.float32)
        cc_f = jnp.repeat(cc.reshape(b, t, g, ds), hpg_flat,
                          axis=2).astype(jnp.float32)
        dtv_f = _dt_act(dt, p.dt_bias)                     # [B,T,nh]
        core = _kernel_ssd_core(lc, registry.interpret_flag(backend))
        ys, h_fin = core(xs_f, dtv_f, a_neg, bb_f, cc_f,
                         p.D.astype(jnp.float32), state.ssm)
        y = _gate_out(p, ys.reshape(b, t, cfg.d_inner).astype(x.dtype), z)
        return y, SSMState(new_conv, h_fin)

    xs = xs.reshape(b, nc, lc, nh, hd).astype(jnp.float32)
    bb = bb.reshape(b, nc, lc, g, ds).astype(jnp.float32)
    cc = cc.reshape(b, nc, lc, g, ds).astype(jnp.float32)
    hpg = nh // g
    dtv = _dt_act(dt, p.dt_bias).reshape(b, nc, lc, nh)
    dta = dtv * a_neg                                   # log-decay per step
    cum = jnp.cumsum(dta, axis=2)                       # [B,nc,lc,nh]

    # intra-chunk: scores[i,j] = C_i·B_j * exp(cum_i - cum_j) * dt_j  (i>=j)
    cb = jnp.einsum("bcign,bcjgn->bcgij", cc, bb)       # [B,nc,g,lc,lc]
    cb = jnp.repeat(cb, hpg, axis=2)                    # [B,nc,nh,lc,lc]
    li = cum.transpose(0, 1, 3, 2)                      # [B,nc,nh,lc]
    # cum is non-increasing, so the causal (i >= j) exponents are <= 0; the
    # masked upper triangle is *positive* and exp overflows to inf there,
    # which turns the where's cotangent into 0 * inf = NaN.  Zero the
    # exponent under the mask before exp so both passes stay finite.
    ldiff = li[..., :, None] - li[..., None, :]
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    decay = jnp.exp(jnp.where(mask, ldiff, 0.0))
    w = jnp.where(mask, cb * decay, 0.0) * dtv.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xs)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j
    seg = jnp.exp(cum[:, :, -1:, :] - cum)              # [B,nc,lc,nh]
    bb_h = jnp.repeat(bb, hpg, axis=3)                  # [B,nc,lc,nh,ds]
    dbx = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", seg * dtv, bb_h, xs)

    chunk_decay = jnp.exp(cum[:, :, -1, :])             # [B,nc,nh] full-chunk

    def carry(h, inp):
        dbx_c, cd_c = inp                               # [B,nh,hd,ds],[B,nh]
        h_new = cd_c[:, :, None, None] * h + dbx_c
        return h_new, h                                 # emit state *entering*

    h_fin, h_in = jax.lax.scan(
        carry, state.ssm,
        (dbx.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=nc if unroll else 1)
    h_in = h_in.transpose(1, 0, 2, 3, 4)                # [B,nc,nh,hd,ds]

    # inter-chunk: y_i += exp(cum_i) * C_i · h_in
    cc_h = jnp.repeat(cc, hpg, axis=3)                  # [B,nc,lc,nh,ds]
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", cc_h, h_in) \
        * jnp.exp(cum)[..., None]

    ys = (y_intra + y_inter).reshape(b, t, nh, hd) \
        + p.D[None, None, :, None] * xs.reshape(b, t, nh, hd)
    y = _gate_out(p, ys.reshape(b, t, cfg.d_inner).astype(x.dtype), z)
    return y, SSMState(new_conv, h_fin)


# ------------------------------------------------------------------ decode
def ssm_decode_step(p: SSMParams, cfg: ArchConfig, x, state: SSMState):
    """Single-token decode.  x [B, H] -> (y [B, H], new state)."""
    b = x.shape[0]
    nh, hd, ds = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xbc_raw, dt = _project(p, cfg, x)                # [B, ...]

    hist = jnp.concatenate([state.conv, xbc_raw[:, :, None]], axis=-1)
    xbc = jnp.einsum("bck,ck->bc", hist.astype(jnp.float32),
                     p.conv_w.astype(jnp.float32)) + p.conv_b.astype(jnp.float32)
    xbc = jax.nn.silu(xbc).astype(x.dtype)
    new_conv = hist[:, :, 1:]

    xs, bb, cc = _split_xbc(cfg, xbc)
    xs = xs.reshape(b, nh, hd).astype(jnp.float32)
    g = cfg.ssm_ngroups
    hpg = nh // g
    bb = jnp.repeat(bb.reshape(b, g, ds), hpg, axis=1).astype(jnp.float32)
    cc = jnp.repeat(cc.reshape(b, g, ds), hpg, axis=1).astype(jnp.float32)
    dtv = _dt_act(dt, p.dt_bias)                        # [B,nh]
    da = jnp.exp(dtv * (-jnp.exp(p.A_log)))             # [B,nh]

    h = da[:, :, None, None] * state.ssm \
        + (dtv[:, :, None] * xs)[..., None] * bb[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, cc) + p.D[None, :, None] * xs
    out = _gate_out(p, y.reshape(b, cfg.d_inner).astype(x.dtype), z)
    return out, SSMState(new_conv, h)
