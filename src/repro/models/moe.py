"""Mixture-of-Experts: top-k router + gather-based capacity dispatch.

Dispatch is built from *gathers/scatters* rather than GShard one-hot einsums:
identical semantics (capacity-C token dropping, gate-weighted combine) but the
dispatch contributes ~zero FLOPs to ``cost_analysis`` so the §Roofline
MODEL_FLOPS/HLO_FLOPs ratio stays meaningful, and it is autodiff-able
(gather's transpose is scatter-add).

Group structure [G, g, ...] keeps the data-axis all-to-all pattern under
GSPMD: dispatch is local within a group; the reshard from [G@data, E, C, H]
to [G, E@data, C, H] lowers to an all-to-all (the paper's MoE FFN schedule:
intra-expert All-Reduce + inter-expert All-Gather is what GSPMD emits from
the TPF×EP constraints).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.utils import cdiv


class MoEParams(NamedTuple):
    router: jax.Array   # [H, E] (kept f32 for routing stability)
    w1: jax.Array       # [E, H, Fe]
    w3: jax.Array       # [E, H, Fe]
    w2: jax.Array       # [E, Fe, H]


def init_moe(moe: MoEConfig, d_model: int, key, dtype) -> MoEParams:
    ks = jax.random.split(key, 4)
    e, fe, h = moe.n_experts, moe.d_ff, d_model
    s_in, s_out = h ** -0.5, fe ** -0.5
    return MoEParams(
        router=(jax.random.normal(ks[0], (h, e), jnp.float32) * 0.02),
        w1=(jax.random.normal(ks[1], (e, h, fe), jnp.float32) * s_in).astype(dtype),
        w3=(jax.random.normal(ks[2], (e, h, fe), jnp.float32) * s_in).astype(dtype),
        w2=(jax.random.normal(ks[3], (e, fe, h), jnp.float32) * s_out).astype(dtype),
    )


class RouterOut(NamedTuple):
    expert_idx: jax.Array   # [T, k] int32
    gates: jax.Array        # [T, k] f32 (renormalized over top-k)
    aux_loss: jax.Array     # scalar: load-balance + z-loss


def route(router_w, x, moe: MoEConfig) -> RouterOut:
    """x [T, H] -> top-k expert assignment + aux losses."""
    logits = x.astype(jnp.float32) @ router_w              # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, expert_idx = jax.lax.top_k(probs, moe.topk)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance loss + router z-loss
    e = moe.n_experts
    me = jnp.mean(probs, axis=0)                           # mean router prob
    ce = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / expert_idx.size)                             # fraction dispatched
    aux = moe.aux_coef * e * jnp.sum(me * ce)
    z = moe.router_z_coef * jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return RouterOut(expert_idx.astype(jnp.int32), gates, aux + z)


def dispatch_plan(expert_idx, n_experts: int, capacity: int):
    """Token->slot plan.  expert_idx [T, k] -> (slot_of [T,k], tok_of [E*C]).

    slot_of[t,j]  — slot within expert (== capacity ⇒ dropped)
    tok_of[e*C+c] — flat token index filling that slot (== T ⇒ empty slot)
    """
    t, k = expert_idx.shape
    flat_e = expert_idx.reshape(-1)                        # [T*k], token-major
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot              # rank within expert
    slot_of = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    slot_of = jnp.minimum(slot_of, capacity).reshape(t, k)  # == capacity: drop

    keep = slot_of.reshape(-1) < capacity
    # dropped assignments get an out-of-bounds slot; mode="drop" discards them
    flat_slot = jnp.where(keep, flat_e * capacity + slot_of.reshape(-1),
                          n_experts * capacity)
    tok_ids = jnp.arange(t * k, dtype=jnp.int32) // k
    tok_of = jnp.full((n_experts * capacity,), t, jnp.int32)
    tok_of = tok_of.at[flat_slot].set(tok_ids, mode="drop")
    return slot_of, tok_of


def expert_ffn(params: MoEParams, xe, act):
    """Batched expert MLP.  xe [E, C, H] -> [E, C, H]."""
    h1 = jnp.einsum("ech,ehf->ecf", xe, params.w1)
    h3 = jnp.einsum("ech,ehf->ecf", xe, params.w3)
    return jnp.einsum("ecf,efh->ech", act(h1) * h3, params.w2)


def _identity(x):
    return x


def moe_ffn(params: MoEParams, x, moe: MoEConfig, act,
            capacity_factor: float | None = None, groups: int = 1,
            c_disp=_identity, c_exp=_identity):
    """Full MoE layer.  x [T, H] -> (y [T, H], aux_loss).

    ``groups`` splits T for group-local dispatch.  ``c_disp`` / ``c_exp`` are
    sharding-constraint hooks applied to the grouped [G, E, C, H] tensor in
    its dispatch layout (G sharded, e.g. over DP) and its expert layout
    (E sharded over EP).  Under GSPMD the c_disp->c_exp reshard lowers to the
    inter-expert all-to-all; the expert einsums with TP-sharded weights emit
    the intra-expert all-reduce — the paper's §2.2 MoE FFN schedule.
    """
    t, h = x.shape
    cf = capacity_factor or moe.capacity_factor
    r = route(params.router, x, moe)

    g = groups
    assert t % g == 0, (t, g)
    tg = t // g
    cap = max(cdiv(tg * moe.topk, moe.n_experts), 1)
    cap = int(cap * cf + 0.5)
    e = moe.n_experts

    xg = x.reshape(g, tg, h)
    eig = r.expert_idx.reshape(g, tg, moe.topk)
    gag = r.gates.reshape(g, tg, moe.topk)

    slot_of, tok_of = jax.vmap(
        lambda ei: dispatch_plan(ei, e, cap))(eig)          # [G,tg,k],[G,E*C]
    # Empty slots carry the out-of-bounds sentinel (== tg); mode="fill" zeroes
    # them in the gather itself.  No sentinel zero-row concat: an unevenly
    # sharded concat feeding a gather miscompiles under the SPMD partitioner
    # of older XLA (replicated operand becomes a partial-sum — observed 2x
    # values on a ('data', 'model') mesh with EP constraints downstream).
    xe = jnp.take_along_axis(xg, tok_of[..., None], axis=1,
                             mode="fill", fill_value=0)     # [G, E*C, H]
    xe = c_disp(xe.reshape(g, e, cap, h))
    xe = c_exp(xe)                                          # reshard: a2a

    h1 = jnp.einsum("gech,ehf->gecf", xe, params.w1)
    h3 = jnp.einsum("gech,ehf->gecf", xe, params.w3)
    ye = jnp.einsum("gecf,efh->gech", act(h1) * h3, params.w2)
    ye = c_disp(c_exp(ye))                                  # reshard back

    src = eig * cap + jnp.minimum(slot_of, cap - 1)
    src = jnp.where(slot_of < cap, src, e * cap)            # dropped -> zero
    yk = jnp.take_along_axis(ye.reshape(g, e * cap, h),
                             src.reshape(g, tg * moe.topk, 1), axis=1,
                             mode="fill", fill_value=0)
    yk = yk.reshape(g, tg, moe.topk, h)
    y = jnp.sum(yk * gag[..., None].astype(ye.dtype), axis=2)
    return y.reshape(t, h), r.aux_loss
