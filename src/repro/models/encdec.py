"""Whisper-style encoder (conv frontend stubbed — input_specs provides
precomputed frame embeddings [B, S_enc, d_model])."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.configs.base import ArchConfig
from repro.models.attention import head_layout
from repro.models.layers import rms_norm, sinusoidal_positions
from repro.models.transformer import NO_POLICY


def encode(cfg: ArchConfig, enc_params, frames, *, policy=NO_POLICY,
           chunk_q: int = 512, tp_width: int = 1, unroll: bool = False,
           attn_backend: str = "ref", prune: bool = True):
    """frames [B, S_enc, d_model] -> enc_out [B, S_enc, d_model].

    ``attn_backend`` routes the bidirectional encoder attention through the
    flash_prefill kernel family (non-causal mode); ``prune`` its
    block-skipping knob."""
    from repro.models.transformer import _attn_block, _ffn_block  # cycle-free

    b, s, _ = frames.shape
    x = frames + sinusoidal_positions(s, cfg.d_model)[None].astype(frames.dtype)
    x = policy(x, "dp", None, None)
    layout = head_layout(cfg.n_heads, cfg.n_kv_heads, tp_width)

    def body(carry, lp):
        h = rms_norm(carry, lp["ln1"])
        a_out, _ = _attn_block(cfg, lp["attn"], h, layout=layout, window=0,
                               policy=policy, causal=False, chunk_q=chunk_q,
                               unroll=unroll, attn_backend=attn_backend,
                               prune=prune)
        y = carry + a_out
        h2 = rms_norm(y, lp["ln2"])
        y = y + _ffn_block(cfg, lp["ffn"], h2, policy)
        return y, None

    x, _ = jax.lax.scan(body, x, enc_params["layers"],
                        unroll=cfg.enc_layers if unroll else 1)
    return rms_norm(x, enc_params["ln_f"])


def cross_kv(cfg: ArchConfig, layers_params, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output.

    Returns (kx, vx) [L, B, S_enc, Kh, hsz] — the static "KV cache" that the
    Helix decode path shards across KVP ranks (contiguous split, no
    round-robin since it never grows).
    """
    b, s, _ = enc_out.shape

    def one(lp):
        k = (enc_out @ lp["xattn"]["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.hsz)
        v = (enc_out @ lp["xattn"]["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.hsz)
        return k, v

    return jax.lax.map(one, layers_params)
