from repro.runtime.watchdog import StepWatchdog, RetryPolicy, run_with_retries

__all__ = ["StepWatchdog", "RetryPolicy", "run_with_retries"]
