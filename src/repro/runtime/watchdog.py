"""Fault tolerance runtime: step watchdog + retry/restart policy.

At thousand-node scale the failure modes we must survive are (a) a step
that hangs (collective deadlock after a node drop), (b) a step that dies
(device OOM, preemption), (c) persistent stragglers.  The mechanism here:

  * ``StepWatchdog`` — wraps each step with a monotonic deadline on a
    background timer; on trip it invokes ``on_stall`` (log + best-effort
    checkpoint + abort).  On a real pod the abort kills the hung collective
    so the launcher can re-form the mesh without the failed pod (the elastic
    restore path in checkpoint/manager.py — same code the elastic test
    exercises).
  * ``run_with_retries`` — the launcher loop: run step; on exception or
    watchdog trip, restore from the newest committed checkpoint and resume
    (bounded retries, exponential backoff).  Straggler mitigation: per-step
    wall-times feed an EWMA; a step exceeding ``straggler_factor`` x EWMA is
    *recorded* so the scheduler can migrate that pod's shard at the next
    checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable


class StepTimeout(RuntimeError):
    pass


class StepWatchdog:
    def __init__(self, timeout_s: float, on_stall: Callable[[], None] | None = None):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self.ewma: float | None = None
        self.straggler_steps: list[tuple[int, float]] = []

    def run(self, step_idx: int, fn: Callable[[], Any],
            straggler_factor: float = 3.0) -> Any:
        tripped = threading.Event()

        def _trip():
            tripped.set()
            if self.on_stall:
                self.on_stall()

        timer = threading.Timer(self.timeout_s, _trip)
        timer.daemon = True
        timer.start()
        t0 = time.monotonic()
        try:
            out = fn()
        finally:
            timer.cancel()
        dt = time.monotonic() - t0
        if tripped.is_set():
            raise StepTimeout(f"step {step_idx} exceeded {self.timeout_s}s")
        prev = self.ewma
        self.ewma = dt if prev is None else 0.9 * prev + 0.1 * dt
        if prev is not None and dt > straggler_factor * prev:
            self.straggler_steps.append((step_idx, dt))
        return out


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 1.0
    backoff_mult: float = 2.0


def run_with_retries(step_fn: Callable[[int, Any], Any], state: Any,
                     *, start_step: int, num_steps: int,
                     save_fn: Callable[[int, Any], None] | None = None,
                     restore_fn: Callable[[], tuple[int, Any]] | None = None,
                     save_every: int = 50,
                     watchdog: StepWatchdog | None = None,
                     policy: RetryPolicy = RetryPolicy(),
                     log: Callable[[str], None] = print) -> tuple[int, Any]:
    """The launcher loop: deterministic data (pure fn of step) + committed
    checkpoints make crash-restart exact."""
    step = start_step
    retries = 0
    backoff = policy.backoff_s
    while step < start_step + num_steps:
        try:
            if watchdog is not None:
                state = watchdog.run(step, lambda: step_fn(step, state))
            else:
                state = step_fn(step, state)
            step += 1
            retries = 0
            backoff = policy.backoff_s
            if save_fn and step % save_every == 0:
                save_fn(step, state)
        except Exception as e:                       # noqa: BLE001
            retries += 1
            log(f"[runtime] step {step} failed ({type(e).__name__}: {e}); "
                f"retry {retries}/{policy.max_retries}")
            if retries > policy.max_retries:
                raise
            time.sleep(backoff)
            backoff *= policy.backoff_mult
            if restore_fn is not None:
                step, state = restore_fn()
                log(f"[runtime] restored from checkpoint at step {step}")
    return step, state
