"""Helix attention (§2.1): KVP×TPA sharded decode attention as a shard_map
module, composable inside a jit/GSPMD step function.

Design (DESIGN.md §2): the *only* explicit-SPMD region is the paper's
contribution — per-rank flash-decode over the local KV shard, the single
all-to-all over the query-head axis, and the LSE rescale-sum combine.  The
surrounding projections / FFN / MoE run under GSPMD with phase-dependent
sharding constraints (core/sharding.py), which is how the same device pool
is "re-provisioned" between attention and FFN on TPU.

Round-robin cache layout (§2.3): global position p lives at

    owner rank r = (p // rr) % KVP
    local slot j = ((p // rr) // KVP) * rr + p % rr

i.e. global cache slot s = r * S_loc + j when the sequence dim is sharded
contiguously over the kvp axes.  ``rr_slot_of_position`` maps p -> s for the
GSPMD cache append; the in-shard mask inverts it (kernels/flash_decode/ref).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.combine import combine_fragments
from repro.core.sharding import HelixConfig
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref, local_valid_len
from repro.utils import round_up, shard_map


def helix_out_dim(q_dim: int, n_devices: int) -> int:
    """Flattened attention-output dim after the all-to-all (padded)."""
    return round_up(q_dim, n_devices)


def rr_slot_of_position(pos, kvp: int, s_loc: int, rr_block: int):
    """Global round-robin cache slot for sequence position ``pos``."""
    blk = pos // rr_block
    rank = blk % kvp
    local = (blk // kvp) * rr_block + pos % rr_block
    return rank * s_loc + local


def _window_slice(total_len, rank, s_loc, *, kvp, rr_block, window):
    """§Perf (beyond-paper): sliding-window layers only need the last
    ``window`` positions.  Positions are strictly increasing in the local
    slot index, so the live span is the W_loc slots ending at this rank's
    valid length — slice it out and read O(window/KVP) bytes instead of
    O(S/KVP).  Returns (j_lo, w_loc) or None when the slice doesn't apply
    (static window and scalar total_len required)."""
    if not (isinstance(window, int) and window > 0
            and jnp.ndim(total_len) == 0):
        return None
    w_loc = min((window // (kvp * rr_block) + 2) * rr_block, s_loc)
    if w_loc >= s_loc:
        return None
    j_hi = local_valid_len(total_len, rank, kvp, rr_block)
    j_lo = jnp.clip(j_hi - w_loc, 0, s_loc - w_loc)
    return j_lo, w_loc


def fuse_append_applicable(hx, kvp: int, window, total_len, s_cap: int, *,
                           quant: bool = False,
                           contiguous: bool = False,
                           paged: bool = False) -> bool:
    """Static check: can this decode step run the fused KV-append epilogue?

    The fused path (kernels/flash_decode append mode) writes the new token's
    K/V row inside the kernel — quantizing it in-kernel for int8 caches —
    eliminating the separate ``append_kv``/``append_kv_quant`` cache
    round-trip.  It requires a Pallas backend with ``hx.fuse_append`` on and
    a round-robin cache, and must not collide with the sliding-window
    cache-slice fast path (which attends over a *slice* of the shard — an
    in-kernel write there would miss the real cache).  With
    ``hx.prune_blocks`` (the default) that conflict cannot arise: in-kernel
    block pruning subsumes the slice fast path, so windowed layers fuse
    too.  All inputs are trace-time static, so the choice costs nothing at
    runtime.
    """
    if hx.attn_backend == "ref" or not hx.fuse_append:
        return False
    if contiguous:
        return False
    del quant  # int8 caches fuse too (in-kernel quantization)
    if paged:
        # the paged pool never takes the cache-slice fast path (pages are
        # indirected, not sliceable), so fusion always composes
        return True
    if hx.prune_blocks:
        return True
    s_loc = s_cap // kvp
    return _window_slice(total_len, 0, s_loc, kvp=kvp, rr_block=hx.rr_block,
                         window=window) is None


def _local_attend(q, k, v, total_len, rank, *, kvp, rr_block, window,
                  contiguous: bool, kscale=None, vscale=None,
                  backend: str = "ref", k_new=None, v_new=None,
                  prune: bool = True, block_tables=None,
                  block_s: int = 512, groups=None):
    """Per-rank partial attention + LSE over the local KV shard.

    contiguous=True: static split (whisper cross-attn KV) — every local slot
    s maps to global position rank*S_loc + s; otherwise round-robin (§2.3).
    kscale/vscale [B, Kh, S_loc]: int8-cache dequant scales (§Perf knob).
    backend: "ref" (pure jnp), "pallas-interpret" or "pallas" — the Pallas
    flash-decode kernel (kernels/flash_decode) in interpreted / compiled
    mode.  The kernel covers every mode natively (per-request [B] lengths,
    contiguous layout, sliding window, int8 dequant from scales), so all
    backends are drop-in exact up to fp summation order.
    prune: in-kernel block pruning (Pallas backends) — HBM reads scale with
    the valid length / window, not the slot capacity, which subsumes the
    caller-side cache-slice fast path below.
    k_new/v_new [B, Kh, hsz]: fused KV-append epilogue (Pallas backends
    only; see ``fuse_append_applicable``) — the kernel appends the new
    token's row to the local shard and returns
    ``(out, lse, kcache, vcache)`` (+ the updated scales for int8 caches)
    instead of ``(out, lse)``.
    block_tables [B, max_pages]: shared-pool paged mode — k/v (and scales)
    are this rank's pool-plane shards ``[n_pool, Kh, ps_loc, ...]``; the
    Pallas backends stream pages through the prefetched table, the ref
    backend gathers the pages into the equivalent dense local cache first
    (bit-exact — masked tail slots contribute exact zeros).
    block_s: fixed-layout kernel S-block size (``HelixConfig.attn_block_s``).
    groups: (group_id [B], group_np [B]) — grouped shared-prefix decode
    (Pallas paged mode); the ref backend *ignores* the grouping, which is
    exactly the oracle semantics (grouping must not change results).
    """
    fused = k_new is not None
    paged = block_tables is not None
    assert not fused or backend != "ref", \
        "fused append requires a Pallas backend"
    assert not (paged and contiguous), \
        "paged mode excludes the contiguous (cross-attn) layout"
    assert groups is None or paged, \
        "grouped decode requires the paged pool"
    if paged and backend == "ref":
        from repro.core.kvcache import gather_pages
        k = gather_pages(k, block_tables)
        v = gather_pages(v, block_tables)
        if kscale is not None:
            kscale = gather_pages(kscale, block_tables)
            vscale = gather_pages(vscale, block_tables)
        paged, block_tables = False, None
    s_loc = k.shape[2]
    # Sliding-window cache-slice fast path: slice the live span out of the
    # shard and re-align positions via slot_offset.  Only worth it where the
    # kernel can't prune for itself — the ref backend, or a Pallas backend
    # with pruning disabled.  Incompatible with the fused append (the kernel
    # must write the real cache, not a slice) and with the paged pool (pages
    # are indirected, not sliceable) — fuse_append_applicable() excludes
    # the overlap.
    slot_offset = 0
    if (not contiguous and not fused and not paged
            and (backend == "ref" or not prune)):
        sl = _window_slice(total_len, rank, s_loc, kvp=kvp,
                           rr_block=rr_block, window=window)
        if sl is not None:
            j_lo, w_loc = sl
            k = jax.lax.dynamic_slice_in_dim(k, j_lo, w_loc, axis=2)
            v = jax.lax.dynamic_slice_in_dim(v, j_lo, w_loc, axis=2)
            if kscale is not None:
                kscale = jax.lax.dynamic_slice_in_dim(
                    kscale, j_lo, w_loc, axis=2)
                vscale = jax.lax.dynamic_slice_in_dim(
                    vscale, j_lo, w_loc, axis=2)
            slot_offset = j_lo
    if backend != "ref":
        return flash_decode(q, k, v, total_len, rank, kvp=kvp,
                            rr_block=rr_block, window=window,
                            contiguous=contiguous, slot_offset=slot_offset,
                            kscale=kscale, vscale=vscale,
                            k_new=k_new, v_new=v_new, prune=prune,
                            block_tables=block_tables, block_s=block_s,
                            groups=groups, interpret=backend != "pallas")
    # ---- pure-JAX reference path ----
    if contiguous:
        # positions rank*s_loc + j: with kvp=1 the round-robin formula
        # degenerates to pos = slot_offset + j, so the contiguous layout is
        # the ref with a rank-sized slot offset (window stays honoured).
        return flash_decode_ref(q, k, v, total_len, 0, kvp=1,
                                rr_block=rr_block, window=window,
                                slot_offset=rank * s_loc,
                                kscale=kscale, vscale=vscale)
    return flash_decode_ref(q, k, v, total_len, rank, kvp=kvp,
                            rr_block=rr_block, window=window,
                            slot_offset=slot_offset,
                            kscale=kscale, vscale=vscale)


def helix_attention(mesh: Mesh, hx: HelixConfig, q, kcache, vcache, total_len,
                    *, window: int | jax.Array = 0, contiguous: bool = False,
                    hopb_chunks: int = 1, kscale=None, vscale=None,
                    k_new=None, v_new=None, block_tables=None, groups=None):
    """Exact sharded decode attention.

    Args:
      q:            [B, Qh, hsz] global (replicated over kvp, heads over tpa).
      kcache/vcache:[B, Kh, S_cap, hsz] global; S_cap sharded over kvp axes,
                    heads over tpa axis (round-robin slot layout).
      total_len:    scalar or [B] int32 — global sequence length(s).
      window:       sliding window (0 = full); may be traced (gemma3 scan).
      hopb_chunks:  HOP-B (§2.1.3): split the batch into this many
                    independent chunks so XLA's latency-hiding scheduler can
                    overlap chunk i's all-to-all with chunk i+1's attention
                    compute (TPU-idiomatic equivalent of stream overlap).
      k_new/v_new:  [B, Kh, hsz] — fused KV-append epilogue: the new token's
                    K/V row is written into the cache *inside* the decode
                    kernel (its owner rank's shard), replacing the separate
                    ``append_kv`` pass.  With an int8 cache (kscale/vscale
                    given) the kernel quantizes the row in-kernel and also
                    returns the updated scales.  Pass the pre-append caches
                    and a ``total_len`` that already counts the new token;
                    the caller must have checked ``fuse_append_applicable``.
      block_tables: [B, max_pages] int32 — shared-pool *paged* mode:
                    kcache/vcache are pool planes ``[n_blocks, Kh, block_s,
                    hsz]`` (scales ``[n_blocks, Kh, block_s]``) whose
                    block_s axis shards over the kvp axes exactly like the
                    fixed layout's slot axis — each rank holds block_s/KVP
                    rows of every page, its round-robin local slots for
                    that page (core/kvcache.py paged layout).  The table is
                    replicated; per-rank attention streams pages through
                    it.  Fused append composes (the kernel writes the new
                    row's page through the table).
      groups:       (group_id [B], group_np [B]) int32 — grouped shared-
                    prefix decode (paged mode): requests whose tables share
                    their leading ``group_np`` pages stream each shared page
                    once per group (kernels/flash_decode ``groups``).  Both
                    arrays are replicated; the ref backend ignores them
                    (grouping is bit-exact, so the oracle doesn't need
                    them).  Forces ``hopb_chunks=1`` — groups span the
                    whole batch, chunking would split them.

    Returns: [B, Qh*hsz] attention output, sharded over (tpa, kvp) on dim 1 —
    exactly the TP layout the post-attention projection consumes (§2.2).
    In fused-append mode returns ``(out, kcache, vcache)`` with the appended
    caches (same global layout/sharding as the inputs — whole pool planes in
    paged mode), plus ``(kscale, vscale)`` for int8 caches.
    """
    import math
    b, qh, hsz = q.shape
    kvp_axes = hx.kvp_axes
    tpa = hx.tpa_axis
    kvp = math.prod(mesh.shape[a] for a in kvp_axes)
    qh_local = qh // (mesh.shape[tpa] if tpa else 1)
    fused = k_new is not None
    paged = block_tables is not None
    grouped = groups is not None
    assert not fused or not contiguous
    assert not (paged and contiguous)
    assert not grouped or paged, "grouped decode requires the paged pool"
    if grouped:
        hopb_chunks = 1        # groups span the batch; chunks would split them
    # The all-to-all splits the flattened (Qh_local*hsz) dim into KVP slices.
    # When it does not divide (e.g. hymba q_dim=1600, N=256) we zero-pad the
    # flat dim only — attention itself runs the canonical heads; pad elements
    # carry clamped head indices so combine weights hit zeros (exact).  The
    # caller pads the out-projection rows to match (helix_out_dim).
    d_flat = qh_local * hsz
    d_pad = round_up(d_flat, kvp)
    if d_pad != d_flat:
        assert tpa is None, "flat-dim padding only supported in pure-KVP mode"
    sl = d_pad // kvp
    flat_heads = jnp.minimum(jnp.arange(d_pad, dtype=jnp.int32) // hsz,
                             qh_local - 1)
    head_idx_table = flat_heads.reshape(kvp, sl)          # [KVP, sl]

    def local_fn(q_l, k_l, v_l, tl, *extras):
        rank = jax.lax.axis_index(kvp_axes)
        ks_l = vs_l = kn_l = vn_l = tbl_l = grp_l = None
        if kscale is not None:
            ks_l, vs_l, extras = extras[0], extras[1], extras[2:]
        if fused:
            kn_l, vn_l, extras = extras[0], extras[1], extras[2:]
        if paged:
            tbl_l, extras = extras[0], extras[1:]
        if grouped:
            grp_l = (extras[0], extras[1])
        res = _local_attend(q_l, k_l, v_l, tl, rank, kvp=kvp,
                            rr_block=hx.rr_block, window=window,
                            contiguous=contiguous,
                            kscale=ks_l, vscale=vs_l,
                            backend=hx.attn_backend,
                            k_new=kn_l, v_new=vn_l,
                            prune=hx.prune_blocks,
                            block_tables=tbl_l,
                            block_s=hx.attn_block_s,
                            groups=grp_l)
        out, lse = res[0], res[1]
        bl = out.shape[0]
        # single all-to-all over the query-head axis (§2.1.2): volume B×H/TPA,
        # independent of S.
        flat = out.reshape(bl, d_flat)
        if d_pad != d_flat:
            flat = jnp.pad(flat, ((0, 0), (0, d_pad - d_flat)))
        frags = flat.reshape(bl, kvp, sl).transpose(1, 0, 2)  # [KVP, B, sl]
        frags = jax.lax.all_to_all(frags, kvp_axes, split_axis=0,
                                   concat_axis=0, tiled=False)
        lses = jax.lax.all_gather(lse, kvp_axes, axis=0, tiled=False)
        my_slice = jax.lax.dynamic_index_in_dim(
            head_idx_table, rank, axis=0, keepdims=False)
        combined = combine_fragments(frags, lses, my_slice)   # [B, sl]
        if fused:
            # + appended local KV shards (and updated scales for int8)
            return (combined,) + tuple(res[2:])
        return combined

    tl_spec = P() if jnp.ndim(total_len) == 0 else P(None)
    quant = kscale is not None
    # fixed layout: cache [B, Kh, S_cap, hsz], slot axis over kvp; paged:
    # pool [n_blocks, Kh, block_s, hsz], the page's block_s axis over kvp —
    # the *same* spec, by construction of the paged layout
    cache_spec = P(None, tpa, kvp_axes, None)
    in_specs = (P(None, tpa, None),                       # q: repl over kvp
                cache_spec,                               # kcache
                cache_spec,                               # vcache
                tl_spec)
    if quant:
        in_specs += (P(None, tpa, kvp_axes), P(None, tpa, kvp_axes))
    if fused:
        in_specs += (P(None, tpa, None), P(None, tpa, None))  # k_new, v_new
    if paged:
        in_specs += (P(None, None),)                      # tables: replicated
    if grouped:
        in_specs += (P(None), P(None))                    # group_id, group_np
    out_spec = P(None, ((tpa,) if tpa else ()) + kvp_axes)
    scale_spec = P(None, tpa, kvp_axes)
    if fused:
        out_specs = (out_spec, cache_spec, cache_spec)
        if quant:
            out_specs += (scale_spec, scale_spec)
    else:
        out_specs = out_spec
    shard_fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs,
        out_specs=out_specs, check_vma=False)

    def call(qs, ks, vs, tl, kss, vss, kns, vns, tbl):
        args = (qs, ks, vs, tl)
        if quant:
            args += (kss, vss)
        if fused:
            args += (kns, vns)
        if paged:
            args += (tbl,)
        if grouped:
            args += (jnp.asarray(groups[0], jnp.int32),
                     jnp.asarray(groups[1], jnp.int32))
        return shard_fn(*args)

    if hopb_chunks <= 1:
        return call(q, kcache, vcache, total_len, kscale, vscale,
                    k_new, v_new, block_tables)

    # ---- HOP-B: batch-wise communication/computation overlap (§2.1.3) ----
    assert b % hopb_chunks == 0, (b, hopb_chunks)
    bc = b // hopb_chunks
    outs = []
    # paged pool planes carry no batch axis: every chunk sees the whole
    # pool (its table rows select its pages).  In fused mode the appended
    # pool must thread chunk-to-chunk — that serializes the cache writes,
    # but the attention/all-to-all overlap HOP-B exists for is unaffected.
    kc_cur, vc_cur, ks_cur, vs_cur = kcache, vcache, kscale, vscale
    for i in range(hopb_chunks):
        csl = slice(i * bc, (i + 1) * bc)
        tl_i = total_len if jnp.ndim(total_len) == 0 else total_len[csl]
        res = call(q[csl],
                   kc_cur if paged else kc_cur[csl],
                   vc_cur if paged else vc_cur[csl], tl_i,
                   (ks_cur if paged else ks_cur[csl]) if quant else None,
                   (vs_cur if paged else vs_cur[csl]) if quant else None,
                   k_new[csl] if fused else None,
                   v_new[csl] if fused else None,
                   block_tables[csl] if paged else None)
        if fused and paged:
            outs.append(res[0])
            kc_cur, vc_cur = res[1], res[2]
            if quant:
                ks_cur, vs_cur = res[3], res[4]
        else:
            outs.append(res)
    if fused and paged:
        out = jnp.concatenate(outs, axis=0)
        if quant:
            return out, kc_cur, vc_cur, ks_cur, vs_cur
        return out, kc_cur, vc_cur
    if fused:
        return tuple(jnp.concatenate([o[i] for o in outs], axis=0)
                     for i in range(len(outs[0])))
    return jnp.concatenate(outs, axis=0)


def paged_slot_of_position(pos, block_tables, *, kvp: int, rr_block: int,
                           block_s: int):
    """(physical page [B], in-page row [B]) holding global position ``pos``.

    The paged twin of ``rr_slot_of_position``: position ``pos`` lives on
    rank ``r = (pos//rr) % KVP`` at local slot ``j``, i.e. logical page
    ``j // ps_loc`` at in-page row ``r*ps_loc + j % ps_loc`` (``ps_loc =
    block_s/KVP`` — the page's block_s axis is rank-major).  Negative
    positions (idle engine rows) clamp to logical page 0, whose table entry
    is the reserved sink page."""
    pos = jnp.asarray(pos, jnp.int32)
    ps_loc = block_s // kvp
    blk = pos // rr_block
    rank = blk % kvp
    j = (blk // kvp) * rr_block + pos % rr_block
    page = jnp.clip(j // ps_loc, 0, block_tables.shape[1] - 1)
    row = rank * ps_loc + j % ps_loc
    b = block_tables.shape[0]
    phys = block_tables[jnp.arange(b), jnp.broadcast_to(page, (b,))]
    return phys, jnp.broadcast_to(row, (b,))


def append_kv(kcache, vcache, k_new, v_new, total_len, *, kvp: int,
              rr_block: int, block_tables=None):
    """Round-robin KV concatenation (§2.3), GSPMD-compatible.

    kcache [B, Kh, S_cap, hsz] (S_cap = KVP * S_loc, round-robin layout);
    k_new [B, Kh, hsz] for the token at position total_len - 1.  total_len
    may be scalar (uniform batch: dynamic-update-slice) or [B] (continuous
    batching: per-request scatter).

    Paged mode (``block_tables`` [B, max_pages]): kcache/vcache are pool
    planes ``[n_blocks, Kh, block_s, hsz]`` and the row scatters into the
    physical page the table names for the token's logical page
    (``paged_slot_of_position``); idle rows (total_len 0) land on the
    reserved sink page 0.
    """
    if block_tables is not None:
        phys, row = paged_slot_of_position(
            total_len - 1, block_tables, kvp=kvp, rr_block=rr_block,
            block_s=kcache.shape[2])
        kcache = kcache.at[phys, :, row, :].set(k_new.astype(kcache.dtype))
        vcache = vcache.at[phys, :, row, :].set(v_new.astype(vcache.dtype))
        return kcache, vcache
    s_cap = kcache.shape[2]
    s_loc = s_cap // kvp
    pos = total_len - 1
    slot = rr_slot_of_position(pos, kvp, s_loc, rr_block)
    if jnp.ndim(total_len) == 0:
        k_new = k_new[:, :, None, :].astype(kcache.dtype)
        v_new = v_new[:, :, None, :].astype(vcache.dtype)
        kcache = jax.lax.dynamic_update_slice(kcache, k_new, (0, 0, slot, 0))
        vcache = jax.lax.dynamic_update_slice(vcache, v_new, (0, 0, slot, 0))
        return kcache, vcache
    b = kcache.shape[0]
    rows = jnp.arange(b)
    kcache = kcache.at[rows, :, slot, :].set(k_new.astype(kcache.dtype))
    vcache = vcache.at[rows, :, slot, :].set(v_new.astype(vcache.dtype))
    return kcache, vcache


def quantize_kv_token(x):
    """[B, Kh, hsz] -> (int8 [B, Kh, hsz], scale f32 [B, Kh]) symmetric."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def append_kv_quant(kcache, vcache, kscale, vscale, k_new, v_new, total_len,
                    *, kvp: int, rr_block: int, block_tables=None):
    """int8 round-robin KV append: quantize the new token per (B, Kh) and
    write payload + scale at its round-robin slot (§2.3 + §Perf kv8).
    Paged mode (``block_tables``): the payload/scale scatter goes through
    the block table into the pool planes, like ``append_kv``."""
    kq, ks = quantize_kv_token(k_new)
    vq, vs = quantize_kv_token(v_new)
    kcache, vcache = append_kv(kcache, vcache, kq, vq, total_len, kvp=kvp,
                               rr_block=rr_block, block_tables=block_tables)
    if block_tables is not None:
        phys, row = paged_slot_of_position(
            total_len - 1, block_tables, kvp=kvp, rr_block=rr_block,
            block_s=kcache.shape[2])
        kscale = kscale.at[phys, :, row].set(ks.astype(kscale.dtype))
        vscale = vscale.at[phys, :, row].set(vs.astype(vscale.dtype))
        return kcache, vcache, kscale, vscale
    s_loc = kcache.shape[2] // kvp
    slot = rr_slot_of_position(total_len - 1, kvp, s_loc, rr_block)
    if jnp.ndim(total_len) == 0:
        kscale = jax.lax.dynamic_update_slice(
            kscale, ks[:, :, None].astype(kscale.dtype), (0, 0, slot))
        vscale = jax.lax.dynamic_update_slice(
            vscale, vs[:, :, None].astype(vscale.dtype), (0, 0, slot))
    else:
        rows = jnp.arange(kcache.shape[0])
        kscale = kscale.at[rows, :, slot].set(ks.astype(kscale.dtype))
        vscale = vscale.at[rows, :, slot].set(vs.astype(vscale.dtype))
    return kcache, vcache, kscale, vscale


def prefill_to_rr_layout(cache, kvp: int, rr_block: int):
    """[B, Kh, S, hsz] contiguous-position cache -> round-robin slot layout.

    S must be a multiple of kvp*rr_block.  Pure reshape/transpose: block b of
    rr_block positions goes to rank b % kvp, local block b // kvp.
    """
    b, kh, s, hsz = cache.shape
    nblk = s // rr_block
    assert nblk % kvp == 0, (s, kvp, rr_block)
    c = cache.reshape(b, kh, nblk // kvp, kvp, rr_block, hsz)
    c = c.transpose(0, 1, 3, 2, 4, 5)          # [B,Kh,KVP,nloc,rr,hsz]
    return c.reshape(b, kh, s, hsz)
