"""Exact attention-partial combine (flash-decoding / Helix §2.1.1 math).

Each KV-parallel (KVP) rank computes attention of the full query batch against
its *local* KV shard, emitting a partial un-normalized-softmax output together
with the per-(token, head) log-sum-exp (LSE).  The exact softmax attention over
the union of shards is the LSE-weighted sum of the partials:

    LSE    = logsumexp_r(lse_r)
    out    = sum_r exp(lse_r - LSE) * out_r

This module implements that combine in f32, with empty-shard (-inf LSE) safety,
in three forms:

  * ``combine_partials``      — stacked partials  [R, ..., Q, hsz]
  * ``combine_two``           — binary (associative) form, for tree reduction
  * ``combine_fragments``     — the post-all-to-all form used by Helix, where
    the flattened head dim ``D = Q*hsz`` has been split into per-rank slices
    that may straddle head boundaries; weights are expanded per-element via a
    static head-index lookup so any divisible split is exact.

All math is done in float32 regardless of input dtype; outputs are cast back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import NEG_INF


def _safe_weights(lses: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Softmax over the leading (shard) axis of stacked LSEs, -inf safe.

    Returns (weights [R, ...], total_lse [...]).
    """
    lses = lses.astype(jnp.float32)
    m = jnp.max(lses, axis=0)
    # If every shard is empty (all -inf), avoid NaN: weights -> 0.
    m_safe = jnp.where(m <= NEG_INF, 0.0, m)
    unnorm = jnp.exp(lses - m_safe)
    denom = jnp.sum(unnorm, axis=0)
    weights = unnorm / jnp.maximum(denom, 1e-37)
    total = m_safe + jnp.log(jnp.maximum(denom, 1e-37))
    total = jnp.where(m <= NEG_INF, NEG_INF, total)
    return weights, total


def combine_partials(outs: jax.Array, lses: jax.Array):
    """Combine stacked partial attention outputs.

    Args:
      outs: [R, ..., Q, hsz] partial outputs (already softmax-normalized
        *within* each shard, i.e. out_r = softmax_r(scores) @ V_r).
      lses: [R, ..., Q] log-sum-exp of each shard's scores.

    Returns:
      (out [..., Q, hsz], lse [..., Q])
    """
    weights, total = _safe_weights(lses)
    out = jnp.sum(outs.astype(jnp.float32) * weights[..., None], axis=0)
    return out.astype(outs.dtype), total


def combine_two(out_a, lse_a, out_b, lse_b):
    """Binary combine; associative and commutative (up to fp rounding)."""
    outs = jnp.stack([out_a, out_b])
    lses = jnp.stack([lse_a, lse_b])
    out, lse = combine_partials(outs, lses)
    return out, lse


def fragment_head_index(q_heads: int, hsz: int, num_slices: int) -> jnp.ndarray:
    """Static [num_slices, D/num_slices] head index for flattened (Q*hsz) dim.

    Slice s covers flat elements [s*sl, (s+1)*sl); element e belongs to head
    e // hsz.  Used to expand per-head combine weights to per-element weights
    when an all-to-all slices the flattened head dim.
    """
    d = q_heads * hsz
    assert d % num_slices == 0, (q_heads, hsz, num_slices)
    sl = d // num_slices
    flat = jnp.arange(d, dtype=jnp.int32) // hsz
    return flat.reshape(num_slices, sl)


def combine_fragments(frags: jax.Array, lses: jax.Array, head_idx: jax.Array):
    """Combine post-all-to-all fragments for one destination rank.

    Args:
      frags: [R, B, sl] — partial outputs for this rank's flat slice of the
        (Q*hsz) dim, one per source KVP rank.
      lses:  [R, B, Q] — all-gathered LSEs (full head set, tiny).
      head_idx: [sl] int32 — head owning each flat element of this slice
        (one row of ``fragment_head_index``).

    Returns:
      combined [B, sl] in frags.dtype.
    """
    weights, _ = _safe_weights(lses)            # [R, B, Q] f32
    w_elem = weights[:, :, head_idx]            # [R, B, sl]
    out = jnp.sum(frags.astype(jnp.float32) * w_elem, axis=0)
    return out.astype(frags.dtype)
