"""Distributed decode state: round-robin KV caches (§2.3), SSM states,
whisper cross-attention KV — plus their PartitionSpecs and dry-run stand-ins.
"""
from __future__ import annotations

from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import HelixConfig
from repro.utils import round_up


def cache_capacity(cfg_seq_len: int, kvp: int, rr_block: int) -> int:
    """Smallest valid cache capacity >= seq_len (multiple of kvp*rr)."""
    return round_up(cfg_seq_len, kvp * rr_block)


# ----------------------------------------------------------- paged layout
#
# Shared-pool paged KV cache (serving/pool.py, docs/serving.md): instead of
# one fixed [B, Kh, S_cap, hsz] row per slot, K/V live in pool planes
#
#     [L, n_blocks, Kh, block_s, hsz]        (+ [L, n_blocks, Kh, block_s]
#                                             f32 scale planes for kv8)
#
# where one *page* holds ``block_s`` consecutive global positions
# [i*block_s, (i+1)*block_s) of whichever request owns it, and a per-request
# ``[B, max_pages]`` block table maps logical page i -> physical pool plane.
# Under KVP sharding the page's block_s axis splits over the kvp axes —
# rank r holds rows [r*ps_loc, (r+1)*ps_loc) with ps_loc = block_s/kvp,
# which are exactly its round-robin local slots [i*ps_loc, (i+1)*ps_loc)
# (requires ps_loc % rr_block == 0, i.e. block_s a multiple of
# kvp*rr_block).  The paged pool is therefore a page-granularity
# *permutation* of the fixed layout: with identity tables the two layouts
# are reshapes of each other, which is what makes paged-vs-fixed bit-exact
# parity provable (tests/kernels/test_flash_decode_paged.py).
# Page 0 is the reserved sink for idle-row appends (serving/pool.py).


def page_positions(kvp: int, rr_block: int) -> int:
    """Global positions per pool page: the smallest legal page (one
    round-robin cycle, ``kvp * rr_block``) — each KVP rank then holds
    ``rr_block`` rows of every page.  Matching the decode kernel's S-block
    size (``HelixConfig.attn_block_s``) to ``rr_block`` aligns the paged
    and fixed online-softmax block partitions, making the two layouts
    bit-identical end to end."""
    return kvp * rr_block


def cache_to_pages(row, kvp: int, block_s: int):
    """One request's fixed-layout cache -> its page stack.

    ``row`` is ``[L, Kh, S_cap, ...]`` in the *global* rank-major
    round-robin layout (slot ``r*S_loc + j``); returns
    ``[L, P, Kh, block_s, ...]`` with ``P = S_cap_padded / block_s`` pages
    whose in-page row ``r*ps_loc + jj`` holds rank ``r``'s local slot
    ``i*ps_loc + jj`` — the paged pool layout documented above.  Works for
    K/V payloads (trailing hsz) and scale planes (no trailing axis)."""
    l, kh, s_cap = row.shape[:3]
    trail = row.shape[3:]
    ps_loc = block_s // kvp
    s_pad = round_up(s_cap, block_s)
    if s_pad != s_cap:
        pad = [(0, 0)] * row.ndim
        pad[2] = (0, s_pad - s_cap)
        row = jnp.pad(row, pad)
    p = s_pad // block_s
    r = row.reshape(l, kh, kvp, p, ps_loc, *trail)
    r = jnp.moveaxis(r, 3, 1)                       # [L, P, Kh, kvp, ps, ...]
    return r.reshape(l, p, kh, block_s, *trail)


def pages_to_cache(pages, kvp: int):
    """Inverse of ``cache_to_pages``: ``[L, P, Kh, block_s, ...]`` page
    stack -> ``[L, Kh, P*block_s, ...]`` fixed rank-major round-robin
    cache."""
    l, p, kh, block_s = pages.shape[:4]
    trail = pages.shape[4:]
    ps_loc = block_s // kvp
    r = pages.reshape(l, p, kh, kvp, ps_loc, *trail)
    r = jnp.moveaxis(r, 1, 3)                       # [L, Kh, kvp, P, ps, ...]
    return r.reshape(l, kh, p * block_s, *trail)


def gather_pages(pool, tables):
    """Dense per-request view of a pool plane (the ref-backend oracle path).

    ``pool`` ``[n_blocks, Kh, block_s, ...]`` + ``tables`` ``[B, max_pages]``
    -> ``[B, Kh, max_pages*block_s, ...]`` fixed-layout local caches (one
    gather; the Pallas kernels do this lazily through their index_maps
    instead)."""
    b, mp = tables.shape
    g = pool[tables]                                # [B, MP, Kh, bs, ...]
    g = jnp.moveaxis(g, 2, 1)                       # [B, Kh, MP, bs, ...]
    return g.reshape(b, pool.shape[1], mp * pool.shape[2], *pool.shape[3:])


def gather_pool_pages(state: dict[str, Any], phys) -> dict[str, jax.Array]:
    """Device-side page-stack gather for the host spill path.

    Selects the physical pages ``phys`` (logical-page order) out of every
    pool plane present in ``state`` — K/V payloads and, on kv8 engines,
    the f32 scale planes — as ``[L, P, ...]`` stacks.  One gather per
    plane; the caller performs the single batched device->host transfer
    (serving/engine.py's sanctioned spill site), so the exact pool bytes
    (int8 payloads + scales included) round-trip through the host tier."""
    idx = jnp.asarray(phys, jnp.int32)
    return {key: state[key][:, idx]
            for key in ("kcache", "vcache", "kscale", "vscale")
            if key in state}


def scatter_pool_pages(state: dict[str, Any], phys,
                       planes: dict[str, Any]) -> dict[str, Any]:
    """Inverse of ``gather_pool_pages``: H2D restore of spilled pages.

    Writes each plane's ``[L, P, ...]`` page stack back into the pool at
    the physical pages ``phys`` (freshly granted at re-admission — the
    original tenancy is gone).  Returns a copy of ``state`` with the pool
    planes updated; bytes land exactly as spilled, which is what makes a
    spill/restore resume bit-exact with never having been preempted."""
    out = dict(state)
    idx = jnp.asarray(phys, jnp.int32)
    for key, stack in planes.items():
        out[key] = state[key].at[:, idx].set(
            jnp.asarray(stack, state[key].dtype))
    return out


def state_to_paged(state: dict[str, Any], tables, n_blocks: int, kvp: int,
                   block_s: int) -> dict[str, Any]:
    """Fixed-cap decode state -> the equivalent paged state (test helper).

    Scatters every slot's cache rows into the pool planes at the physical
    pages named by ``tables`` ([B, max_pages] int32; entry 0 = sink) and
    adds ``block_tables`` to the state.  Pages beyond a row's table extent
    must be 0 in ``tables``; slot data beyond the table extent is dropped
    (it must be dead).  Non-attention leaves pass through."""
    out = dict(state)
    out["block_tables"] = jnp.asarray(tables, jnp.int32)
    for key in ("kcache", "vcache", "kscale", "vscale"):
        if key not in state:
            continue
        plane = state[key]                          # [L, B, Kh, S_cap, ...]
        l, b = plane.shape[:2]
        trail = plane.shape[4:] if plane.ndim > 4 else ()
        pool = jnp.zeros((l, n_blocks, plane.shape[2], block_s) + trail,
                         plane.dtype)
        for i in range(b):
            pages = cache_to_pages(plane[:, i], kvp, block_s)
            phys = np.asarray(tables[i])
            live = phys > 0
            idx = np.nonzero(live)[0]
            idx = idx[idx < pages.shape[1]]
            if idx.size:
                pool = pool.at[:, phys[idx]].set(pages[:, idx])
        out[key] = pool
    return out


def sampling_leaf_shapes(batch: int) -> dict[str, Any]:
    """ShapeDtypeStructs for the on-device sampling leaves (one value per
    batch row, carried in the decode state so the sampler epilogue stays a
    pure function of ``(params, state, tokens)``): ``sample_temp``/
    ``sample_topp`` f32, ``sample_topk`` i32, ``sample_seed`` u32 (the
    per-request PRNG seed) and ``sample_idx`` i32 (tokens sampled so far —
    the ``fold_in`` counter; see serving/sampling.py).  Presence of
    ``sample_seed`` in a state is what switches ``serve_step`` from the
    argmax epilogue to the sampler."""
    b = (batch,)
    return {"sample_temp": jax.ShapeDtypeStruct(b, jnp.float32),
            "sample_topk": jax.ShapeDtypeStruct(b, jnp.int32),
            "sample_topp": jax.ShapeDtypeStruct(b, jnp.float32),
            "sample_seed": jax.ShapeDtypeStruct(b, jnp.uint32),
            "sample_idx": jax.ShapeDtypeStruct(b, jnp.int32)}


def decode_state_shapes(cfg: ArchConfig, batch: int, seq_len: int,
                        kvp: int, rr_block: int = 16,
                        dtype=jnp.bfloat16, kv_bits: int = 16,
                        pool_blocks: int = 0,
                        max_pages: int = 0,
                        grouped: bool = False,
                        sampling: bool = False) -> dict[str, Any]:
    """ShapeDtypeStructs for every decode-state leaf (dry-run input_specs).

    ``pool_blocks > 0`` switches the attention K/V leaves to the shared-pool
    *paged* layout (see the paged-layout block above): pool planes
    ``[L, pool_blocks, Kh, block_s, hsz]`` with ``block_s =
    page_positions(kvp, rr_block)``, plus a ``block_tables``
    ``[batch, max_pages]`` int32 leaf (``max_pages`` defaults to
    ``pool_blocks`` — any request may take the whole pool).  ``grouped``
    (paged only) adds the grouped shared-prefix decode's ``group_id``/
    ``group_np`` ``[batch]`` int32 leaves (``HelixConfig.grouped_decode``;
    the serving engine recomputes them each step).  ``sampling`` adds the
    per-row on-device sampling leaves (``sampling_leaf_shapes``)."""
    s: dict[str, Any] = {"total_len": jax.ShapeDtypeStruct((), jnp.int32)}
    if sampling:
        s.update(sampling_leaf_shapes(batch))
    L = cfg.n_layers
    if cfg.has_attention:
        kv_dtype = jnp.int8 if kv_bits == 8 else dtype
        if pool_blocks > 0:
            bs = page_positions(kvp, rr_block)
            mp = max_pages or pool_blocks
            kv = jax.ShapeDtypeStruct(
                (L, pool_blocks, cfg.n_kv_heads, bs, cfg.hsz), kv_dtype)
            s["kcache"], s["vcache"] = kv, kv
            s["block_tables"] = jax.ShapeDtypeStruct((batch, mp), jnp.int32)
            if grouped:
                gi = jax.ShapeDtypeStruct((batch,), jnp.int32)
                s["group_id"], s["group_np"] = gi, gi
            if kv_bits == 8:
                sc = jax.ShapeDtypeStruct(
                    (L, pool_blocks, cfg.n_kv_heads, bs), jnp.float32)
                s["kscale"], s["vscale"] = sc, sc
        else:
            cap = cache_capacity(seq_len, kvp, rr_block)
            kv = jax.ShapeDtypeStruct(
                (L, batch, cfg.n_kv_heads, cap, cfg.hsz), kv_dtype)
            s["kcache"], s["vcache"] = kv, kv
            if kv_bits == 8:
                sc = jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, cap),
                                          jnp.float32)
                s["kscale"], s["vscale"] = sc, sc
    if cfg.has_ssm:
        s["ssm_conv"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.conv_dim, cfg.ssm_conv - 1), jnp.float32)
        s["ssm_state"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32)
    if cfg.is_encdec:
        s_enc = round_up(seq_len, kvp)
        xkv = jax.ShapeDtypeStruct(
            (L, batch, cfg.n_kv_heads, s_enc, cfg.hsz), dtype)
        s["xk"], s["xv"] = xkv, xkv
        s["enc_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return s


def decode_state_specs(cfg: ArchConfig, hx: HelixConfig,
                       batch: int | None = None,
                       mesh=None, sampling: bool = False) -> dict[str, Any]:
    """PartitionSpecs matching decode_state_shapes.

    The paged pool planes ``[L, n_blocks, Kh, block_s, hsz]`` reuse the
    fixed layout's spec: the sequence-ish axis (dim 3 — ``block_s`` for
    paged, ``S_cap`` for fixed) shards over the kvp axes, heads over tpa.
    ``block_tables`` is replicated (tiny int32), as are the ``sampling``
    leaves (per-row scalars)."""
    tpa, kvp = hx.tpa_axis, hx.kvp_axes
    s: dict[str, Any] = {"total_len": P()}
    if sampling:
        for key in sampling_leaf_shapes(1):
            s[key] = P(None)
    if cfg.has_attention:
        s["kcache"] = s["vcache"] = P(None, None, tpa, kvp, None)
        if hx.paged_kv:
            s["block_tables"] = P(None, None)
            if hx.grouped_decode:
                s["group_id"] = s["group_np"] = P(None)
        if hx.kv_cache_bits == 8:
            s["kscale"] = s["vscale"] = P(None, None, tpa, kvp)
    if cfg.has_ssm:
        # batch over 'data' (when divisible), ssm heads/channels over 'model'
        dsz = mesh.shape["data"] if mesh else 1
        msz = mesh.shape["model"] if mesh else 1
        bax = "data" if (batch is None or batch % dsz == 0) else None
        hax = "model" if cfg.ssm_heads % msz == 0 else None
        cax = "model" if cfg.conv_dim % msz == 0 else None
        s["ssm_conv"] = P(None, bax, cax, None)
        s["ssm_state"] = P(None, bax, hax, None, None)
    if cfg.is_encdec:
        s["xk"] = s["xv"] = P(None, None, tpa, kvp, None)
        s["enc_len"] = P()
    return s


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int, kvp: int,
                      rr_block: int = 16, dtype=jnp.bfloat16,
                      total_len: int | jax.Array = 0,
                      kv_bits: int = 16, pool_blocks: int = 0,
                      max_pages: int = 0,
                      grouped: bool = False,
                      sampling: bool = False) -> dict[str, Any]:
    """Zero-initialised decode state (concrete arrays, small/test use).

    ``kv_bits=8`` allocates int8 K/V payloads plus per-slot f32 scale
    planes (``kscale``/``vscale``).  ``pool_blocks > 0`` allocates the
    shared-pool *paged* layout instead (pool planes + zeroed
    ``block_tables`` — every row starts parked on the sink page 0).
    ``grouped`` adds zeroed ``group_id``/``group_np`` leaves (all rows
    singleton groups under group 0 with no shared prefix, which decodes
    identically to ungrouped).  ``sampling`` adds zeroed per-row sampling
    leaves (all rows greedy — temp 0 — until the engine installs a
    request's policy at commit/restore time)."""
    shapes = decode_state_shapes(cfg, batch, seq_len, kvp, rr_block, dtype,
                                 kv_bits=kv_bits, pool_blocks=pool_blocks,
                                 max_pages=max_pages, grouped=grouped,
                                 sampling=sampling)
    state = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
    tl = jnp.asarray(total_len, jnp.int32)
    state["total_len"] = tl
    return state


def quantize_decode_state(state: dict[str, Any]) -> dict[str, Any]:
    """fp round-robin K/V caches -> int8 payloads + per-slot f32 scales.

    Per-(…, slot) symmetric quantization over the ``hsz`` axis with the
    same formula as ``core/helix.quantize_kv_token`` (the decode-step
    append), so a prefilled-then-quantized cache and a cache grown token by
    token agree on shared slots.  Layout-agnostic: the reduction runs over
    the trailing ``hsz`` axis, so fixed-cap ``[L, B, Kh, S, hsz]`` caches
    and paged pool planes ``[L, n_blocks, Kh, block_s, hsz]`` both work
    (scale planes come back one axis shorter).  Zero (unfilled) slots
    quantize to zero payloads with the epsilon scale.  Returns a copy of
    ``state`` with ``kcache``/``vcache`` replaced and ``kscale``/``vscale``
    added; other leaves pass through."""
    out = dict(state)
    for key, skey in (("kcache", "kscale"), ("vcache", "vscale")):
        c = state[key].astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(c), axis=-1) / 127.0, 1e-30)
        out[key] = jnp.clip(jnp.round(c / scale[..., None]),
                            -127, 127).astype(jnp.int8)
        out[skey] = scale
    return out
