"""Distributed decode state: round-robin KV caches (§2.3), SSM states,
whisper cross-attention KV — plus their PartitionSpecs and dry-run stand-ins.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.sharding import HelixConfig
from repro.utils import round_up


def cache_capacity(cfg_seq_len: int, kvp: int, rr_block: int) -> int:
    """Smallest valid cache capacity >= seq_len (multiple of kvp*rr)."""
    return round_up(cfg_seq_len, kvp * rr_block)


def decode_state_shapes(cfg: ArchConfig, batch: int, seq_len: int,
                        kvp: int, rr_block: int = 16,
                        dtype=jnp.bfloat16, kv_bits: int = 16) -> dict[str, Any]:
    """ShapeDtypeStructs for every decode-state leaf (dry-run input_specs)."""
    s: dict[str, Any] = {"total_len": jax.ShapeDtypeStruct((), jnp.int32)}
    L = cfg.n_layers
    if cfg.has_attention:
        cap = cache_capacity(seq_len, kvp, rr_block)
        kv_dtype = jnp.int8 if kv_bits == 8 else dtype
        kv = jax.ShapeDtypeStruct(
            (L, batch, cfg.n_kv_heads, cap, cfg.hsz), kv_dtype)
        s["kcache"], s["vcache"] = kv, kv
        if kv_bits == 8:
            sc = jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, cap),
                                      jnp.float32)
            s["kscale"], s["vscale"] = sc, sc
    if cfg.has_ssm:
        s["ssm_conv"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.conv_dim, cfg.ssm_conv - 1), jnp.float32)
        s["ssm_state"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
            jnp.float32)
    if cfg.is_encdec:
        s_enc = round_up(seq_len, kvp)
        xkv = jax.ShapeDtypeStruct(
            (L, batch, cfg.n_kv_heads, s_enc, cfg.hsz), dtype)
        s["xk"], s["xv"] = xkv, xkv
        s["enc_len"] = jax.ShapeDtypeStruct((), jnp.int32)
    return s


def decode_state_specs(cfg: ArchConfig, hx: HelixConfig,
                       batch: int | None = None,
                       mesh=None) -> dict[str, Any]:
    """PartitionSpecs matching decode_state_shapes."""
    tpa, kvp = hx.tpa_axis, hx.kvp_axes
    s: dict[str, Any] = {"total_len": P()}
    if cfg.has_attention:
        s["kcache"] = s["vcache"] = P(None, None, tpa, kvp, None)
        if hx.kv_cache_bits == 8:
            s["kscale"] = s["vscale"] = P(None, None, tpa, kvp)
    if cfg.has_ssm:
        # batch over 'data' (when divisible), ssm heads/channels over 'model'
        dsz = mesh.shape["data"] if mesh else 1
        msz = mesh.shape["model"] if mesh else 1
        bax = "data" if (batch is None or batch % dsz == 0) else None
        hax = "model" if cfg.ssm_heads % msz == 0 else None
        cax = "model" if cfg.conv_dim % msz == 0 else None
        s["ssm_conv"] = P(None, bax, cax, None)
        s["ssm_state"] = P(None, bax, hax, None, None)
    if cfg.is_encdec:
        s["xk"] = s["xv"] = P(None, None, tpa, kvp, None)
        s["enc_len"] = P()
    return s


def init_decode_state(cfg: ArchConfig, batch: int, seq_len: int, kvp: int,
                      rr_block: int = 16, dtype=jnp.bfloat16,
                      total_len: int | jax.Array = 0,
                      kv_bits: int = 16) -> dict[str, Any]:
    """Zero-initialised decode state (concrete arrays, small/test use).

    ``kv_bits=8`` allocates int8 K/V payloads plus per-slot f32 scale
    planes (``kscale``/``vscale``)."""
    shapes = decode_state_shapes(cfg, batch, seq_len, kvp, rr_block, dtype,
                                 kv_bits=kv_bits)
    state = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
    tl = jnp.asarray(total_len, jnp.int32)
    state["total_len"] = tl
    return state


def quantize_decode_state(state: dict[str, Any]) -> dict[str, Any]:
    """fp round-robin K/V caches -> int8 payloads + per-slot f32 scales.

    Per-(…, slot) symmetric quantization over the ``hsz`` axis with the
    same formula as ``core/helix.quantize_kv_token`` (the decode-step
    append), so a prefilled-then-quantized cache and a cache grown token by
    token agree on shared slots.  Zero (unfilled) slots quantize to zero
    payloads with the epsilon scale.  Returns a copy of ``state`` with
    ``kcache``/``vcache`` replaced and ``kscale``/``vscale`` added; other
    leaves pass through."""
    out = dict(state)
    for key, skey in (("kcache", "kscale"), ("vcache", "vscale")):
        c = state[key].astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(c), axis=-1) / 127.0, 1e-30)
        out[key] = jnp.clip(jnp.round(c / scale[..., None]),
                            -127, 127).astype(jnp.int8)
        out[skey] = scale
    return out
