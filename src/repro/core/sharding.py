"""Phase-dependent sharding policies (Helix's "re-provisioning", §2.2).

One *fixed* device mesh; the **logical role** of its axes changes per phase:

  train/prefill :  DP = ("pod","data")   TP = ("model",)   EP = ("data",)
  helix decode  :  KVP × TPA during attention, TPF(×EP) during FFN — these
                   live inside shard_map (core/helix.py, models/decode_model);
                   this module provides the in/out PartitionSpecs for params,
                   caches and batch data.

This is the TPU-idiomatic equivalent of the paper's GPU pool
reconfiguration: meshes are static under XLA, so "re-provisioning" is
re-interpreting axis roles (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


# ------------------------------------------------------------------ policy
class MeshPolicy:
    """Callable activation-sharding policy for the GSPMD (train/prefill) path.

    ``policy(x, "dp", None, "tp")`` constrains x's dims to the mesh axes the
    logical roles map to.  Unknown/None dims stay unconstrained.
    """

    def __init__(self, mesh: Mesh, roles: dict[str, tuple[str, ...]]):
        self.mesh = mesh
        self.roles = roles

    def spec(self, *axes) -> P:
        return P(*[self.roles.get(a) if a else None for a in axes])

    def __call__(self, x, *axes):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*axes)))


def train_roles(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    names = mesh.axis_names
    dp = tuple(n for n in names if n in ("pod", "data"))
    roles = {"dp": dp, "tp": ("model",), "ep": ("data",)}
    if "pod" in names:
        roles["pod"] = ("pod",)
    return roles


# ------------------------------------------------------------------ helix
# back-compat alias: the canonical list lives in the kernel registry
from repro.kernels.registry import BACKENDS as ATTN_BACKENDS  # noqa: E402


@dataclasses.dataclass(frozen=True)
class HelixConfig:
    """How the mesh axes are consumed by the Helix decode phases.

    Attention phase: KV cache sharded over kvp_axes (sequence, round-robin)
    × tpa_axis (kv heads, requires TPA <= K).  FFN phase: same devices as
    TPF = everything (dense) or TPF × EP (MoE, EP = ep_axis).

    Kernel backends: the four ``*_backend`` fields select, per kernel family,
    one of ``"ref"`` | ``"pallas-interpret"`` | ``"pallas"`` from the unified
    registry (kernels/registry.py) — ``attn_backend`` routes flash_decode
    (the Helix decode attention inside the shard_map), ``prefill_backend``
    routes flash_prefill (full-sequence attention in prefill/train),
    ``ssd_backend`` routes ssd_prefill (the Mamba2 SSD scan core) and
    ``matmul_backend`` routes w8a16_matmul.  All backends of a family are
    exact up to fp summation order; see docs/kernels.md.
    """
    kvp_axes: tuple[str, ...]            # sequence-sharding axes
    tpa_axis: str | None = None          # head-sharding axis (None => TPA=1)
    ep_axis: str | None = None           # expert axis during FFN (MoE)
    rr_block: int = 16                   # §2.3 round-robin block
    # --- beyond-paper §Perf knobs (paper-faithful defaults) ---
    qkv_shard: bool = False              # shard QKV weights over 'model' and
    #   all-gather the small activations, instead of the paper's replicated
    #   per-rank QKV compute (wins when decode is weight-read bound)
    kv_cache_bits: int = 16              # 8 => int8 KV cache + f32 scales
    paged_kv: bool = False               # shared-pool paged KV cache: K/V
    #   live in [L, n_blocks, Kh, block_s, hsz] pool planes with per-request
    #   block tables instead of fixed per-slot rows, so cache pressure is a
    #   *global* page count (serving/pool.py, core/kvcache.py paged layout).
    #   Bit-exact vs the fixed layout at the same attn_block_s partition;
    #   decode-state leaves gain `block_tables` [B, max_pages] int32.
    attn_block_s: int = 512              # flash_decode S-block size (kernel
    #   tuning knob; clamped to the shard capacity).  In paged mode the
    #   per-rank page rows (rr_block) take over as the block size; setting
    #   attn_block_s == rr_block makes fixed and paged online-softmax block
    #   partitions identical, hence bit-exact parity between the layouts.
    # --- per-family kernel backends (kernels/registry.py) ---
    attn_backend: str = "ref"            # flash_decode (helix decode attn)
    prefill_backend: str = "ref"         # flash_prefill (prefill/train attn)
    ssd_backend: str = "ref"             # ssd_prefill (mamba2 SSD core)
    matmul_backend: str = "ref"          # w8a16_matmul (int8-weight matmul)
    fuse_append: bool = True             # fuse the rr-slot KV append into the
    #   flash-decode kernel epilogue (saves one cache HBM round-trip per
    #   layer per step).  Only active on the pallas backends, for round-robin
    #   caches (fp and int8 — the kernel quantizes the new token in-kernel);
    #   set False to force the separate append_kv pass (bit-exact either way).
    prune_blocks: bool = True            # length/causality-aware block
    #   pruning in the Pallas attention kernels: invalid K/V blocks are
    #   *skipped* (index_map clamp elides their DMAs), not masked, so
    #   per-request HBM reads scale with the true sequence length (and the
    #   window on sliding-window layers) instead of the slot capacity.
    #   Bit-exact either way; False restores the dense sweep (and, on the
    #   Pallas backends, re-enables the caller-side windowed cache-slice
    #   fast path the pruning subsumes).
    lm_head_w8: bool = False             # quantize the lm_head weights to
    #   int8 (per-column symmetric) on the decode path and run the logits
    #   matmul through the w8a16_matmul family (``matmul_backend`` picks the
    #   oracle or the Pallas kernel).  Changes numerics (weight-only
    #   quantization); all matmul_backend choices agree on the same
    #   quantized weights up to fp summation order.
    grouped_decode: bool = False         # grouped shared-prefix decode
    #   (CoDec-style, arXiv 2505.17694) on the paged Pallas backends:
    #   requests whose block tables share leading pages (prefix sharing —
    #   serving/pool.py) stack their Q rows and stream each shared page
    #   once per *group* instead of once per request.  Requires paged_kv;
    #   decode-state leaves gain `group_id`/`group_np` [B] int32 (the
    #   engine recomputes them each step).  Bit-exact vs ungrouped; the
    #   ref backend ignores the grouping (oracle semantics).

    def __post_init__(self):
        from repro.kernels import registry
        for field, family in registry.FAMILY_FIELDS.items():
            assert getattr(self, field) in registry.BACKENDS, \
                (field, getattr(self, field), registry.BACKENDS)

    def backend_for(self, family: str) -> str:
        """Selected backend for a registry kernel family name."""
        from repro.kernels import registry
        for field, fam in registry.FAMILY_FIELDS.items():
            if fam == family:
                return getattr(self, field)
        raise ValueError(f"unknown kernel family {family!r}")

    def all_axes(self) -> tuple[str, ...]:
        """Every mesh axis the attention phase consumes (kvp then tpa)."""
        return self.kvp_axes + ((self.tpa_axis,) if self.tpa_axis else ())

    def kvp(self, mesh: Mesh) -> int:
        """KV-parallel width: product of the kvp axes' sizes on ``mesh``."""
        import math
        return math.prod(mesh.shape[a] for a in self.kvp_axes)

    def tpa(self, mesh: Mesh) -> int:
        """Attention tensor-parallel width (1 when ``tpa_axis`` is None)."""
        return mesh.shape[self.tpa_axis] if self.tpa_axis else 1


def default_helix_config(cfg: ArchConfig, mesh: Mesh) -> HelixConfig:
    """Paper §2.1: TPA <= K, KVP = rest.  Pure-KVP (TPA=1) is roofline-
    equivalent for KV reads (DESIGN.md §2 mesh-shape constraint); archs with
    K >= model-width use the 2-D mode (phi-3-vision: TPA=model)."""
    names = mesh.axis_names
    model_w = mesh.shape["model"]
    ep = "data" if cfg.moe else None
    if cfg.has_attention and cfg.n_kv_heads >= model_w:
        kvp = tuple(n for n in names if n != "model")
        return HelixConfig(kvp_axes=kvp, tpa_axis="model", ep_axis=ep)
    return HelixConfig(kvp_axes=tuple(names), tpa_axis=None, ep_axis=ep)


# --------------------------------------------------------- param specs
def _match(tree: Any, fn) -> Any:
    """tree_map over dict-of-arrays with (path, leaf) callback."""
    return {
        k: _match(v, lambda p, x, k=k: fn((k,) + p, x)) if isinstance(v, dict)
        else fn((k,), v)
        for k, v in tree.items()
    }


def _sized(mesh: Mesh):
    """dim-size-aware spec guard: axes kept only if they divide the dim."""
    def ok(dim_size: int, axes) -> Any:
        if axes is None:
            return None
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        import math
        return axes if dim_size % math.prod(
            mesh.shape[a] for a in tup) == 0 else None
    return ok


def train_param_specs(cfg: ArchConfig, params, mesh: Mesh) -> Any:
    """GSPMD train/prefill specs: Megatron TP over 'model', experts over
    'data' (EP), everything else replicated.  Layer-stacked leaves keep a
    leading None dim.  Axes that don't divide a dim fall back to replicated
    (pjit argument shardings must divide evenly)."""
    ok = _sized(mesh)

    def leaf(path, x):
        name = path[-1]
        stacked = path[0] in ("layers",) or (path[0] == "enc"
                                             and path[1] == "layers")
        lead = (None,) if stacked else ()
        nd = x.ndim - len(lead)
        if name in ("wq", "wk", "wv", "w1", "w3"):       # col-parallel
            if len(path) >= 2 and path[-2] == "moe":
                return P(*lead, ok(x.shape[1], "data"), None,
                         ok(x.shape[3], "model"))        # [L,E,H,Fe]
            return P(*lead, None, ok(x.shape[-1], "model"))
        if name in ("wo", "w2"):                          # row-parallel
            if len(path) >= 2 and path[-2] == "moe":
                return P(*lead, ok(x.shape[1], "data"),
                         ok(x.shape[2], "model"), None)  # [L,E,Fe,H]
            return P(*lead, ok(x.shape[-2], "model"), None)
        if name == "router":
            return P(*lead, None, None)
        if name == "w_in":                                # ssm in-proj
            return P(*lead, None, ok(x.shape[-1], "model"))
        if name == "w_out":
            return P(*lead, ok(x.shape[-2], "model"), None)
        if name in ("conv_w", "conv_b", "norm_w", "A_log", "D", "dt_bias"):
            if nd >= 1:
                return P(*lead, ok(x.shape[len(lead)], "model"),
                         *([None] * (nd - 1)))
            return P()
        if name == "embed":
            return P(ok(x.shape[0], "model"), None)
        if name == "lm_head":
            return P(None, ok(x.shape[1], "model"))
        return P(*lead, *([None] * nd))

    return _match(params, leaf)


def dense_ffn_mode(cfg: ArchConfig, mesh: Mesh, hx: HelixConfig) -> str:
    """'1d' — TPF = N on the F dim (the paper's dense layout); '2d' — H over
    the dp-ish axes × F over 'model' when F doesn't divide by N (hymba's
    F=5504, arctic multi-pod residual F=4864)."""
    import math
    n = math.prod(mesh.shape[a] for a in _axes(hx))
    return "1d" if cfg.d_ff % n == 0 else "2d"


def helix_param_specs(cfg: ArchConfig, params, hx: HelixConfig,
                      mesh: Mesh) -> Any:
    """Decode-phase specs (GSPMD argument shardings for serve_step).

    FFN weights: TPF = all axes (dense, '1d' mode; '2d' fallback shards
    H x F) or EP=data × TPF=rest (MoE experts).  Attention QKV: sharded over
    tpa_axis heads only (replicated over KVP — the paper's choice: every KVP
    rank computes the full QKV projection).  wo: input dim sharded over ALL
    axes (the post-all-to-all [B, H/N] layout, tpa-major then kvp) when it
    divides; 'model'-on-H fallback for padded flat dims (see helix_out_dim).
    """
    import math
    ok = _sized(mesh)
    tpf = tuple(a for a in ("pod", "model") if a in _axes(hx)) or None
    all_ax = _axes(hx)
    n_all = math.prod(mesh.shape[a] for a in all_ax)
    o_in = ((hx.tpa_axis,) if hx.tpa_axis else ()) + hx.kvp_axes
    ffn2d = cfg.d_ff and dense_ffn_mode(cfg, mesh, hx) == "2d"
    dp_ish = tuple(a for a in mesh.axis_names if a != "model")

    def leaf(path, x):
        name = path[-1]
        stacked = path[0] in ("layers",) or (path[0] == "enc"
                                             and path[1] == "layers")
        lead = (None,) if stacked else ()
        nd = x.ndim - len(lead)
        moe = len(path) >= 2 and path[-2] == "moe"
        if moe and name in ("w1", "w3"):
            return P(*lead, ok(x.shape[1], hx.ep_axis), None,
                     ok(x.shape[3], tpf))
        if moe and name == "w2":
            return P(*lead, ok(x.shape[1], hx.ep_axis),
                     ok(x.shape[2], tpf), None)
        if moe and name == "router":
            return P(*lead, None, None)
        if name in ("w1", "w3"):                          # dense FFN
            if ffn2d:
                return P(*lead, ok(x.shape[-2], dp_ish),
                         ok(x.shape[-1], "model"))
            return P(*lead, None, all_ax)
        if name == "w2":
            if ffn2d:
                return P(*lead, ok(x.shape[-2], "model"),
                         ok(x.shape[-1], dp_ish))
            return P(*lead, all_ax, None)
        if name in ("wq", "wk", "wv"):
            if hx.qkv_shard and not hx.tpa_axis:
                return P(*lead, None, ok(x.shape[-1], "model"))
            return P(*lead, None, ok(x.shape[-1], hx.tpa_axis)
                     if hx.tpa_axis else None)
        if name == "wo":
            # input dim == q_dim; shardable over all axes iff divisible
            return P(*lead, ok(x.shape[-2], o_in), None)
        if name == "w_in":                        # ssm: TP over 'model' only
            return P(*lead, None, ok(x.shape[-1], "model"))
        if name == "w_out":
            return P(*lead, ok(x.shape[-2], "model"), None)
        if name in ("conv_w", "conv_b", "norm_w", "A_log", "D", "dt_bias"):
            if nd >= 1:
                return P(*lead, ok(x.shape[len(lead)], "model"),
                         *([None] * (nd - 1)))
            return P()
        if name == "embed":
            return P(ok(x.shape[0], "model"), None)   # lookup-friendly
        if name == "lm_head":
            return P(None, ok(x.shape[1], all_ax))
        return P(*lead, *([None] * nd))

    return _match(params, leaf)


def _axes(hx: HelixConfig) -> tuple[str, ...]:
    return hx.all_axes()


def cache_specs(hx: HelixConfig):
    """KV cache [L, B, Kh/TPA, S/KVP, hsz]: sequence over kvp, heads over tpa."""
    return P(None, None, hx.tpa_axis, hx.kvp_axes, None)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
