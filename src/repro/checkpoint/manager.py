"""Sharded checkpointing: atomic commit, keep-last-k GC, elastic reshard.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json      tree structure, per-leaf shape/dtype, step
        leaf_00000.npy ... one .npy per pytree leaf (global array)
        COMMITTED          written last — a dir without it is garbage

Writes go to ``step_X.tmp`` then a single atomic rename; a crash mid-write
can never corrupt the newest checkpoint.  Restore reshards to *any* mesh:
leaves are stored as global arrays and re-dispatched with the target
sharding (``jax.device_put``), which is what elastic up/down-scaling needs.
At real multi-host scale the same manifest drives per-host partial writes
(each host serializes only the shards it owns — the addressable-shard loop
below — then rank 0 commits); on this single-process runtime the global
array is fully addressable so the loop degenerates to one write.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any

import numpy as np
import jax


def _flatten_with_names(tree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return named, treedef


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any) -> Path:
        final = self.root / f"step_{step:09d}"
        tmp = self.root / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        named, _ = _flatten_with_names(tree)
        manifest = {"step": step, "leaves": []}
        for i, (name, leaf) in enumerate(named):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"name": name, "file": fname, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic commit
        self._gc()
        return final

    # -------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = sorted(self._committed())
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; optionally reshard.

        ``shardings``: matching pytree of NamedSharding for elastic restore
        onto a (possibly different) mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.root}")
        d = self.root / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())

        flat, treedef = jax.tree_util.tree_flatten(tree_like)
        assert len(flat) == len(manifest["leaves"]), \
            (len(flat), len(manifest["leaves"]))
        arrays = [np.load(d / leaf["file"]) for leaf in manifest["leaves"]]
        if shardings is not None:
            shard_flat = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_flat)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return treedef.unflatten(arrays)

    # ------------------------------------------------------------------- gc
    def _committed(self) -> list[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return out

    def _gc(self):
        steps = sorted(self._committed())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.root / f"step_{s:09d}", ignore_errors=True)
