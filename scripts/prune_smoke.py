#!/usr/bin/env python
"""CI smoke for length/causality-aware block pruning (scripts/ci.sh).

Asserts the PR's acceptance criteria cheaply (small shapes, seconds):

  1. flash_decode with pruning visits <= ceil(local_valid_len / block_s) + 1
     K/V blocks per (b, h) at short lengths — not S_cap / block_s — and the
     windowed case caps at O(window / block_s);
  2. causal flash_prefill visits ~the lower triangle (~55% for deep grids)
     of the (T/blk_q) x (S/blk_k) rectangle;
  3. pruned and unpruned kernel outputs are bit-exact in both families.

Run directly:  PYTHONPATH=src python scripts/prune_smoke.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.kernels import registry                             # noqa: E402
from repro.kernels.flash_decode import (flash_decode,          # noqa: E402
                                        local_valid_len)
from repro.kernels.flash_prefill import flash_prefill          # noqa: E402
from repro.utils import cdiv                                   # noqa: E402


def main() -> int:
    # ---- decode: short request in a large-capacity shard ----
    b, qh, kh, hsz, s_cap = 2, 8, 2, 64, 256
    kvp, rr, block_s, rank = 4, 16, 32, 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, qh, hsz))
    k = jax.random.normal(ks[1], (b, kh, s_cap, hsz))
    v = jax.random.normal(ks[2], (b, kh, s_cap, hsz))
    account = registry.accounting("flash_decode")

    total_len = 100                      # ~25 valid local slots of 256
    for window in (0, 48):
        acc = account(q, k, v, total_len, rank, kvp=kvp, rr_block=rr,
                      window=window, block_s=block_s, prune=True)
        dense = account(q, k, v, total_len, rank, kvp=kvp, rr_block=rr,
                        window=window, block_s=block_s, prune=False)
        valid = int(local_valid_len(jnp.asarray(total_len), rank, kvp, rr))
        bound = cdiv(valid, block_s) + 1
        per_bh = acc["blocks_visited"] / (b * kh)
        assert per_bh <= bound, (per_bh, bound)
        assert acc["blocks_visited"] < dense["blocks_total"], acc
        out_p, lse_p = flash_decode(q, k, v, total_len, rank, kvp=kvp,
                                    rr_block=rr, window=window,
                                    block_s=block_s, prune=True)
        out_d, lse_d = flash_decode(q, k, v, total_len, rank, kvp=kvp,
                                    rr_block=rr, window=window,
                                    block_s=block_s, prune=False)
        np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_d))
        np.testing.assert_array_equal(np.asarray(lse_p), np.asarray(lse_d))
        print(f"[prune_smoke] decode window={window}: "
              f"{acc['blocks_visited']}/{dense['blocks_total']} blocks "
              f"(<= {bound}/ (b,h)), outputs bit-exact")

    # ---- paged decode: table indirection keeps the same bound ----
    ps = block_s                              # page rows == kernel block
    mp = s_cap // ps
    tables = np.zeros((b, mp), np.int32)
    perm = np.random.default_rng(0).permutation(np.arange(1, 1 + b * mp))
    pool_k = jnp.zeros((1 + b * mp, kh, ps, 64), jnp.float32)
    pool_v = jnp.zeros((1 + b * mp, kh, ps, 64), jnp.float32)
    i = 0
    for bb in range(b):
        for p in range(mp):
            phys = int(perm[i]); i += 1
            tables[bb, p] = phys
            pool_k = pool_k.at[phys].set(k[bb, :, p * ps:(p + 1) * ps])
            pool_v = pool_v.at[phys].set(v[bb, :, p * ps:(p + 1) * ps])
    accp = account(q, pool_k, pool_v, total_len, rank, kvp=kvp, rr_block=rr,
                   prune=True, block_tables=tables)
    accf = account(q, k, v, total_len, rank, kvp=kvp, rr_block=rr,
                   block_s=ps, prune=True)
    assert accp["blocks_visited"] == accf["blocks_visited"], (accp, accf)
    valid = int(local_valid_len(jnp.asarray(total_len), rank, kvp, rr))
    assert accp["blocks_visited"] / (b * kh) <= cdiv(valid, ps) + 1
    out_f, _ = flash_decode(q, k, v, total_len, rank, kvp=kvp, rr_block=rr,
                            block_s=ps, prune=True)
    out_g, _ = flash_decode(q, pool_k, pool_v, total_len, rank, kvp=kvp,
                            rr_block=rr, prune=True,
                            block_tables=jnp.asarray(tables))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_g))
    print(f"[prune_smoke] paged decode: {accp['blocks_visited']} blocks "
          f"through the block table (== fixed), outputs bit-exact")

    # ---- prefill: causal triangle ----
    t = s = 320
    blk = 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    qp = jax.random.normal(ks[0], (1, t, 4, 32))
    kp = jax.random.normal(ks[1], (1, s, 2, 32))
    vp = jax.random.normal(ks[2], (1, s, 2, 32))
    paccount = registry.accounting("flash_prefill")
    acc = paccount(qp, kp, vp, causal=True, blk_q=blk, blk_k=blk, prune=True)
    frac = acc["blocks_visited"] / acc["blocks_total"]
    n = acc["n_qblocks"]
    assert abs(frac - (n + 1) / (2 * n)) < 1e-9, (frac, n)
    assert frac <= 0.56, frac
    out_p = flash_prefill(qp, kp, vp, causal=True, blk_q=blk, blk_k=blk,
                          prune=True)
    out_d = flash_prefill(qp, kp, vp, causal=True, blk_q=blk, blk_k=blk,
                          prune=False)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_d))
    print(f"[prune_smoke] prefill causal: {frac * 100:.0f}% of the "
          f"rectangle visited, outputs bit-exact")
    print("[prune_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
