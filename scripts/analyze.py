#!/usr/bin/env python
"""Run the Helix static contract checker (see docs/analysis.md).

Layers: index (kernel index-space audit), jaxpr (collective/dtype audit of
the serving step graphs), sync (host-sync AST lint over serving/launch).

    python scripts/analyze.py                 # errors fail, warnings print
    python scripts/analyze.py --strict        # any unsuppressed finding fails
    python scripts/analyze.py --skip jaxpr    # run a subset
    python scripts/analyze.py --update-baseline   # rewrite suppress entries

Writes the machine-readable report to ANALYSIS.json (schema asserted by
scripts/check_analysis_schema.py); baseline suppressions live in
ANALYSIS_BASELINE.json and match findings on (check, path, symbol) — never
line numbers.  CI runs ``--strict`` (scripts/ci.sh, ``make analyze``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

LAYERS = ("index", "jaxpr", "sync")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="fail on any unsuppressed finding (CI gate)")
    ap.add_argument("--skip", default="",
                    help="comma-separated layers to skip "
                         f"(of: {', '.join(LAYERS)})")
    ap.add_argument("--json", default="ANALYSIS.json",
                    help="machine-readable report path ('' disables)")
    ap.add_argument("--baseline", default="ANALYSIS_BASELINE.json",
                    help="baseline suppression file ('' disables)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "(review the diff before committing!)")
    args = ap.parse_args()

    skip = {s for s in args.skip.split(",") if s}
    unknown = skip - set(LAYERS)
    if unknown:
        ap.error(f"unknown layers in --skip: {sorted(unknown)}")

    from repro.analysis import (Report, lint_paths, load_baseline,
                                run_index_audit)
    from repro.analysis.jaxpr_audit import run_jaxpr_audit

    repo = os.path.join(os.path.dirname(__file__), "..")
    report = Report()
    if "index" not in skip:
        run_index_audit(report)
    if "jaxpr" not in skip:
        run_jaxpr_audit(report)
    if "sync" not in skip:
        report.extend(lint_paths(repo_root=repo))
        report.mark_run("sync")

    if args.update_baseline:
        entries = [{"check": f.check, "path": f.path, "symbol": f.symbol,
                    "reason": "baselined by --update-baseline; document "
                              "why this finding is intentional"}
                   for f in sorted({f.key(): f
                                    for f in report.findings}.values(),
                                   key=lambda f: f.key())]
        path = os.path.join(repo, args.baseline or "ANALYSIS_BASELINE.json")
        with open(path, "w") as f:
            json.dump({"suppress": entries}, f, indent=2)
            f.write("\n")
        print(f"wrote {len(entries)} suppress entries to {path}")
        return 0

    stale = []
    if args.baseline:
        bpath = os.path.join(repo, args.baseline)
        if os.path.exists(bpath):
            stale = report.apply_baseline(load_baseline(bpath))

    meta = {"generated_by": "scripts/analyze.py",
            "strict": args.strict,
            "baseline": args.baseline or None}
    if args.json:
        jpath = os.path.join(repo, args.json)
        with open(jpath, "w") as f:
            json.dump(report.to_dict(meta), f, indent=2)
            f.write("\n")

    print(report.render())
    for e in stale:
        print(f"[stale baseline] {e['check']} {e['path']} ({e['symbol']}): "
              f"no longer found — remove the entry")

    if report.unsuppressed("error"):
        return 1
    if args.strict and (report.unsuppressed() or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
