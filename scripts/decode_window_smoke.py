#!/usr/bin/env python
"""CI smoke for windowed multi-step decode (scripts/ci.sh).

Runs the same sampled workload through ``serve_demo`` with
``decode_window`` in {1, 4, 17} and asserts, per engine config:

  1. **Stream identity** — every request's token stream is bit-identical
     across window sizes (the whole point of the design: the window is a
     dispatch-granularity change, never a semantics change).
  2. **Sync rate** — the engine blocked on exactly ``68 / N`` decode
     transfers per run (2 equal lockstep requests, ``max_new = 69`` ⇒ 68
     post-prefill decode steps, divisible by 1, 4 and 17): syncs per
     decoded token really drop to 1/N, the headline of this optimization.

Config (a) is the fixed per-slot cache; config (b) layers paged KV +
prefix sharing + a host tier on top, proving the window path composes
with every cache feature.  Sampling is top-p (the deepest sampler path),
so the device PRNG streams are exercised, not just argmax.

Run directly:  PYTHONPATH=src python scripts/decode_window_smoke.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.serve import serve_demo                      # noqa: E402

WINDOWS = (1, 4, 17)
MAX_NEW = 69          # 1 prefill token + 68 decode steps (lcm-friendly)
CONFIGS = {
    "fixed": {},
    "paged+prefix+tier": dict(paged_kv=True, prefix_share=True,
                              shared_prefix_len=8, host_pages=16,
                              session_kv=True),
}


def run(window: int, extra: dict):
    finished, summary = serve_demo(
        "granite-3-2b", reduced=True, n_requests=2, prompt_len=12,
        max_new=MAX_NEW, max_batch=2, chunk_tokens=8,
        sampling="top_p", temperature=0.9, top_p=0.85, seed=7,
        decode_window=window, log=lambda s: None, **extra)
    return {r.rid: tuple(r.out_tokens) for r in finished}, summary


def main() -> int:
    for name, extra in CONFIGS.items():
        base = None
        for window in WINDOWS:
            streams, summary = run(window, extra)
            assert all(len(t) == MAX_NEW for t in streams.values()), streams
            if base is None:
                base = streams
            else:
                assert streams == base, (
                    f"[{name}] decode_window={window} diverged from "
                    f"window=1:\n  w1: {base}\n  w{window}: {streams}")
            # 2 equal lockstep rows -> every window is full: exactly
            # 68 / N blocking decode transfers, i.e. 1/N syncs per token
            want_syncs = 68 // window
            assert summary["decode_syncs"] == want_syncs, (
                f"[{name}] decode_window={window}: "
                f"{summary['decode_syncs']} syncs, want {want_syncs}")
            assert summary["decoded_tokens"] == 2 * 68, summary
            print(f"[decode_window_smoke] {name}: window={window:<3} "
                  f"syncs={summary['decode_syncs']:<3} "
                  f"syncs_per_token={summary['syncs_per_token']:.4f} "
                  f"streams == w1: True")
    print("[decode_window_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
