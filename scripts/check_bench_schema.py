#!/usr/bin/env python
"""CI schema check for the machine-readable benchmark JSONs.

Asserts ``BENCH_serving.json`` (benchmarks/bench_serving.py) carries every
field downstream tooling keys on, with the right types and sane values —
so a refactor of the bench or the metrics summary can't silently drop a
column and erase the perf trajectory across PRs.

Run directly:  python scripts/check_bench_schema.py [BENCH_serving.json]
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

META_KEYS = {"arch", "device", "requests", "prompt_len", "max_new",
             "max_batch"}


def check(path: pathlib.Path) -> list[str]:
    from benchmarks.bench_serving import ROW_SCHEMA  # single source of truth
    errors: list[str] = []
    data = json.loads(path.read_text())
    missing_meta = META_KEYS - set(data.get("meta", {}))
    if missing_meta:
        errors.append(f"meta missing keys: {sorted(missing_meta)}")
    rows = data.get("rows", [])
    if not rows:
        errors.append("no rows")
    for i, row in enumerate(rows):
        for key, typ in ROW_SCHEMA.items():
            if key not in row:
                errors.append(f"row {i}: missing {key!r}")
            elif not isinstance(row[key], (typ, int) if typ is float else typ):
                errors.append(f"row {i}: {key!r} is {type(row[key]).__name__},"
                              f" want {typ.__name__}")
        if row.get("n_finished", 0) <= 0:
            errors.append(f"row {i}: n_finished must be positive "
                          "(engine drained nothing?)")
        for key in ("ttft_p50_s", "ttl_p50_s", "throughput_tok_s"):
            if not row.get(key, 0) > 0:
                errors.append(f"row {i}: {key} must be > 0, got {row.get(key)}")
        # paged-pool health columns: a paged row must have seen real
        # occupancy; fixed-cap rows must report zeros (no phantom pool)
        if row.get("paged_kv"):
            if not 0 < row.get("pool_occupancy_peak", 0) <= 1:
                errors.append(f"row {i}: paged row needs pool_occupancy_peak"
                              f" in (0, 1], got "
                              f"{row.get('pool_occupancy_peak')}")
            if not 0 <= row.get("pool_frag_mean", -1) <= 1:
                errors.append(f"row {i}: pool_frag_mean out of [0, 1]")
        else:
            for key in ("pool_occupancy_peak", "pool_frag_mean"):
                if row.get(key, 0) != 0:
                    errors.append(f"row {i}: fixed-cap row has nonzero "
                                  f"{key}: {row.get(key)}")
        # prefix-sharing columns: a sharing row must have matched at least
        # one prefix (else the workload/stagger is broken and the row is
        # measuring nothing); non-sharing rows must report zeros
        if row.get("prefix_share"):
            if not 0 < row.get("prefix_hit_rate", 0) <= 1:
                errors.append(f"row {i}: prefix_share row needs "
                              f"prefix_hit_rate in (0, 1], got "
                              f"{row.get('prefix_hit_rate')}")
            if not row.get("pages_shared_peak", 0) >= 1:
                errors.append(f"row {i}: prefix_share row needs "
                              "pages_shared_peak >= 1")
        else:
            for key in ("prefix_hit_rate", "pages_shared_peak"):
                if row.get(key, 0) != 0:
                    errors.append(f"row {i}: non-sharing row has nonzero "
                                  f"{key}: {row.get(key)}")
        # host KV tier columns: a session row must have restored history
        # with zero re-prefill fallback (faults are never injected in the
        # bench, so any fallback means the tier is broken); non-session
        # rows must report a zero turn-2 TTFT only when single-turn
        if row.get("session_kv"):
            if row.get("turns", 1) < 2:
                errors.append(f"row {i}: session_kv row needs turns >= 2")
            if not row.get("restores", 0) >= 1:
                errors.append(f"row {i}: session_kv row needs restores >= 1")
            if row.get("resume_reprefill_chunks", -1) != 0:
                errors.append(f"row {i}: session_kv row (no faults) must "
                              "have resume_reprefill_chunks == 0, got "
                              f"{row.get('resume_reprefill_chunks')}")
            if not row.get("turn2_ttft_s", 0) > 0:
                errors.append(f"row {i}: session_kv row needs "
                              "turn2_ttft_s > 0")
        elif row.get("turns", 1) == 1 and not row.get("slo_ttl_ms"):
            # governor rows (slo_ttl_ms > 0) legitimately spill in a
            # single-turn run — shedding batch work IS the spill path
            for key in ("spills", "restores", "turn2_ttft_s",
                        "restore_p95_ms"):
                if row.get(key, 0) != 0:
                    errors.append(f"row {i}: single-turn row has nonzero "
                                  f"{key}: {row.get(key)}")
        # multi-tenant SLO columns: every row is trace-addressed and
        # names its tenant/class slice; governor rows carry a real
        # goodput and a miss rate in [0, 1], unarmed rows a zero miss
        # rate (no target to miss)
        for key in ("trace", "tenant", "slo_class"):
            if not (isinstance(row.get(key), str) and row.get(key)):
                errors.append(f"row {i}: {key!r} must be a non-empty "
                              f"string, got {row.get(key)!r}")
        if not 0 <= row.get("ttl_target_miss_rate", -1) <= 1:
            errors.append(f"row {i}: ttl_target_miss_rate out of [0, 1]")
        if row.get("slo_ttl_ms", 0):
            if not row.get("goodput_tok_s", 0) > 0:
                errors.append(f"row {i}: governor row needs "
                              "goodput_tok_s > 0")
        elif row.get("ttl_target_miss_rate", 0) != 0:
            errors.append(f"row {i}: unarmed row (slo_ttl_ms == 0) has "
                          "nonzero ttl_target_miss_rate")
        # windowed decode + sampling columns: the window is a positive
        # step count, the sync rate is a real rate in (0, 1] whenever the
        # row decoded anything, and the sampling kind is a known name.
        # session-KV rows are exempt from the upper bound: teacher-forced
        # history catch-up steps each sync without emitting a token, so
        # their sync rate legitimately exceeds 1 per *emitted* token
        if not row.get("decode_window", 0) >= 1:
            errors.append(f"row {i}: decode_window must be >= 1, got "
                          f"{row.get('decode_window')}")
        if row.get("n_tokens", 0) > 0:
            spt = row.get("syncs_per_token", 0)
            cap = None if row.get("session_kv") else 1
            if not spt > 0 or (cap is not None and spt > cap):
                errors.append(f"row {i}: syncs_per_token must be in (0, 1] "
                              f"when tokens were decoded, got {spt}")
        from repro.serving.sampling import SAMPLING_KINDS
        if row.get("sampling") not in SAMPLING_KINDS:
            errors.append(f"row {i}: sampling must be one of "
                          f"{SAMPLING_KINDS}, got {row.get('sampling')!r}")
    return errors


def main() -> int:
    path = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                        else ROOT / "BENCH_serving.json")
    sys.path.insert(0, str(ROOT))          # import benchmarks.* from root
    if not path.exists():
        print(f"[check_bench_schema] {path} missing "
              "(run benchmarks/bench_serving.py first)")
        return 1
    errors = check(path)
    if errors:
        print(f"[check_bench_schema] FAILED for {path}:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"[check_bench_schema] OK ({path})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
