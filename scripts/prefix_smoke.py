#!/usr/bin/env python
"""CI smoke for prefix sharing + grouped shared-prefix decode (scripts/ci.sh).

Runs a staggered shared-prefix workload (one registrant, then same-prefix
followers — registration happens when the registrant finishes prefill)
through the paged engine three ways — sharing off, sharing on, sharing +
grouped decode — and asserts the PR's acceptance criteria end to end:

  * token streams are identical in all three runs (sharing and grouping
    are memory/bandwidth optimisations, never numerics);
  * a follower's prefill runs ~suffix-only: it spends ceil(suffix/chunk)
    engine steps in PREFILL instead of ceil(prompt/chunk) — the TTFT win;
  * full prefix pages are mapped by more than one request
    (``pages_shared_peak``) and admissions hit the index;
  * the grouped decode's accounting shows the shared prefix pages read
    once per *group* per step instead of once per request — strictly
    fewer HBM bytes than the ungrouped replay of the same state.

Run directly:  PYTHONPATH=src python scripts/prefix_smoke.py
"""
from __future__ import annotations

import math
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402

from repro.configs import get_config                           # noqa: E402
from repro.core.sharding import HelixConfig                    # noqa: E402
from repro.kernels.flash_decode import flash_decode_accounting  # noqa: E402
from repro.models.model_zoo import (build_serve_step,          # noqa: E402
                                    make_chunk_prefill_step,
                                    make_prefill_step)
from repro.models.transformer import init_params               # noqa: E402
from repro.serving import DecodeEngine, Request                # noqa: E402
from repro.utils import make_mesh, set_mesh                    # noqa: E402

CHUNK = 4
PREFIX_LEN = 32          # 2 full pages at kvp=1, rr_block=16
SUFFIX_LENS = (7, 9, 5)
MAX_NEW = 6


def _engine(cfg, params, mesh, *, share, grouped):
    hx = HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16,
                     paged_kv=True, grouped_decode=grouped)
    with set_mesh(mesh):
        serve = build_serve_step(cfg, mesh, hx)
        prefill = make_prefill_step(cfg, mesh, hx)
        cs = make_chunk_prefill_step(cfg, mesh, hx)
        return DecodeEngine(cfg, params, serve, prefill, max_batch=3,
                            max_seq=96, hx=hx, chunk_tokens=CHUNK,
                            chunk_prefill_step=cs, tp_width=1,
                            prefix_share=share)


def run(cfg, params, mesh, prompts, *, share, grouped):
    eng = _engine(cfg, params, mesh, share=share, grouped=grouped)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    prefill_steps = [0] * len(reqs)
    snap = {}
    with set_mesh(mesh):
        eng.submit(reqs[0])
        while reqs[0].state != "decode":        # register r0's prefix first
            eng.step()
        for r in reqs[1:]:
            eng.submit(r)
        while not all(r.done for r in reqs):
            eng.step()
            for i, r in enumerate(reqs):
                prefill_steps[i] += r.state == "prefill"
            if grouped and not snap and all(r.state == "decode"
                                            for r in reqs):
                snap = {k: np.asarray(eng.state[k]) for k in
                        ("block_tables", "group_id", "group_np",
                         "total_len")}
                snap["kshape"] = tuple(eng.state["kcache"].shape)
    streams = [tuple(r.out_tokens) for r in reqs]
    return streams, prefill_steps, eng, snap


def main() -> int:
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab, PREFIX_LEN).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab, n).tolist()
               for n in SUFFIX_LENS]

    base, base_pf, _, _ = run(cfg, params, mesh, prompts,
                              share=False, grouped=False)
    shared, sh_pf, eng_s, _ = run(cfg, params, mesh, prompts,
                                  share=True, grouped=False)
    grp, gr_pf, eng_g, snap = run(cfg, params, mesh, prompts,
                                  share=True, grouped=True)

    # 1) identical streams in all three runs
    assert base == shared == grp, (
        f"streams diverged:\n  base:   {base}\n  shared: {shared}\n"
        f"  grouped:{grp}")

    # 2) follower prefill is ~suffix-only (the TTFT ~ suffix claim):
    # request 1's prompt is PREFIX_LEN + suffix tokens; sharing matches the
    # whole prefix so only the suffix chunk-prefills
    for i in (1, 2):
        # the step finishing the last chunk already shows state DECODE, so
        # counted PREFILL steps are one short of the chunk count
        full = math.ceil(len(prompts[i]) / CHUNK) - 1
        sfx = math.ceil((SUFFIX_LENS[i] + 1) / CHUNK) + 1
        assert base_pf[i] >= full, (i, base_pf)
        assert sh_pf[i] <= sfx < base_pf[i], (i, sh_pf, base_pf)
        assert gr_pf[i] <= sfx, (i, gr_pf)

    # 3) the pool really multiplexed prefix pages
    for eng in (eng_s, eng_g):
        st = eng.pool_stats()
        assert st["prefix_hit_rate"] > 0, st
        assert st["pages_shared_peak"] >= PREFIX_LEN // eng.block_s, st
        assert eng.pool.free_count == eng.pool.capacity    # drained

    # 4) grouped decode reads the shared prefix once per group: replay the
    # captured mid-decode state through the accounting with and without
    # the group leaves
    assert snap, "grouped run never had all requests decoding at once"
    n_pool, kh, bs, hsz = snap["kshape"][1:]
    kv = jax.ShapeDtypeStruct((n_pool, kh, bs, hsz), jnp.float32)
    q = jax.ShapeDtypeStruct((len(prompts), cfg.n_heads, hsz), jnp.float32)
    common = dict(kvp=1, rr_block=eng_g.rr, block_s=bs,
                  block_tables=snap["block_tables"])
    acc_g = flash_decode_accounting(
        q, kv, kv, snap["total_len"], 0,
        groups=(snap["group_id"], snap["group_np"]), **common)
    acc_u = flash_decode_accounting(q, kv, kv, snap["total_len"], 0, **common)
    assert acc_g["prefix_blocks"] > 0
    assert acc_g["bytes_read"] < acc_u["bytes_read"], (acc_g, acc_u)
    print(f"[prefix_smoke] streams identical (3 runs x {len(prompts)} "
          f"requests); follower prefill steps {base_pf[1:]} -> {sh_pf[1:]} "
          f"(suffix-only); pages_shared_peak="
          f"{eng_s.pool_stats()['pages_shared_peak']}; grouped decode "
          f"bytes/step {acc_g['bytes_read']} < ungrouped "
          f"{acc_u['bytes_read']} "
          f"({acc_g['bytes_read'] / acc_u['bytes_read']:.2f}x)")
    print("[prefix_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
