#!/usr/bin/env python
"""CI smoke for the multi-tenant SLO serving front end (scripts/ci.sh).

Generates a 2-tenant interactive+batch trace (serving/workload.py) that
saturates a small slot pool, round-trips it through ``save_trace`` /
``load_trace``, and replays it through ``serve_demo`` under the
deterministic ``VirtualClock``:

1. **Replay determinism** — two runs of the same trace produce
   bit-identical per-request token streams AND identical metrics
   summaries (the whole point of trace-addressed benchmarking).
2. **Governor acceptance** — with ``slo_ttl_ms`` armed, the TTL governor
   sheds batch-class slots through the host-tier spill path (zero
   re-prefill chunks on resume: graceful degradation, not wasted work),
   the interactive TTL mean lands strictly below the governor-off replay
   of the *same trace*, and the shed batch work still completes in full —
   batch trades latency for the interactive SLO, exactly the Helix
   premise (PAPER.md §1).
3. **Bench schema** — the multi-tenant columns bench rows carry
   (tenant / slo_class / goodput_tok_s / ttl_target_miss_rate) are
   present in benchmarks/bench_serving.py's ROW_SCHEMA.

Run directly:  PYTHONPATH=src python scripts/trace_smoke.py
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from repro.launch.serve import serve_demo                      # noqa: E402
from repro.serving.workload import (TenantSpec, generate_trace,  # noqa: E402
                                    load_trace, save_trace, trace_id)

SLO_TTL_MS = 2.6


def replay(rows, slo_ttl_ms: float):
    """One deterministic replay of ``rows``; returns (streams, summary)."""
    finished, summary = serve_demo(
        "granite-3-2b", reduced=True, n_requests=len(rows), prompt_len=12,
        max_new=6, max_batch=4, chunk_tokens=4, paged_kv=True,
        host_pages=64, trace=rows,
        tenants="chat:3:interactive,jobs:1:batch:3",
        slo_ttl_ms=slo_ttl_ms, virtual_clock=True, log=lambda s: None)
    return {r.rid: tuple(r.out_tokens) for r in finished}, summary


def main() -> int:
    tenants = (TenantSpec("chat", weight=3.0, slo_class="interactive",
                          share=3.0, max_tokens=(8, 12)),
               TenantSpec("jobs", weight=1.0, slo_class="batch",
                          share=3.0, max_tokens=(12, 16)))
    rows = generate_trace(12, arrival="poisson", rate=2.0, tenants=tenants,
                          prompt_len=12, max_tokens=6, seed=0)

    # trace I/O round-trip: what we save is what any replayer loads
    with tempfile.TemporaryDirectory() as td:
        path = pathlib.Path(td) / "trace.jsonl"
        save_trace(path, rows, meta={"smoke": True})
        loaded = load_trace(str(path))
    assert loaded == rows, "save/load round-trip changed the trace"
    assert trace_id(loaded) == trace_id(rows)

    # replay determinism: streams AND summaries, bit for bit
    streams_a, summary_a = replay(rows, SLO_TTL_MS)
    streams_b, summary_b = replay(rows, SLO_TTL_MS)
    assert streams_a == streams_b, "replay token streams diverged"
    dump = lambda s: json.dumps(s, sort_keys=True, default=float)  # noqa
    assert dump(summary_a) == dump(summary_b), "replay summaries diverged"
    assert summary_a["trace_id"] == trace_id(rows)

    # governor acceptance vs the governor-off replay of the same trace.
    # The run-wide p95 is dominated by the (identical) pre-shed warm-up
    # samples the estimator needs before it may act, so the discriminator
    # is the interactive TTL *mean*: shedding batch slots must lower it.
    _, summary_off = replay(rows, 0.0)
    on_ttl = summary_a["per_class"]["interactive"]["ttl_s"]
    off_ttl = summary_off["per_class"]["interactive"]["ttl_s"]
    assert summary_off["governor_sheds"] == 0, summary_off
    assert summary_a["governor_sheds"] >= 1, \
        f"governor never shed under saturation: {summary_a}"
    assert summary_a["preempt_spills"] >= summary_a["governor_sheds"], \
        "sheds must route through the spill path"
    assert summary_a["resume_reprefill_chunks"] == 0, \
        f"shed work re-prefilled on resume: {summary_a}"
    assert on_ttl["mean"] < off_ttl["mean"], (
        f"governor did not improve interactive TTL: "
        f"on={on_ttl} off={off_ttl}")
    # graceful degradation: shed batch work still completes in full
    # (delayed, restored from the host tier — never discarded)
    assert (summary_a["per_class"]["batch"]["n_tokens"]
            == summary_off["per_class"]["batch"]["n_tokens"]), \
        (summary_a["per_class"], summary_off["per_class"])
    assert 0 < summary_a["goodput_tok_s"] <= summary_a["throughput_tok_s"]
    assert 0 <= summary_a["ttl_target_miss_rate"] <= 1

    # the bench carries the multi-tenant columns these runs produce
    from benchmarks.bench_serving import ROW_SCHEMA
    need = {"tenant", "slo_class", "goodput_tok_s", "ttl_target_miss_rate",
            "slo_ttl_ms", "governor_sheds", "trace"}
    assert need <= set(ROW_SCHEMA), sorted(need - set(ROW_SCHEMA))

    print(f"[trace_smoke] trace {summary_a['trace_id']}: "
          f"{len(streams_a)} requests replay-deterministic; governor shed "
          f"{summary_a['governor_sheds']} batch slot(s) to spill "
          f"(0 re-prefill chunks), interactive ttl mean "
          f"{on_ttl['mean'] * 1e3:.2f}ms vs {off_ttl['mean'] * 1e3:.2f}ms "
          f"ungoverned (target {SLO_TTL_MS}ms)")
    print("[trace_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
