#!/usr/bin/env python
"""CI chaos smoke for the host KV tier (scripts/ci.sh).

Drives the same preempt-mid-decode workload through the paged engine five
ways — no faults, then each injected failure mode at probability 1.0
(restore_fail, corrupt, store_full, delay) — and asserts the PR's
acceptance criteria end to end:

  * token streams are identical to a never-preempted baseline in EVERY
    run: spill/restore is exact bytes, and every injected fault degrades
    to the re-prefill fallback, never to divergent tokens;
  * without faults the resume runs **zero re-prefill chunks** (the
    preempted request never re-enters PREFILL) and the restore counter
    ticks;
  * with faults the matching counter ticks (restores_failed /
    checksum_mismatches / store_full+preempt_drops) and the fallback is
    counted in ``resume_reprefill_chunks``;
  * the injected delay holds only the restoring slot (other streams keep
    decoding) and still commits with zero re-prefill chunks.

Run directly:  PYTHONPATH=src python scripts/chaos_smoke.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402

from repro.configs import get_config                           # noqa: E402
from repro.core.sharding import HelixConfig                    # noqa: E402
from repro.models.model_zoo import (build_serve_step,          # noqa: E402
                                    make_chunk_prefill_step,
                                    make_prefill_step)
from repro.models.transformer import init_params               # noqa: E402
from repro.serving import DecodeEngine, Request                # noqa: E402
from repro.serving.faults import FaultPlan                     # noqa: E402
from repro.utils import make_mesh, set_mesh                    # noqa: E402

CHUNK = 4
PROMPT_LENS = (24, 13, 9)
MAX_NEW = 8
PREEMPT_AFTER = 3        # preempt r0 once it has decoded this many tokens


def _engine(cfg, params, mesh, *, host_pages, fault_plan):
    hx = HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16,
                     paged_kv=True)
    with set_mesh(mesh):
        serve = build_serve_step(cfg, mesh, hx)
        prefill = make_prefill_step(cfg, mesh, hx)
        cs = make_chunk_prefill_step(cfg, mesh, hx)
        return DecodeEngine(cfg, params, serve, prefill, max_batch=3,
                            max_seq=96, hx=hx, chunk_tokens=CHUNK,
                            chunk_prefill_step=cs, tp_width=1,
                            host_pages=host_pages, fault_plan=fault_plan)


def run(cfg, params, mesh, prompts, *, host_pages=0, fault_plan=None,
        preempt=False):
    """One engine run; returns (streams, summary, post_preempt_prefills)."""
    eng = _engine(cfg, params, mesh, host_pages=host_pages,
                  fault_plan=fault_plan)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)]
    preempted = False
    post_prefills = 0
    with set_mesh(mesh):
        for r in reqs:
            eng.submit(r)
        for _ in range(10_000):
            if all(r.done for r in reqs):
                break
            eng.step()
            if (preempt and not preempted
                    and len(reqs[0].out_tokens) >= PREEMPT_AFTER
                    and reqs[0].state == "decode"):
                eng.preempt(0)
                preempted = True
            if preempted:
                post_prefills += reqs[0].state == "prefill"
    assert all(r.done for r in reqs), [r.state for r in reqs]
    assert not preempt or preempted, "preempt trigger never fired"
    assert eng.pool.free_count == eng.pool.capacity        # pool drained
    if eng.store is not None:
        eng.store.check_invariants()
    return ([tuple(r.out_tokens) for r in reqs],
            eng.metrics.summary(), post_prefills)


def main() -> int:
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in PROMPT_LENS]

    base, base_sum, _ = run(cfg, params, mesh, prompts)
    assert base_sum["preempts"] == 0

    # healthy tier: spill -> restore, zero re-prefill chunks, same stream
    ok, ok_sum, ok_pf = run(cfg, params, mesh, prompts,
                            host_pages=64, preempt=True)
    assert ok == base, f"healthy spill/restore diverged:\n{base}\n{ok}"
    assert ok_sum["preempt_spills"] == 1 and ok_sum["preempt_drops"] == 0, \
        ok_sum
    assert ok_sum["restores"] >= 1 and ok_sum["restores_failed"] == 0, ok_sum
    assert ok_sum["resume_reprefill_chunks"] == 0, ok_sum
    assert ok_pf == 0, f"resumed request re-entered PREFILL ({ok_pf} steps)"

    # no tier at all: the drop/re-prefill fallback, still bit-exact
    drop, drop_sum, drop_pf = run(cfg, params, mesh, prompts, preempt=True)
    assert drop == base, "no-tier re-prefill fallback diverged"
    assert drop_sum["preempt_drops"] == 1 and drop_sum["spills"] == 0, \
        drop_sum
    assert drop_sum["resume_reprefill_chunks"] > 0 and drop_pf > 0, drop_sum

    # every injected fault: stream stays identical, its counter ticks,
    # and the fallback (when one happens) is counted
    matrix = {
        "restore_fail": FaultPlan(seed=1, restore_fail=1.0),
        "corrupt": FaultPlan(seed=2, corrupt=1.0),
        "store_full": FaultPlan(seed=3, store_full=1.0),
        "delay": FaultPlan(seed=4, delay=1.0, delay_steps=3),
    }
    counters = {}
    for name, plan in matrix.items():
        s, summ, pf = run(cfg, params, mesh, prompts,
                          host_pages=64, fault_plan=plan, preempt=True)
        assert s == base, f"fault {name!r} diverged the stream"
        counters[name] = summ
        if name == "restore_fail":
            assert summ["restores_failed"] >= 1, summ
            assert summ["resume_reprefill_chunks"] > 0 and pf > 0, summ
        elif name == "corrupt":
            assert summ["checksum_mismatches"] >= 1, summ
            assert summ["restores_failed"] >= 1, summ
            assert summ["resume_reprefill_chunks"] > 0 and pf > 0, summ
        elif name == "store_full":
            # the save itself is refused: the preemption degrades to the
            # drop path and resume re-prefills
            assert summ["spills"] == 0 and summ["preempt_drops"] == 1, summ
            assert summ["resume_reprefill_chunks"] > 0 and pf > 0, summ
        elif name == "delay":
            # slower host tier, same outcome: restore commits late but
            # still with zero re-prefill chunks
            assert summ["restores"] >= 1 and summ["restores_failed"] == 0, \
                summ
            assert summ["resume_reprefill_chunks"] == 0 and pf == 0, summ

    print(f"[chaos_smoke] streams identical across baseline + healthy "
          f"spill/restore + no-tier drop + {len(matrix)} fault runs; "
          f"healthy resume re-prefilled 0 chunks (restores="
          f"{ok_sum['restores']}); fallbacks counted: "
          + ", ".join(f"{k}={counters[k]['resume_reprefill_chunks']}"
                      for k in matrix))
    print("[chaos_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
