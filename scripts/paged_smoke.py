#!/usr/bin/env python
"""CI smoke for the shared-pool paged KV cache (scripts/ci.sh).

Runs the same synthetic workload through ``serve_demo`` twice — fixed
per-slot cache vs ``--paged-kv`` — and asserts the per-request token
streams are **identical** (the paged pool is a page-granularity permutation
of the fixed layout; see docs/serving.md).  Also sanity-checks the pool
health numbers the serving bench records.

Run directly:  PYTHONPATH=src python scripts/paged_smoke.py
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.launch.serve import serve_demo                      # noqa: E402


def streams(paged: bool, chunk: int):
    finished, summary = serve_demo(
        "granite-3-2b", reduced=True, n_requests=5, prompt_len=12,
        max_new=4, max_batch=2, chunk_tokens=chunk,
        paged_kv=True if paged else None, log=lambda s: None)
    return ({r.rid: tuple(r.out_tokens) for r in finished}, summary)


def main() -> int:
    for chunk in (0, 4):
        fixed, _ = streams(False, chunk)
        paged, summary = streams(True, chunk)
        assert fixed == paged, (
            f"paged vs fixed token streams diverged (chunk={chunk}):\n"
            f"  fixed: {fixed}\n  paged: {paged}")
        assert summary["paged_kv"] is True
        assert 0 < summary["pool_occupancy_peak"] <= 1, summary
        print(f"[paged_smoke] chunk={chunk}: paged == fixed token streams "
              f"({len(fixed)} requests), pool peak occupancy "
              f"{summary['pool_occupancy_peak']:.2f}, frag "
              f"{summary['pool_frag_mean']:.2f}")
    print("[paged_smoke] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
