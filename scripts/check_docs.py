#!/usr/bin/env python
"""CI docs check (scripts/ci.sh): fails when

1. a public symbol of a kernel family's ``ops.py`` (or a listed public-API
   entry point) lacks a docstring, or
2. a ``--flag`` shown in a README.md code block for one of the repo's CLIs
   doesn't exist in that CLI's argparse any more (README drift).

Run directly:  PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import importlib
import inspect
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

ERRORS: list[str] = []


def err(msg: str) -> None:
    ERRORS.append(msg)


# ------------------------------------------------------------- docstrings
def _check_doc(qualname: str, obj) -> None:
    doc = inspect.getdoc(obj)
    if not doc or not doc.strip():
        err(f"missing docstring: {qualname}")


def check_docstrings() -> None:
    from repro.kernels import registry

    # every kernel family's ops.py public surface
    for fam in registry.FAMILIES.values():
        mod_name = fam.kernel.split(":")[0]
        mod = importlib.import_module(mod_name)
        _check_doc(mod_name, mod)
        for name, obj in vars(mod).items():
            if name.startswith("_") or not callable(obj):
                continue
            if getattr(obj, "__wrapped__", None) is not None:
                obj = obj.__wrapped__          # unwrap functools/jax.jit
            if getattr(obj, "__module__", mod_name) != mod_name:
                continue                       # re-exports checked at home
            _check_doc(f"{mod_name}.{name}", obj)

    # the documented public API entry points
    public = [
        ("repro.core.sharding", "HelixConfig"),
        ("repro.core.helix", "helix_attention"),
        ("repro.core.helix", "append_kv"),
        ("repro.core.helix", "fuse_append_applicable"),
        ("repro.models.decode_model", "build_serve_step"),
        ("repro.models.decode_model", "build_serve_multistep"),
        ("repro.models.model_zoo", "make_train_step"),
        ("repro.models.model_zoo", "make_prefill_step"),
        ("repro.models.model_zoo", "make_chunk_prefill_step"),
        ("repro.models.model_zoo", "init_prefill_buffers"),
        ("repro.models.model_zoo", "finalize_chunked_prefill"),
        ("repro.models.decode_model", "prepare_decode_params"),
        ("repro.models.attention", "prefill_attention"),
        ("repro.models.attention", "decode_attention"),
        ("repro.serving.engine", "DecodeEngine"),
        ("repro.serving.scheduler", "Scheduler"),
        ("repro.serving.scheduler", "Request"),
        ("repro.serving.scheduler", "PrefixIndex"),
        ("repro.serving.scheduler", "TenantConfig"),
        ("repro.serving.metrics", "EngineMetrics"),
        ("repro.serving.sampling", "SamplingParams"),
        ("repro.serving.sampling", "sample_tokens"),
        ("repro.serving.sampling", "sample_oracle"),
        ("repro.serving.sampling", "request_seed"),
        ("repro.serving.sampling", "gumbel_noise"),
        ("repro.serving.metrics", "VirtualClock"),
        ("repro.serving.governor", "TTLGovernor"),
        ("repro.serving.governor", "GovernorConfig"),
        ("repro.serving.workload", "TraceRow"),
        ("repro.serving.workload", "TenantSpec"),
        ("repro.serving.workload", "parse_tenants"),
        ("repro.serving.workload", "generate_trace"),
        ("repro.serving.workload", "poisson_arrival_steps"),
        ("repro.serving.workload", "bursty_arrival_steps"),
        ("repro.serving.workload", "save_trace"),
        ("repro.serving.workload", "load_trace"),
        ("repro.serving.workload", "trace_id"),
        ("repro.serving.workload", "prompt_tokens"),
        ("repro.serving.workload", "requests_from_trace"),
        ("repro.serving.pool", "BlockAllocator"),
        ("repro.serving.pool", "pages_for"),
        ("repro.serving.tier", "HostPageStore"),
        ("repro.serving.faults", "FaultPlan"),
        ("repro.serving.faults", "FaultInjector"),
        ("repro.core.kvcache", "quantize_decode_state"),
        ("repro.core.kvcache", "cache_to_pages"),
        ("repro.core.kvcache", "pages_to_cache"),
        ("repro.core.kvcache", "gather_pages"),
        ("repro.core.kvcache", "state_to_paged"),
        ("repro.core.kvcache", "page_positions"),
        ("repro.core.kvcache", "gather_pool_pages"),
        ("repro.core.kvcache", "scatter_pool_pages"),
        ("repro.core.helix", "paged_slot_of_position"),
        ("repro.kernels.pruning", "table_block"),
        ("repro.kernels.pruning", "span_clamp"),
        ("repro.kernels.registry", "KernelFamily"),
        ("repro.kernels.registry", "backend_table"),
        ("repro.kernels.registry", "contract_suite"),
        ("repro.kernels.contract", "KernelContract"),
        ("repro.kernels.contract", "Operand"),
        ("repro.analysis.findings", "Finding"),
        ("repro.analysis.findings", "Report"),
        ("repro.analysis.findings", "load_baseline"),
        ("repro.analysis.index_audit", "audit_contract"),
        ("repro.analysis.index_audit", "run_index_audit"),
        ("repro.analysis.index_audit", "eval_index_table"),
        ("repro.analysis.jaxpr_audit", "audit_step_fn"),
        ("repro.analysis.jaxpr_audit", "collect_collectives"),
        ("repro.analysis.jaxpr_audit", "run_jaxpr_audit"),
        ("repro.analysis.host_sync", "lint_source"),
        ("repro.analysis.host_sync", "lint_paths"),
    ]
    for mod_name, sym in public:
        mod = importlib.import_module(mod_name)
        obj = getattr(mod, sym, None)
        if obj is None:
            err(f"public symbol vanished: {mod_name}.{sym}")
            continue
        _check_doc(f"{mod_name}.{sym}", obj)
        if inspect.isclass(obj):
            for mname, meth in vars(obj).items():
                if not mname.startswith("_") and callable(meth):
                    _check_doc(f"{mod_name}.{sym}.{mname}", meth)


# ------------------------------------------------------------ README drift
# CLI target -> source file whose argparse defines its flags
CLI_SOURCES = {
    "repro.launch.serve": ROOT / "src/repro/launch/serve.py",
    "repro.launch.train": ROOT / "src/repro/launch/train.py",
    "bench_decode_kernel.py": ROOT / "benchmarks/bench_decode_kernel.py",
    "bench_serving.py": ROOT / "benchmarks/bench_serving.py",
    "analyze.py": ROOT / "scripts/analyze.py",
}
FLAG_RE = re.compile(r"add_argument\(\s*[\"'](--[A-Za-z0-9-]+)[\"']")


def _argparse_flags(path: pathlib.Path) -> set[str]:
    return set(FLAG_RE.findall(path.read_text()))


def check_readme_flags() -> None:
    readme = (ROOT / "README.md").read_text()
    blocks = re.findall(r"```(?:bash|sh|shell)?\n(.*?)```", readme, re.S)
    for block in blocks:
        targets = [t for t in CLI_SOURCES if t in block]
        if not targets:
            continue
        known = set().union(*(_argparse_flags(CLI_SOURCES[t])
                              for t in targets))
        used = set(re.findall(r"(--[A-Za-z0-9][A-Za-z0-9-]*)", block))
        for flag in sorted(used - known):
            err(f"README flag {flag} not found in argparse of "
                f"{' / '.join(targets)} (drifted?)")


def main() -> int:
    check_docstrings()
    check_readme_flags()
    if ERRORS:
        print("[check_docs] FAILED:")
        for e in ERRORS:
            print(f"  - {e}")
        return 1
    print("[check_docs] OK (docstrings + README flags)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
