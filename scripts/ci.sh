#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps when the environment allows, then
# run the full suite.  A missing dev dep (e.g. hypothesis in an air-gapped
# container) must degrade to skipped property tests, never to collection
# errors — scripts/ci.sh exists so that regression can't land silently.
set -euo pipefail
cd "$(dirname "$0")/.."

if python -m pip install -q -r requirements-dev.txt 2>/dev/null; then
    echo "[ci] dev deps installed"
else
    echo "[ci] WARNING: pip install failed (offline?); property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q "$@"
