#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps when the environment allows, then
# run the docs/backends smoke checks and the full suite.  A missing dev dep
# (e.g. hypothesis in an air-gapped container) must degrade to skipped
# property tests, never to collection errors — scripts/ci.sh exists so that
# regression can't land silently.
set -euo pipefail
cd "$(dirname "$0")/.."

if python -m pip install -q -r requirements-dev.txt 2>/dev/null; then
    echo "[ci] dev deps installed"
else
    echo "[ci] WARNING: pip install failed (offline?); property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# docs check: public ops.py/API docstrings + README CLI-flag drift
python scripts/check_docs.py

# kernel-registry smoke: imports every family and prints the backend matrix
python -m repro.launch.serve --list-backends

# static contract checker: index-space audit of every kernel family's
# contracts, jaxpr collective/dtype audit of the serving step graphs, and
# the host-sync lint — strict: any unbaselined finding fails the build
python scripts/analyze.py --strict
python scripts/check_analysis_schema.py ANALYSIS.json

# block-pruning smoke: pruning shrinks visited K/V blocks at short lengths
# (and to the causal triangle in prefill) while outputs stay bit-exact
python scripts/prune_smoke.py

# paged-KV smoke: the shared-pool paged cache must produce token streams
# identical to the fixed per-slot layout (one-shot + chunked prefill)
python scripts/paged_smoke.py

# prefix-sharing smoke: refcounted copy-on-write page sharing + grouped
# shared-prefix decode must keep token streams identical to the unshared
# run, prefill only the suffix on a hit, and read shared prefix pages once
# per group (accounting bytes check)
python scripts/prefix_smoke.py

# host-tier chaos smoke: preempt/spill/restore must keep token streams
# identical to a never-preempted baseline with zero re-prefill chunks, and
# every injected fault (restore_fail / corrupt / store_full / delay) must
# degrade to the counted re-prefill fallback, never to divergent tokens
python scripts/chaos_smoke.py

# multi-tenant SLO smoke: a 2-tenant interactive+batch trace must replay
# bit-identically (streams AND metrics summaries, virtual clock), and the
# TTL governor must shed batch slots through the spill path (zero
# re-prefill) while improving the interactive TTL over the ungoverned
# replay of the same trace
python scripts/trace_smoke.py

# windowed-decode smoke: --decode-window N token streams must be
# bit-identical to single-step across window sizes (fixed AND
# paged+prefix+tier configs, top-p sampling) while blocking host syncs
# drop to exactly 1/N per decoded token
python scripts/decode_window_smoke.py

# serving smoke: scheduler-driven engine with chunked prefill under synthetic
# Poisson traffic; writes BENCH_serving.json (incl. a --paged-kv row with
# pool occupancy/fragmentation columns) whose schema is then asserted
# (perf rows can't silently drift)
python benchmarks/bench_serving.py --smoke
python scripts/check_bench_schema.py BENCH_serving.json

# full suite (tests/serving + tests/kernels + tests/models + distributed ...)
python -m pytest -q "$@"
