#!/usr/bin/env python
"""CI schema check for the machine-readable static-analysis report.

Asserts ``ANALYSIS.json`` (scripts/analyze.py) carries every field
downstream tooling keys on — check ids from the catalog, typed finding
fields, a consistent summary — so a refactor of the analyzer can't
silently drop a column or invent an untracked check id.

Run directly:  python scripts/check_analysis_schema.py [ANALYSIS.json]
"""
from __future__ import annotations

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

META_KEYS = {"generated_by", "strict", "baseline", "checks_run"}
SUMMARY_KEYS = {"total", "errors", "warnings", "suppressed"}
CHECK_ID_RE = re.compile(r"^[a-z]+\.[a-z-]+$")
LAYERS = {"index", "jaxpr", "sync"}


def check(path: pathlib.Path) -> list[str]:
    # single source of truth for the per-finding schema + check catalog
    from repro.analysis.findings import CHECKS, FINDING_FIELDS, SEVERITIES
    errors: list[str] = []
    data = json.loads(path.read_text())

    meta = data.get("meta", {})
    missing_meta = META_KEYS - set(meta)
    if missing_meta:
        errors.append(f"meta missing keys: {sorted(missing_meta)}")
    run = set(meta.get("checks_run", []))
    if not run <= LAYERS:
        errors.append(f"meta.checks_run has unknown layers: "
                      f"{sorted(run - LAYERS)}")
    if not run:
        errors.append("meta.checks_run empty — no layer ran")

    summary = data.get("summary", {})
    missing_sum = SUMMARY_KEYS - set(summary)
    if missing_sum:
        errors.append(f"summary missing keys: {sorted(missing_sum)}")

    findings = data.get("findings", None)
    if findings is None:
        errors.append("no findings list")
        return errors
    n_sup = 0
    for i, f in enumerate(findings):
        for key, typ in FINDING_FIELDS.items():
            if key not in f:
                errors.append(f"finding {i}: missing {key!r}")
            elif not isinstance(f[key], typ):
                errors.append(f"finding {i}: {key!r} is "
                              f"{type(f[key]).__name__}, want {typ.__name__}")
        cid = f.get("check", "")
        if not CHECK_ID_RE.match(cid):
            errors.append(f"finding {i}: malformed check id {cid!r}")
        elif cid not in CHECKS:
            errors.append(f"finding {i}: check id {cid!r} not in catalog")
        if f.get("severity") not in SEVERITIES:
            errors.append(f"finding {i}: bad severity {f.get('severity')!r}")
        n_sup += bool(f.get("suppressed"))
    if summary.get("total") != len(findings):
        errors.append(f"summary.total {summary.get('total')} != "
                      f"{len(findings)} findings")
    if summary.get("suppressed") != n_sup:
        errors.append(f"summary.suppressed {summary.get('suppressed')} != "
                      f"{n_sup} suppressed findings")
    return errors


def main() -> int:
    path = pathlib.Path(sys.argv[1] if len(sys.argv) > 1
                        else ROOT / "ANALYSIS.json")
    if not path.exists():
        print(f"[check_analysis_schema] {path} missing "
              "(run scripts/analyze.py first)")
        return 1
    errors = check(path)
    if errors:
        print(f"[check_analysis_schema] FAILED for {path}:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"[check_analysis_schema] OK ({path})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
