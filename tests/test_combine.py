"""Property tests for the exact LSE combine (the Helix correctness core)."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.testing import optional_hypothesis

# degrades to skipped property tests when hypothesis is not installed
given, settings, st = optional_hypothesis()

from repro.core.combine import (combine_fragments, combine_partials,
                                combine_two, fragment_head_index)
from repro.utils import NEG_INF


def _softmax_attn(scores, v):
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def _shard(scores, v, lo, hi):
    """partial attention over key-slice [lo, hi) + lse."""
    s = scores[..., lo:hi]
    m = s.max(-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    out = (p @ v[lo:hi]) / l[..., None]
    return out, m + jnp.log(l)


@settings(max_examples=30, deadline=None)
@given(q=st.integers(1, 6), s=st.integers(2, 64), hsz=st.sampled_from([4, 8]),
       r=st.integers(2, 4), seed=st.integers(0, 2 ** 16))
def test_combine_equals_unsharded_softmax(q, s, hsz, r, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.standard_normal((q, s)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((s, hsz)), jnp.float32)
    cuts = sorted(rng.choice(np.arange(1, s), size=min(r - 1, s - 1),
                             replace=False).tolist())
    bounds = [0] + cuts + [s]
    outs, lses = zip(*[_shard(scores, v, lo, hi)
                       for lo, hi in zip(bounds[:-1], bounds[1:])])
    got, _ = combine_partials(jnp.stack(outs), jnp.stack(lses))
    want = _softmax_attn(scores, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_combine_permutation_invariant(seed):
    rng = np.random.default_rng(seed)
    outs = jnp.asarray(rng.standard_normal((4, 3, 8)), jnp.float32)
    lses = jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)
    a, _ = combine_partials(outs, lses)
    perm = rng.permutation(4)
    b, _ = combine_partials(outs[perm], lses[perm])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_combine_two_associative(seed):
    rng = np.random.default_rng(seed)
    o = [jnp.asarray(rng.standard_normal((2, 4)), jnp.float32)
         for _ in range(3)]
    l = [jnp.asarray(rng.standard_normal((2,)), jnp.float32)
         for _ in range(3)]
    ab, lab = combine_two(o[0], l[0], o[1], l[1])
    left, _ = combine_two(ab, lab, o[2], l[2])
    bc, lbc = combine_two(o[1], l[1], o[2], l[2])
    right, _ = combine_two(o[0], l[0], bc, lbc)
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=1e-5, atol=1e-5)


def test_empty_shards_are_ignored():
    outs = jnp.stack([jnp.ones((2, 4)), jnp.full((2, 4), 7.0)])
    lses = jnp.stack([jnp.zeros((2,)), jnp.full((2,), NEG_INF)])
    got, lse = combine_partials(outs, lses)
    np.testing.assert_allclose(np.asarray(got), np.ones((2, 4)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.zeros((2,)), atol=1e-6)


def test_all_empty_is_zero_neginf():
    outs = jnp.zeros((3, 2, 4))
    lses = jnp.full((3, 2), NEG_INF)
    got, lse = combine_partials(outs, lses)
    assert np.all(np.asarray(got) == 0)
    assert np.all(np.asarray(lse) == NEG_INF)


def test_fragment_combine_matches_full_combine():
    """Slicing the flattened head dim (incl. head-straddling cuts) is exact."""
    rng = np.random.default_rng(0)
    r, b, qh, hsz, nsl = 3, 2, 4, 8, 8        # slice = 4 elements < hsz
    outs = jnp.asarray(rng.standard_normal((r, b, qh, hsz)), jnp.float32)
    lses = jnp.asarray(rng.standard_normal((r, b, qh)), jnp.float32)
    full, _ = combine_partials(outs, lses)
    flat = outs.reshape(r, b, qh * hsz)
    table = fragment_head_index(qh, hsz, nsl)
    sl = qh * hsz // nsl
    for i in range(nsl):
        frag = combine_fragments(flat[..., i * sl:(i + 1) * sl], lses,
                                 table[i])
        np.testing.assert_allclose(
            np.asarray(frag),
            np.asarray(full.reshape(b, qh * hsz)[:, i * sl:(i + 1) * sl]),
            rtol=1e-5, atol=1e-5)
