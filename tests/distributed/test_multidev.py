"""Multi-device tests, run in subprocesses so the 8-fake-device XLA flag
never leaks into the single-device test session (the dry-run spec mandates
the flag must NOT be set globally)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPTS = Path(__file__).parent / "scripts"
SRC = str(Path(__file__).resolve().parents[2] / "src")


def _run(script: str, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    p = subprocess.run([sys.executable, str(SCRIPTS / script)], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if p.returncode != 0:
        raise AssertionError(
            f"{script} failed\nstdout:\n{p.stdout}\nstderr:\n{p.stderr}")
    return p.stdout


def test_helix_attention_exactness():
    out = _run("helix_exact.py")
    assert "ALL OK" in out


def test_e2e_prefill_decode_equivalence():
    out = _run("e2e_decode.py")
    assert "ALL OK" in out


def test_sharded_train_matches_single_device():
    out = _run("train_parity.py")
    assert "ALL OK" in out


def test_compressed_pod_allreduce():
    out = _run("pod_compression.py")
    assert "ALL OK" in out


def test_elastic_checkpoint_reshard():
    out = _run("elastic_restore.py")
    assert "ALL OK" in out


def test_perf_variants_correct():
    out = _run("perf_variants.py")
    assert "ALL OK" in out
