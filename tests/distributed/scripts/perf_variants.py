"""§Perf variant correctness: qkv_shard is EXACT; int8 KV cache is within
quantization tolerance of the bf16-cache decode."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.kvcache import init_decode_state
from repro.core.sharding import default_helix_config
from repro.models.model_zoo import build_serve_step, make_prefill_step
from repro.models.transformer import init_params
from repro.utils import make_mesh, set_mesh

mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_config("granite-3-2b").reduced()
hx0 = default_helix_config(cfg, mesh)
params = init_params(cfg, jax.random.PRNGKey(0))
B, T = 4, 24
tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T + 4), 0, cfg.vocab)

prefill = make_prefill_step(cfg, mesh, hx0, s_cap=128)
with set_mesh(mesh):
    _, state0 = jax.jit(prefill)(params, {"tokens": tokens[:, :T]})


def run_decode(hx, state, n=4):
    serve = build_serve_step(cfg, mesh, hx, hopb_chunks=2, return_logits=True)
    logits_all = []
    with set_mesh(mesh):
        for i in range(n):
            (nt, lg), state = jax.jit(serve)(params, state, tokens[:, T + i])
            logits_all.append(lg)
    return jnp.stack(logits_all)


base = run_decode(hx0, dict(state0))

# --- qkv_shard: exact (same math, different weight layout) ---
hx_q = dataclasses.replace(hx0, qkv_shard=True)
got = run_decode(hx_q, dict(state0))
err = float(jnp.max(jnp.abs(got - base)))
assert err < 1e-4, err
print(f"qkv_shard exact: max |delta logits| = {err:.2e}")

# --- int8 KV cache: small quantization error only ---
hx_k = dataclasses.replace(hx0, kv_cache_bits=8)
st8 = init_decode_state(cfg, B, 128, hx0.kvp(mesh), dtype=jnp.float32)
# quantize the prefilled cache into the int8 state
kf = state0["kcache"].astype(jnp.float32)
vf = state0["vcache"].astype(jnp.float32)
ks = jnp.maximum(jnp.max(jnp.abs(kf), -1) / 127.0, 1e-30)
vs = jnp.maximum(jnp.max(jnp.abs(vf), -1) / 127.0, 1e-30)
st8 = {"total_len": state0["total_len"],
       "kcache": jnp.clip(jnp.round(kf / ks[..., None]), -127, 127
                          ).astype(jnp.int8),
       "vcache": jnp.clip(jnp.round(vf / vs[..., None]), -127, 127
                          ).astype(jnp.int8),
       "kscale": ks, "vscale": vs}
got8 = run_decode(hx_k, st8)
# compare top-1 choices + logit band
agree = float(jnp.mean(jnp.argmax(got8[..., :cfg.vocab], -1)
                       == jnp.argmax(base[..., :cfg.vocab], -1)))
err8 = float(jnp.max(jnp.abs(got8 - base)))
print(f"kv8: top-1 agreement {agree*100:.0f}%, max |delta logits| {err8:.3f}")
assert agree >= 0.9 and err8 < 0.5, (agree, err8)
print("ALL OK")
