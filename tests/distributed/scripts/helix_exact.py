import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.sharding import HelixConfig
from repro.core.helix import helix_attention, append_kv, rr_slot_of_position, prefill_to_rr_layout
from repro.kernels.flash_decode.ref import flash_decode_ref, shard_positions
from repro.utils import make_mesh, set_mesh

mesh = make_mesh((4, 2), ("data", "model"))

# ---- pure-KVP mode: KVP=8 over both axes ----
hx = HelixConfig(kvp_axes=("data", "model"), tpa_axis=None)
B, QH, KH, HSZ, KVP, RR = 4, 8, 2, 64, 8, 16
S_CAP = KVP * 32  # 32 local slots per rank
total_len = 200
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((B, QH, HSZ), np.float32))

# build global contiguous KV then convert to rr layout
kg = jnp.asarray(rng.standard_normal((B, KH, S_CAP, HSZ), np.float32))
vg = jnp.asarray(rng.standard_normal((B, KH, S_CAP, HSZ), np.float32))
k_rr = prefill_to_rr_layout(kg, KVP, RR)
v_rr = prefill_to_rr_layout(vg, KVP, RR)

with set_mesh(mesh):
    out = jax.jit(lambda q, k, v: helix_attention(mesh, hx, q, k, v, total_len))(q, k_rr, v_rr)
ref, _ = flash_decode_ref(q, kg[:, :, :total_len], vg[:, :, :total_len], total_len, 0, kvp=1)
ref_flat = ref.reshape(B, QH * HSZ)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref_flat), rtol=2e-5, atol=2e-5)
print("pure-KVP helix == unsharded ref: OK")

# ---- HOP-B chunked gives identical results ----
with set_mesh(mesh):
    out2 = jax.jit(lambda q, k, v: helix_attention(mesh, hx, q, k, v, total_len, hopb_chunks=2))(q, k_rr, v_rr)
np.testing.assert_allclose(np.asarray(out2), np.asarray(ref_flat), rtol=2e-5, atol=2e-5)
print("HOP-B chunked == ref: OK")

# ---- 2-D mode: KVP=4 (data), TPA=2 (model) ----
hx2 = HelixConfig(kvp_axes=("data",), tpa_axis="model")
with set_mesh(mesh):
    k_rr2 = prefill_to_rr_layout(kg, 4, RR)
    v_rr2 = prefill_to_rr_layout(vg, 4, RR)
    out3 = jax.jit(lambda q, k, v: helix_attention(mesh, hx2, q, k, v, total_len))(q, k_rr2, v_rr2)
np.testing.assert_allclose(np.asarray(out3), np.asarray(ref_flat), rtol=2e-5, atol=2e-5)
print("2-D (KVP x TPA) helix == ref: OK")

# ---- per-request lengths ----
tls = jnp.asarray([200, 37, 150, 9], jnp.int32)
with set_mesh(mesh):
    out4 = jax.jit(lambda q, k, v: helix_attention(mesh, hx, q, k, v, tls))(q, k_rr, v_rr)
for i, tl in enumerate([200, 37, 150, 9]):
    r, _ = flash_decode_ref(q[i:i+1], kg[i:i+1, :, :tl], vg[i:i+1, :, :tl], tl, 0, kvp=1)
    np.testing.assert_allclose(np.asarray(out4[i]), np.asarray(r.reshape(QH*HSZ)), rtol=2e-5, atol=2e-5)
print("per-request total_len: OK")

# ---- pallas-interpret backend == ref through the all-to-all + combine ----
import dataclasses
hx_pl = dataclasses.replace(hx, attn_backend="pallas-interpret")
hx2_pl = dataclasses.replace(hx2, attn_backend="pallas-interpret")
with set_mesh(mesh):
    pl1 = jax.jit(lambda q, k, v: helix_attention(mesh, hx_pl, q, k, v,
                                                  total_len))(q, k_rr, v_rr)
    pl2 = jax.jit(lambda q, k, v: helix_attention(mesh, hx_pl, q, k, v,
                                                  tls))(q, k_rr, v_rr)
    pl3 = jax.jit(lambda q, k, v: helix_attention(mesh, hx2_pl, q, k, v,
                                                  total_len))(q, k_rr2, v_rr2)
    pl4 = jax.jit(lambda q, k, v: helix_attention(mesh, hx_pl, q, k, v,
                                                  total_len, window=64))(
                                                      q, k_rr, v_rr)
    rf4 = jax.jit(lambda q, k, v: helix_attention(mesh, hx, q, k, v,
                                                  total_len, window=64))(
                                                      q, k_rr, v_rr)
np.testing.assert_allclose(np.asarray(pl1), np.asarray(out), rtol=2e-6,
                           atol=2e-6)
np.testing.assert_allclose(np.asarray(pl2), np.asarray(out4), rtol=2e-6,
                           atol=2e-6)
np.testing.assert_allclose(np.asarray(pl3), np.asarray(out3), rtol=2e-6,
                           atol=2e-6)
np.testing.assert_allclose(np.asarray(pl4), np.asarray(rf4), rtol=2e-6,
                           atol=2e-6)
print("pallas-interpret backend == ref (scalar, [B] tl, 2-D, windowed): OK")

# ---- block pruning == dense masked sweep through the 8-way shard_map ----
hx_nopr = dataclasses.replace(hx_pl, prune_blocks=False)
with set_mesh(mesh):
    for tl_case, win in ((total_len, 0), (total_len, 64), (tls, 64)):
        pr = jax.jit(lambda q, k, v: helix_attention(
            mesh, hx_pl, q, k, v, tl_case, window=win))(q, k_rr, v_rr)
        de = jax.jit(lambda q, k, v: helix_attention(
            mesh, hx_nopr, q, k, v, tl_case, window=win))(q, k_rr, v_rr)
        np.testing.assert_array_equal(np.asarray(pr), np.asarray(de))
print("block pruning == dense (KVP=8, scalar + [B] tl, windowed): OK")

# ---- fused KV-append epilogue == unfused through the 8-way shard_map ----
kn = jnp.asarray(rng.standard_normal((B, KH, HSZ), np.float32))
vn = jnp.asarray(rng.standard_normal((B, KH, HSZ), np.float32))
for tl_new in (total_len + 1, jnp.asarray([201, 38, 151, 10], jnp.int32)):
    kc_u, vc_u = append_kv(k_rr, v_rr, kn, vn, tl_new, kvp=KVP, rr_block=RR)
    with set_mesh(mesh):
        out_u = jax.jit(lambda q, k, v: helix_attention(
            mesh, hx_pl, q, k, v, tl_new))(q, kc_u, vc_u)
        out_f, kc_f, vc_f = jax.jit(
            lambda q, k, v, kn, vn: helix_attention(
                mesh, hx_pl, q, k, v, tl_new, k_new=kn, v_new=vn))(
                    q, k_rr, v_rr, kn, vn)
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
    np.testing.assert_array_equal(np.asarray(kc_f), np.asarray(kc_u))
    np.testing.assert_array_equal(np.asarray(vc_f), np.asarray(vc_u))
print("fused KV-append epilogue == unfused (KVP=8, scalar + [B] tl): OK")

# ---- shared-pool paged KV == fixed-cap layout through the KVP=8 shard_map ----
from repro.core.kvcache import cache_to_pages, pages_to_cache
hx_bs = dataclasses.replace(hx, attn_block_s=RR)          # align partitions
hx_bs_pl = dataclasses.replace(hx_pl, attn_block_s=RR)
BS = KVP * RR                                             # positions / page
MP = S_CAP // BS
NPOOL = 1 + B * MP
tbl = np.zeros((B, MP), np.int32)
perm = np.random.default_rng(5).permutation(np.arange(1, NPOOL))
pool_k = jnp.zeros((NPOOL, KH, BS, HSZ), jnp.float32)
pool_v = jnp.zeros((NPOOL, KH, BS, HSZ), jnp.float32)
pi = 0
for b in range(B):
    pk_pages = cache_to_pages(k_rr[b][None], KVP, BS)[0]
    pv_pages = cache_to_pages(v_rr[b][None], KVP, BS)[0]
    for p in range(MP):
        phys = int(perm[pi]); pi += 1
        tbl[b, p] = phys
        pool_k = pool_k.at[phys].set(pk_pages[p])
        pool_v = pool_v.at[phys].set(pv_pages[p])
tbl = jnp.asarray(tbl)
for hxf, hxp_base in ((hx_bs, hx_bs), (hx_bs_pl, hx_bs_pl)):
    hxp = dataclasses.replace(hxp_base, paged_kv=True)
    for tl_case, win in ((total_len, 0), (tls, 0), (tls, 64)):
        with set_mesh(mesh):
            of = jax.jit(lambda q, k, v: helix_attention(
                mesh, hxf, q, k, v, tl_case, window=win))(q, k_rr, v_rr)
            op = jax.jit(lambda q, k, v, t: helix_attention(
                mesh, hxp, q, k, v, tl_case, window=win,
                block_tables=t))(q, pool_k, pool_v, tbl)
        np.testing.assert_array_equal(np.asarray(of), np.asarray(op))
print("paged pool == fixed (KVP=8, ref + pallas, windowed, [B] tl): OK")

# paged fused append == fixed fused append (pool planes reassemble exactly)
kn_p = jnp.asarray(rng.standard_normal((B, KH, HSZ), np.float32))
vn_p = jnp.asarray(rng.standard_normal((B, KH, HSZ), np.float32))
tl_pp = jnp.asarray([201, 38, 151, 10], jnp.int32)
hxp = dataclasses.replace(hx_bs_pl, paged_kv=True)
with set_mesh(mesh):
    out_ff, kc_ff, vc_ff = jax.jit(lambda q, k, v, kn, vn: helix_attention(
        mesh, hx_bs_pl, q, k, v, tl_pp, k_new=kn, v_new=vn))(
            q, k_rr, v_rr, kn_p, vn_p)
    out_fp, pk_fp, pv_fp = jax.jit(
        lambda q, k, v, kn, vn, t: helix_attention(
            mesh, hxp, q, k, v, tl_pp, k_new=kn, v_new=vn,
            block_tables=t))(q, pool_k, pool_v, kn_p, vn_p, tbl)
np.testing.assert_array_equal(np.asarray(out_ff), np.asarray(out_fp))
tbl_np = np.asarray(tbl)
got_k = jnp.stack([pages_to_cache(pk_fp[tbl_np[b]][None], KVP)[0]
                   for b in range(B)])
got_v = jnp.stack([pages_to_cache(pv_fp[tbl_np[b]][None], KVP)[0]
                   for b in range(B)])
np.testing.assert_array_equal(np.asarray(got_k), np.asarray(kc_ff))
np.testing.assert_array_equal(np.asarray(got_v), np.asarray(vc_ff))
print("paged fused KV-append == fixed (KVP=8 shard_map): OK")

# ---- grouped shared-prefix decode == ungrouped through the KVP=8 shard_map ----
# rows 0,1 map the same first physical page (a shared prefix in the pool);
# the two-pass grouped kernel must be bit-identical to the ungrouped sweep
# over the same tables, including windowed and fused-append modes.
tbl2_np = np.asarray(tbl).copy()
tbl2_np[1, 0] = tbl2_np[0, 0]
tbl2 = jnp.asarray(tbl2_np)
gid_g = jnp.asarray([0, 0, 2, 3], jnp.int32)
gnp_g = jnp.asarray([1, 1, 0, 0], jnp.int32)   # 1 shared page; 2 singletons
tls2 = jnp.asarray([200, 150, 200, 129], jnp.int32)
with set_mesh(mesh):
    for win in (0, 64):
        ou = jax.jit(lambda q, k, v, t: helix_attention(
            mesh, hxp, q, k, v, tls2, window=win, block_tables=t))(
                q, pool_k, pool_v, tbl2)
        og = jax.jit(lambda q, k, v, t, g, n: helix_attention(
            mesh, hxp, q, k, v, tls2, window=win, block_tables=t,
            groups=(g, n)))(q, pool_k, pool_v, tbl2, gid_g, gnp_g)
        np.testing.assert_array_equal(np.asarray(og), np.asarray(ou))
    of, kf, vf = jax.jit(lambda q, k, v, kn, vn, t: helix_attention(
        mesh, hxp, q, k, v, tls2 + 1, k_new=kn, v_new=vn, block_tables=t))(
            q, pool_k, pool_v, kn_p, vn_p, tbl2)
    og2, kg2, vg2 = jax.jit(lambda q, k, v, kn, vn, t, g, n: helix_attention(
        mesh, hxp, q, k, v, tls2 + 1, k_new=kn, v_new=vn, block_tables=t,
        groups=(g, n)))(q, pool_k, pool_v, kn_p, vn_p, tbl2, gid_g, gnp_g)
np.testing.assert_array_equal(np.asarray(og2), np.asarray(of))
np.testing.assert_array_equal(np.asarray(kg2), np.asarray(kf))
np.testing.assert_array_equal(np.asarray(vg2), np.asarray(vf))
print("grouped shared-prefix == ungrouped (KVP=8, windowed + fused append): OK")

# ---- chunked prefill == one-shot prefill through the KVP=8 shard_map ----
from repro.configs import get_config
from repro.models.model_zoo import (build_serve_step, finalize_chunked_prefill,
                                    init_prefill_buffers,
                                    make_chunk_prefill_step, make_prefill_step)
from repro.models.transformer import init_params

cfg = get_config("granite-3-2b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
hx_m = HelixConfig(kvp_axes=("data", "model"), tpa_axis=None)
T, CAP = 40, 128                       # cache_capacity(40, kvp=8, rr=16)
toks = jax.random.randint(jax.random.PRNGKey(2), (1, T), 0, cfg.vocab)
with set_mesh(mesh):
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx_m, s_cap=CAP))
    last_logits, st1 = prefill(params, {"tokens": toks})
    tok1 = int(jnp.argmax(last_logits[0, :cfg.vocab]))
    chunk_step = jax.jit(make_chunk_prefill_step(cfg, mesh, hx_m))
    for chunk in (17, T):
        bufs = init_prefill_buffers(cfg, 1, T, tp_width=mesh.shape["model"])
        pos = 0
        while pos < T:
            c = min(chunk, T - pos)
            nt, bufs = chunk_step(params, toks[:, pos:pos + c], bufs,
                                  jnp.asarray(pos, jnp.int32))
            pos += c
        st2 = finalize_chunked_prefill(cfg, hx_m, bufs, T, s_cap=CAP, kvp=8)
        assert int(nt[0, -1]) == tok1, (chunk, int(nt[0, -1]), tok1)
        np.testing.assert_array_equal(np.asarray(st2["kcache"]),
                                      np.asarray(st1["kcache"]))
        np.testing.assert_array_equal(np.asarray(st2["vcache"]),
                                      np.asarray(st1["vcache"]))
        # decode continuation agrees step for step (tokens + caches)
        serve = jax.jit(build_serve_step(cfg, mesh, hx_m))
        cur1 = cur2 = jnp.full((1,), tok1, jnp.int32)
        s1, s2 = dict(st1), dict(st2)
        for _ in range(2):
            cur1, s1 = serve(params, s1, cur1)
            cur2, s2 = serve(params, s2, cur2)
            assert int(cur1[0]) == int(cur2[0])
        np.testing.assert_array_equal(np.asarray(s2["kcache"]),
                                      np.asarray(s1["kcache"]))
print("chunked prefill == one-shot (KVP=8 shard_map, chunk 17/T): OK")

# ---- append_kv round-robin ----
kc = jnp.zeros((B, KH, S_CAP, HSZ))
vc = jnp.zeros((B, KH, S_CAP, HSZ))
for pos in range(40):
    kn = jnp.full((B, KH, HSZ), float(pos + 1))
    kc, vc = append_kv(kc, vc, kn, kn, pos + 1, kvp=KVP, rr_block=RR)
# slot check: position p -> value p+1
for r in range(KVP):
    pos_map = np.asarray(shard_positions(32, r, KVP, RR))
    local = np.asarray(kc[0, 0, r*32:(r+1)*32, 0])
    expect = np.where(pos_map < 40, pos_map + 1, 0)
    np.testing.assert_array_equal(local, expect)
print("append_kv round-robin layout: OK")
print("ALL OK")
