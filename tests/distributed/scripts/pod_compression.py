"""int8 compressed all-reduce over a 'pod' axis inside shard_map (manual over
pod, GSPMD elsewhere) == f32 mean within quantization error; EF bounded."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.optim.compression import compressed_pod_mean
from repro.utils import make_mesh, set_mesh, shard_map

mesh = make_mesh((2, 4), ("pod", "data"))

rng = np.random.default_rng(0)
# per-pod gradients [2, N]: axis 0 is the pod dim
g = jnp.asarray(rng.standard_normal((2, 4096)).astype(np.float32) * 1e-2)
e = jnp.zeros((2, 4096), jnp.float32)


def pod_fn(g_l, e_l):
    grads = {"w": g_l[0]}
    errs = {"w": e_l[0]}
    mean, new_e = compressed_pod_mean(grads, errs, "pod")
    return mean["w"][None], new_e["w"][None]


fn = jax.jit(shard_map(
    pod_fn, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
    out_specs=(P("pod", None), P("pod", None)), check_vma=False))

with set_mesh(mesh):
    mean, new_e = fn(g, e)

true_mean = np.asarray(g).mean(axis=0)
got = np.asarray(mean)[0]
# both pods agree on the mean
np.testing.assert_allclose(np.asarray(mean)[0], np.asarray(mean)[1],
                           atol=0)
scale = np.abs(np.asarray(g)).max() / 127.0
assert np.abs(got - true_mean).max() <= scale + 1e-7, \
    np.abs(got - true_mean).max()
# error feedback buffers carry the residual
np.testing.assert_allclose(np.asarray(new_e), np.asarray(g) -
                           np.round(np.asarray(g) / scale).clip(-127, 127)
                           * scale, atol=scale * 0.51)
print("compressed pod mean within quantization band; EF residual correct")
print("ALL OK")
