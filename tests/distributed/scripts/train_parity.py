"""Sharded (DP x TP, 8 devices) train_step == single-device train_step."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model_zoo import make_train_step
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.utils import make_mesh, set_mesh

mesh = make_mesh((4, 2), ("data", "model"))

for arch in ["granite-3-2b", "granite-moe-1b-a400m", "mamba2-780m"]:
    cfg = get_config(arch).reduced()
    optcfg = AdamWConfig(lr=1e-3, total_steps=10, warmup_steps=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params, optcfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32),
                                          0, cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32),
                                          0, cfg.vocab)}

    single = jax.jit(make_train_step(cfg, None, optcfg, chunk_q=32))
    p1, o1, m1 = single(params, opt, batch)

    with set_mesh(mesh):
        sharded = jax.jit(make_train_step(cfg, mesh, optcfg, chunk_q=32))
        p2, o2, m2 = sharded(params, opt, batch)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4, \
        (arch, float(m1["loss"]), float(m2["loss"]))
    # updated params agree leaf-wise
    err = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p2)
    worst = max(jax.tree.leaves(err))
    assert worst < 5e-4, (arch, worst)
    print(f"{arch}: sharded == single (loss {float(m1['loss']):.4f}, "
          f"max param delta {worst:.2e})")

print("ALL OK")
