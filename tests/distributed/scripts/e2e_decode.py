"""E2E: prefill -> serve_step decode must equal full-sequence forward."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core.sharding import HelixConfig, default_helix_config
from repro.models.transformer import init_params, forward
from repro.models.model_zoo import make_prefill_step, build_serve_step
from repro.utils import make_mesh, set_mesh

mesh = make_mesh((4, 2), ("data", "model"))

for arch in ["granite-3-2b", "gemma3-12b", "granite-moe-1b-a400m",
             "mamba2-780m", "hymba-1.5b", "whisper-base", "phi-3-vision-4.2b"]:
    cfg = get_config(arch).reduced()
    hx = default_helix_config(cfg, mesh)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, T = 4, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T + 2), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :T]}
    if cfg.vision_patches:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.vision_patches, cfg.d_model)) * 0.02
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, T, cfg.d_model)) * 0.02

    prefill = make_prefill_step(cfg, mesh, hx, s_cap=256)
    serve = build_serve_step(cfg, mesh, hx, hopb_chunks=2, return_logits=True)

    with set_mesh(mesh):
        last_logits, state = jax.jit(prefill)(params, batch)
        (nt1, lg1), state = jax.jit(serve)(params, state, tokens[:, T])
        (nt2, lg2), state = jax.jit(serve)(params, state, tokens[:, T + 1])

    # reference: full forward over T+2 tokens
    fb = dict(batch); fb["tokens"] = tokens
    kw = {}
    if cfg.vision_patches: kw["patch_embeds"] = batch["patch_embeds"]
    if cfg.is_encdec: kw["enc_frames"] = batch["enc_frames"]
    ref_logits, _ = forward(cfg, params, tokens, tp_width=1, **kw)

    for name, got, want in [("prefill", last_logits, ref_logits[:, T - 1]),
                            ("step1", lg1, ref_logits[:, T]),
                            ("step2", lg2, ref_logits[:, T + 1])]:
        g = np.asarray(got, np.float32)[:, :cfg.vocab]
        w = np.asarray(want, np.float32)[:, :cfg.vocab]
        err = np.abs(g - w).max()
        assert err < 2e-3, (arch, name, err)
    print(f"{arch:24s} prefill+2 decode steps == forward  OK")
print("ALL OK")
