"""Elastic restart: checkpoint written under an 8-device (4x2) mesh restores
onto a 4-device (2x2) mesh (simulating the loss of half the fleet) and
training resumes bitwise-deterministically (pure-function-of-step data)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.sharding import train_param_specs, to_shardings
from repro.data import DataConfig, TokenPipeline
from repro.models.model_zoo import make_train_step
from repro.models.transformer import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.utils import make_mesh, set_mesh

cfg = get_config("granite-3-2b").reduced()
optcfg = AdamWConfig(lr=1e-3, total_steps=20, warmup_steps=0)
pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))


def run_steps(mesh, params, opt, start, n):
    step = jax.jit(make_train_step(cfg, mesh, optcfg, chunk_q=32))
    losses = []
    with set_mesh(mesh):
        for s in range(start, start + n):
            b = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            params, opt, m = step(params, opt, b)
            losses.append(float(m["loss"]))
    return params, opt, losses


mesh_big = make_mesh((4, 2), ("data", "model"))
mesh_small = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])

params = init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params, optcfg)

# 3 steps on the big mesh, checkpoint, 3 more (the "would-have-been" path)
params, opt, _ = run_steps(mesh_big, params, opt, 0, 3)
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(3, (params, opt))
    _, _, want = run_steps(mesh_big, params, opt, 3, 3)

    # "pod failure": restore onto the smaller mesh with new shardings
    p_specs = train_param_specs(cfg, params, mesh_small)
    shardings = (to_shardings(mesh_small, p_specs),
                 jax.tree.map(lambda _: NamedSharding(mesh_small, P()),
                              opt, is_leaf=lambda x: hasattr(x, "shape")))
    params2, opt2 = mgr.restore((params, opt), shardings=None)
    _, _, got = run_steps(mesh_small, params2, opt2, 3, 3)

for a, b in zip(want, got):
    assert abs(a - b) < 3e-4, (want, got)
print(f"elastic restore: losses match across mesh change {want} == {got}")
print("ALL OK")
