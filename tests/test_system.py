"""End-to-end system behaviour: training learns, the serving engine's
continuous batching matches step-by-step decoding, checkpoint-restart
resumes identically."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.launch.train import train
from repro.models.model_zoo import (build_serve_step, make_prefill_step)
from repro.models.transformer import forward, init_params
from repro.serving import DecodeEngine, Request
from repro.utils import make_mesh


def test_training_reduces_loss(tmp_path):
    _, _, losses = train("granite-3-2b", reduced=True, steps=40, batch=8,
                         seq=64, lr=1e-3, log=lambda s: None)
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.3, (first, last)


def test_checkpoint_restart_resumes_identically(tmp_path):
    ck = tmp_path / "ck"
    _, _, losses_a = train("granite-3-2b", reduced=True, steps=20, batch=4,
                           seq=32, ckpt_dir=str(ck), save_every=10,
                           log=lambda s: None)
    # second call restores at step 20 and must not retrain anything
    _, _, losses_b = train("granite-3-2b", reduced=True, steps=20, batch=4,
                           seq=32, ckpt_dir=str(ck), log=lambda s: None)
    assert losses_b == []      # nothing left to do: exact resume point
    # a longer run from the same checkpoint continues from step 20
    _, _, losses_c = train("granite-3-2b", reduced=True, steps=25, batch=4,
                           seq=32, ckpt_dir=str(ck), log=lambda s: None)
    assert len(losses_c) == 5


def _mesh1():
    return make_mesh((1, 1), ("data", "model"))


def test_engine_matches_reference_greedy_decode():
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    serve = build_serve_step(cfg, mesh, hx)
    prefill = make_prefill_step(cfg, mesh, hx)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).tolist() for n in (12, 7, 19)]
    engine = DecodeEngine(cfg, params, serve, prefill, max_batch=4,
                          max_seq=64, kvp=1)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert engine.add_request(r)
    engine.run_to_completion()

    # reference: greedy argmax with the full-sequence forward
    for r in reqs:
        toks = list(r.prompt)
        want = []
        for _ in range(6):
            logits, _ = forward(cfg, params, jnp.asarray([toks], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1, :cfg.vocab]))
            want.append(nxt)
            toks.append(nxt)
        assert r.out_tokens == want, (r.rid, r.out_tokens, want)


def test_engine_continuous_batching_slot_reuse():
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    engine = DecodeEngine(cfg, params, build_serve_step(cfg, mesh, hx),
                          make_prefill_step(cfg, mesh, hx),
                          max_batch=2, max_seq=64, kvp=1)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 8).tolist(),
                    max_new_tokens=4) for i in range(5)]
    pending = list(reqs)
    done = []
    for _ in range(100):
        while pending and engine.add_request(pending[0]):
            pending.pop(0)
        done += engine.step()
        if len(done) == 5:
            break
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in reqs)
