"""Substrate tests: data determinism, checkpointing, optimizer, compression,
watchdog/retry loop."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, TokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_schedule,
                         int8_compress, int8_decompress)
from repro.optim.compression import init_error_feedback
from repro.runtime import RetryPolicy, StepWatchdog, run_with_retries
from repro.runtime.watchdog import StepTimeout


# ------------------------------------------------------------------- data
def test_pipeline_is_pure_function_of_step():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    a, b = TokenPipeline(cfg), TokenPipeline(cfg)
    for step in (0, 1, 17, 999):
        ba, bb = a.batch(step), b.batch(step)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])
    assert not np.array_equal(a.batch(0)["tokens"], a.batch(1)["tokens"])


def test_pipeline_host_sharding_partitions_batch():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
    p = TokenPipeline(cfg)
    full = p.batch(5)["tokens"]
    parts = [p.host_batch(5, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_labels_are_shifted_tokens():
    p = TokenPipeline(DataConfig(vocab=64, seq_len=16, global_batch=2))
    b = p.batch(0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    for step in (10, 20, 30):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    assert mgr.latest_step() == 30
    assert sorted(mgr._committed()) == [20, 30]   # keep-2 GC
    got = mgr.restore(tree, step=20)
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.arange(6.0).reshape(2, 3) + 20)


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(tmp_path)
    # a torn tmp dir must not be visible as a checkpoint
    (tmp_path / "step_000000099.tmp").mkdir()
    assert mgr.latest_step() is None
    mgr.save(5, {"x": jnp.zeros(3)})
    assert mgr.latest_step() == 5


def test_checkpoint_restore_into_structure(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.full((3, 3), 2.0), "opt": {"m": jnp.zeros((3, 3))}}
    mgr.save(1, tree)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["w"]), 2.0 * np.ones((3, 3)))


# -------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=300, grad_clip=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[3] < 1.0 and lrs[4] == pytest.approx(0.1, abs=1e-6)


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


# ------------------------------------------------------------ compression
def test_int8_error_feedback_unbiased_over_steps():
    """With EF, the accumulated applied signal tracks the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    for _ in range(50):
        q, scale, err = int8_compress(g_true, err)
        applied += int8_decompress(q, scale)
    total_err = np.abs(np.asarray(applied - 50 * g_true)).max()
    # EF bounds the *final* residual by one quantization step, not O(steps)
    assert total_err <= float(scale) + 1e-7


def test_int8_compress_roundtrip_band():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(64), jnp.float32)
    q, scale, err = int8_compress(g, jnp.zeros_like(g))
    deq = int8_decompress(q, scale)
    assert np.abs(np.asarray(g - deq)).max() <= float(scale) * 0.5 + 1e-7
    np.testing.assert_allclose(np.asarray(g - deq), np.asarray(err),
                               atol=1e-7)


# ---------------------------------------------------------------- runtime
def test_watchdog_times_out():
    wd = StepWatchdog(timeout_s=0.05)
    with pytest.raises(StepTimeout):
        wd.run(0, lambda: time.sleep(0.2))


def test_watchdog_tracks_stragglers():
    wd = StepWatchdog(timeout_s=10.0)
    for i in range(5):
        wd.run(i, lambda: time.sleep(0.01))
    wd.run(5, lambda: time.sleep(0.2))        # 20x slower
    assert wd.straggler_steps and wd.straggler_steps[0][0] == 5


def test_run_with_retries_recovers_from_crash(tmp_path):
    mgr = CheckpointManager(tmp_path)
    crashes = {"left": 2}

    def step_fn(step, state):
        if step == 3 and crashes["left"] > 0:
            crashes["left"] -= 1
            raise RuntimeError("injected fault")
        return state + 1

    def save_fn(step, state):
        mgr.save(step, {"s": jnp.asarray(state)})

    def restore_fn():
        s = mgr.latest_step()
        return s, int(mgr.restore({"s": jnp.asarray(0)})["s"])

    final_step, state = run_with_retries(
        step_fn, 0, start_step=0, num_steps=6, save_fn=save_fn,
        restore_fn=restore_fn, save_every=2,
        policy=RetryPolicy(max_retries=5, backoff_s=0.01), log=lambda s: None)
    assert final_step == 6
    assert state == 6          # exactly-once semantics via restart-from-ckpt
    assert crashes["left"] == 0
