"""helix_attention backend selection: pallas-interpret == ref end to end.

Single-device (trivial 1x1 mesh) so it runs in the main suite; the 8-fake-
device all-to-all parity lives in tests/distributed/scripts/helix_exact.py.
Also covers the serve_step plumbing: build_serve_step(attn_backend=...)
produces identical decodes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.helix import helix_attention
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import build_serve_step, make_prefill_step
from repro.models.transformer import init_params
from repro.utils import make_mesh


def _mesh1():
    return make_mesh((1, 1), ("data", "model"))


def _hx(backend):
    return HelixConfig(kvp_axes=("data",), tpa_axis=None,
                       attn_backend=backend)


def _mk(b=2, qh=8, kh=2, s=64, hsz=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, qh, hsz)),
            jax.random.normal(ks[1], (b, kh, s, hsz)),
            jax.random.normal(ks[2], (b, kh, s, hsz)))


@pytest.mark.parametrize("contiguous", [False, True],
                         ids=["roundrobin", "contiguous"])
@pytest.mark.parametrize("per_request", [False, True],
                         ids=["scalar-tl", "perreq-tl"])
def test_helix_attention_backend_parity(contiguous, per_request):
    mesh = _mesh1()
    q, k, v = _mk()
    tl = jnp.asarray([60, 23], jnp.int32) if per_request else 60

    def run(backend):
        return jax.jit(lambda q, k, v: helix_attention(
            mesh, _hx(backend), q, k, v, tl, contiguous=contiguous))(q, k, v)

    ref = np.asarray(run("ref"))
    got = np.asarray(run("pallas-interpret"))
    np.testing.assert_allclose(got, ref, rtol=2e-6, atol=2e-6)


def test_helix_attention_backend_parity_windowed():
    mesh = _mesh1()
    q, k, v = _mk(s=128)

    def run(backend):
        return jax.jit(lambda q, k, v: helix_attention(
            mesh, _hx(backend), q, k, v, 120, window=32))(q, k, v)

    np.testing.assert_allclose(np.asarray(run("pallas-interpret")),
                               np.asarray(run("ref")), rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("window", [0, 32], ids=["full", "windowed"])
def test_helix_attention_backend_parity_int8(window):
    mesh = _mesh1()
    q, k, v = _mk(s=128)
    scale = jnp.maximum(jnp.max(jnp.abs(k), axis=-1) / 127.0, 1e-30)
    vscale = jnp.maximum(jnp.max(jnp.abs(v), axis=-1) / 127.0, 1e-30)
    kq = jnp.clip(jnp.round(k / scale[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vscale[..., None]), -127, 127).astype(jnp.int8)

    def run(backend):
        return jax.jit(lambda q, k, v, ks, vs: helix_attention(
            mesh, _hx(backend), q, k, v, 120, window=window,
            kscale=ks, vscale=vs))(q, kq, vq, scale, vscale)

    np.testing.assert_allclose(np.asarray(run("pallas-interpret")),
                               np.asarray(run("ref")), rtol=2e-6, atol=2e-6)


def test_serve_step_backend_override_matches_ref():
    """Full serve_step with attn_backend='pallas-interpret' reproduces the
    ref-backend decode exactly (greedy tokens and state lengths)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, state0 = prefill(params, {"tokens": toks})

    def decode(backend, n=4):
        serve = jax.jit(build_serve_step(cfg, mesh, hx,
                                         attn_backend=backend))
        state = dict(state0)
        cur = jnp.zeros((2,), jnp.int32)
        outs = []
        for _ in range(n):
            cur, state = serve(params, state, cur)
            outs.append(np.asarray(cur))
        return np.stack(outs)

    np.testing.assert_array_equal(decode("pallas-interpret"), decode("ref"))


def test_invalid_backend_rejected():
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), attn_backend="cuda")
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), prefill_backend="triton")
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), ssd_backend="cuda")


# ------------------------------------------------------- fused KV append
def test_helix_attention_fused_append_bit_exact():
    """helix_attention(k_new=...) == append_kv then helix_attention, bit for
    bit (output and caches), for scalar and per-request lengths and under
    HOP-B chunking."""
    from repro.core.helix import append_kv
    mesh = _mesh1()
    hx = _hx("pallas-interpret")
    q, k, v = _mk()
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    kn = jax.random.normal(ks[0], (2, 2, 64))
    vn = jax.random.normal(ks[1], (2, 2, 64))

    for tl, chunks in [(60, 1), (jnp.asarray([60, 23], jnp.int32), 1),
                       (60, 2)]:
        kc_u, vc_u = append_kv(k, v, kn, vn, tl, kvp=1, rr_block=hx.rr_block)
        out_u = jax.jit(lambda q, k, v: helix_attention(
            mesh, hx, q, k, v, tl, hopb_chunks=chunks))(q, kc_u, vc_u)
        out_f, kc_f, vc_f = jax.jit(lambda q, k, v, kn, vn: helix_attention(
            mesh, hx, q, k, v, tl, hopb_chunks=chunks, k_new=kn,
            v_new=vn))(q, k, v, kn, vn)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
        np.testing.assert_array_equal(np.asarray(kc_f), np.asarray(kc_u))
        np.testing.assert_array_equal(np.asarray(vc_f), np.asarray(vc_u))


def test_fuse_append_applicable_gating():
    """Static fusion eligibility: on for pallas decode (incl. quant — the
    kernel quantizes in-kernel — and windowed layers, since block pruning
    subsumes the cache-slice fast path), off for ref / opt-out /
    contiguous, and off for the windowed slice path when pruning is
    disabled."""
    from repro.core.helix import fuse_append_applicable
    import dataclasses
    hx = _hx("pallas-interpret")
    assert fuse_append_applicable(hx, 4, 0, 100, 256)
    assert not fuse_append_applicable(_hx("ref"), 4, 0, 100, 256)
    assert not fuse_append_applicable(
        dataclasses.replace(hx, fuse_append=False), 4, 0, 100, 256)
    assert fuse_append_applicable(hx, 4, 0, 100, 256, quant=True)
    assert not fuse_append_applicable(hx, 4, 0, 100, 256, contiguous=True)
    # windowed layers fuse when pruning handles the window in-kernel ...
    assert fuse_append_applicable(hx, 4, 32, 1000, 1024)
    # ... but with pruning off the cache-slice fast path re-engages and the
    # static-window scalar-length case must fall back to unfused append
    hx_np = dataclasses.replace(hx, prune_blocks=False)
    assert not fuse_append_applicable(hx_np, 4, 32, 1000, 1024)
    # traced/per-request total_len: slice path can't engage -> fusible
    assert fuse_append_applicable(hx_np, 4, 32,
                                  jnp.zeros((2,), jnp.int32), 1024)


@pytest.mark.parametrize("window", [0, 32], ids=["full", "windowed"])
def test_helix_attention_prune_parity(window):
    """helix_attention with block pruning on == off == ref, for scalar and
    per-request lengths (pruned/unpruned kernel outputs are bit-exact)."""
    mesh = _mesh1()
    q, k, v = _mk(s=128)
    for tl in (120, jnp.asarray([120, 37], jnp.int32)):
        def run(hx):
            return jax.jit(lambda q, k, v: helix_attention(
                mesh, hx, q, k, v, tl, window=window))(q, k, v)

        hx_p = _hx("pallas-interpret")
        hx_np = dataclasses.replace(hx_p, prune_blocks=False,
                                    fuse_append=False)
        out_p = np.asarray(run(hx_p))
        out_ref = np.asarray(run(_hx("ref")))
        np.testing.assert_allclose(out_p, out_ref, rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(np.asarray(run(hx_np)), out_ref,
                                   rtol=2e-6, atol=2e-6)


def test_helix_attention_fused_append_int8():
    """helix_attention int8 fused append == append_kv_quant then attend,
    bit for bit (output, caches and scales), incl. windowed layers."""
    from repro.core.helix import append_kv_quant
    mesh = _mesh1()
    hx = _hx("pallas-interpret")
    q, k, v = _mk()
    scale = jnp.maximum(jnp.max(jnp.abs(k), axis=-1) / 127.0, 1e-30)
    vscale = jnp.maximum(jnp.max(jnp.abs(v), axis=-1) / 127.0, 1e-30)
    kq = jnp.clip(jnp.round(k / scale[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vscale[..., None]), -127, 127).astype(jnp.int8)
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    kn = jax.random.normal(ks[0], (2, 2, 64))
    vn = jax.random.normal(ks[1], (2, 2, 64))
    for tl, win in [(60, 0), (jnp.asarray([60, 23], jnp.int32), 0), (60, 32)]:
        kc_u, vc_u, ks_u, vs_u = append_kv_quant(
            kq, vq, scale, vscale, kn, vn, tl, kvp=1, rr_block=hx.rr_block)
        out_u = jax.jit(lambda *a: helix_attention(
            mesh, hx, *a[:3], tl, window=win, kscale=a[3], vscale=a[4]))(
                q, kc_u, vc_u, ks_u, vs_u)
        out_f, kc_f, vc_f, ks_f, vs_f = jax.jit(
            lambda *a: helix_attention(
                mesh, hx, *a[:3], tl, window=win, kscale=a[3], vscale=a[4],
                k_new=a[5], v_new=a[6]))(q, kq, vq, scale, vscale, kn, vn)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
        np.testing.assert_array_equal(np.asarray(kc_f), np.asarray(kc_u))
        np.testing.assert_array_equal(np.asarray(vc_f), np.asarray(vc_u))
        np.testing.assert_array_equal(np.asarray(ks_f), np.asarray(ks_u))
        np.testing.assert_array_equal(np.asarray(vs_f), np.asarray(vs_u))


def test_serve_step_fused_append_matches_unfused():
    """Full serve_step: fused-append decode == unfused decode == ref decode
    (greedy tokens identical; caches bit-exact between fused and unfused)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, state0 = prefill(params, {"tokens": toks})

    def decode(backend, fuse, n=3):
        serve = jax.jit(build_serve_step(cfg, mesh, hx, attn_backend=backend,
                                         fuse_append=fuse))
        state = dict(state0)
        cur = jnp.zeros((2,), jnp.int32)
        outs = []
        for _ in range(n):
            cur, state = serve(params, state, cur)
            outs.append(np.asarray(cur))
        return np.stack(outs), state

    t_ref, _ = decode("ref", None)
    t_unf, s_unf = decode("pallas-interpret", False)
    t_fus, s_fus = decode("pallas-interpret", True)
    np.testing.assert_array_equal(t_unf, t_ref)
    np.testing.assert_array_equal(t_fus, t_unf)
    np.testing.assert_array_equal(np.asarray(s_fus["kcache"]),
                                  np.asarray(s_unf["kcache"]))
    np.testing.assert_array_equal(np.asarray(s_fus["vcache"]),
                                  np.asarray(s_unf["vcache"]))


# --------------------------------------------------------- block pruning
def _prefill_state(cfg, mesh, hx, s_cap=64, t=12):
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=s_cap))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, t), 0, cfg.vocab)
    _, state0 = prefill(params, {"tokens": toks})
    return params, state0


def _decode_n(cfg, mesh, hx, params, state0, n=3, **kw):
    serve = jax.jit(build_serve_step(cfg, mesh, hx, **kw))
    state = dict(state0)
    cur = jnp.zeros((2,), jnp.int32)
    outs = []
    for _ in range(n):
        cur, state = serve(params, state, cur)
        outs.append(np.asarray(cur))
    return np.stack(outs), state


def test_prefill_prune_knob_plumbed(monkeypatch):
    """hx.prune_blocks reaches flash_prefill through the prefill step (the
    dense-sweep opt-out must hold for prefill too, not just decode).
    Outputs are bit-exact either way, so a spy checks the plumbing."""
    import repro.models.transformer as tr
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    seen = []
    orig = tr.prefill_attention

    def spy(*a, **kw):
        seen.append(kw.get("prune"))
        return orig(*a, **kw)

    monkeypatch.setattr(tr, "prefill_attention", spy)
    for prune in (False, True):
        seen.clear()
        hx = HelixConfig(kvp_axes=("data",), tpa_axis=None,
                         prefill_backend="pallas-interpret",
                         prune_blocks=prune)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prefill = make_prefill_step(cfg, mesh, hx, s_cap=64)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                  cfg.vocab)
        prefill(params, {"tokens": toks})
        assert seen and all(p is prune for p in seen), (prune, seen)


def test_serve_step_prune_parity():
    """Full serve_step: block pruning on == off == ref (greedy tokens
    identical, pruned/unpruned caches bit-exact)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params, state0 = _prefill_state(cfg, mesh, hx)
    t_ref, _ = _decode_n(cfg, mesh, hx, params, state0, attn_backend="ref")
    t_p, s_p = _decode_n(cfg, mesh, hx, params, state0,
                         attn_backend="pallas-interpret", prune_blocks=True)
    t_d, s_d = _decode_n(cfg, mesh, hx, params, state0,
                         attn_backend="pallas-interpret", prune_blocks=False)
    np.testing.assert_array_equal(t_p, t_ref)
    np.testing.assert_array_equal(t_d, t_ref)
    np.testing.assert_array_equal(np.asarray(s_p["kcache"]),
                                  np.asarray(s_d["kcache"]))
    np.testing.assert_array_equal(np.asarray(s_p["vcache"]),
                                  np.asarray(s_d["vcache"]))


def test_serve_step_fused_append_int8_matches_unfused():
    """Full serve_step with an int8 KV cache: the fused in-kernel
    quantize-and-append decode == the unfused append_kv_quant path, bit for
    bit (tokens, int8 caches and scales)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None, kv_cache_bits=8,
                     attn_backend="pallas-interpret")
    params, state0 = _prefill_state(cfg, mesh, hx)
    kf = state0["kcache"].astype(jnp.float32)
    vf = state0["vcache"].astype(jnp.float32)
    ks = jnp.maximum(jnp.max(jnp.abs(kf), -1) / 127.0, 1e-30)
    vs = jnp.maximum(jnp.max(jnp.abs(vf), -1) / 127.0, 1e-30)
    st8 = dict(state0)
    st8["kcache"] = jnp.clip(jnp.round(kf / ks[..., None]), -127,
                             127).astype(jnp.int8)
    st8["vcache"] = jnp.clip(jnp.round(vf / vs[..., None]), -127,
                             127).astype(jnp.int8)
    st8["kscale"], st8["vscale"] = ks, vs

    t_fus, s_fus = _decode_n(cfg, mesh, hx, params, st8, fuse_append=True)
    t_unf, s_unf = _decode_n(cfg, mesh, hx, params, st8, fuse_append=False)
    np.testing.assert_array_equal(t_fus, t_unf)
    for key in ("kcache", "vcache", "kscale", "vscale"):
        np.testing.assert_array_equal(np.asarray(s_fus[key]),
                                      np.asarray(s_unf[key]))


# ------------------------------------------------------- w8a16 lm_head
def test_serve_step_lm_head_w8_consumer():
    """lm_head_w8 routes the logits matmul through the w8a16_matmul family:
    ref and pallas-interpret matmul backends agree on the same quantized
    weights (greedy tokens identical), and the quantized logits stay close
    to the fp path."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params, state0 = _prefill_state(cfg, mesh, hx)

    def logits_once(**kw):
        serve = jax.jit(build_serve_step(cfg, mesh, hx, return_logits=True,
                                         **kw))
        (nt, lg), _ = serve(params, dict(state0), jnp.zeros((2,), jnp.int32))
        return np.asarray(nt), np.asarray(lg)

    t_fp, lg_fp = logits_once()
    t_r, lg_r = logits_once(lm_head_w8=True, matmul_backend="ref")
    t_k, lg_k = logits_once(lm_head_w8=True,
                            matmul_backend="pallas-interpret")
    np.testing.assert_array_equal(t_r, t_k)
    np.testing.assert_allclose(lg_k, lg_r, rtol=2e-5, atol=2e-5)
    # weight-only quantization: small perturbation of the fp logits
    band = np.max(np.abs(lg_fp)) * 0.1 + 1e-3
    assert np.max(np.abs(lg_r - lg_fp)) < band
