"""helix_attention backend selection: pallas-interpret == ref end to end.

Single-device (trivial 1x1 mesh) so it runs in the main suite; the 8-fake-
device all-to-all parity lives in tests/distributed/scripts/helix_exact.py.
Also covers the serve_step plumbing: build_serve_step(attn_backend=...)
produces identical decodes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.helix import helix_attention
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import build_serve_step, make_prefill_step
from repro.models.transformer import init_params
from repro.utils import make_mesh


def _mesh1():
    return make_mesh((1, 1), ("data", "model"))


def _hx(backend):
    return HelixConfig(kvp_axes=("data",), tpa_axis=None,
                       attn_backend=backend)


def _mk(b=2, qh=8, kh=2, s=64, hsz=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (b, qh, hsz)),
            jax.random.normal(ks[1], (b, kh, s, hsz)),
            jax.random.normal(ks[2], (b, kh, s, hsz)))


@pytest.mark.parametrize("contiguous", [False, True],
                         ids=["roundrobin", "contiguous"])
@pytest.mark.parametrize("per_request", [False, True],
                         ids=["scalar-tl", "perreq-tl"])
def test_helix_attention_backend_parity(contiguous, per_request):
    mesh = _mesh1()
    q, k, v = _mk()
    tl = jnp.asarray([60, 23], jnp.int32) if per_request else 60

    def run(backend):
        return jax.jit(lambda q, k, v: helix_attention(
            mesh, _hx(backend), q, k, v, tl, contiguous=contiguous))(q, k, v)

    ref = np.asarray(run("ref"))
    got = np.asarray(run("pallas-interpret"))
    np.testing.assert_allclose(got, ref, rtol=2e-6, atol=2e-6)


def test_helix_attention_backend_parity_windowed():
    mesh = _mesh1()
    q, k, v = _mk(s=128)

    def run(backend):
        return jax.jit(lambda q, k, v: helix_attention(
            mesh, _hx(backend), q, k, v, 120, window=32))(q, k, v)

    np.testing.assert_allclose(np.asarray(run("pallas-interpret")),
                               np.asarray(run("ref")), rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("window", [0, 32], ids=["full", "windowed"])
def test_helix_attention_backend_parity_int8(window):
    mesh = _mesh1()
    q, k, v = _mk(s=128)
    scale = jnp.maximum(jnp.max(jnp.abs(k), axis=-1) / 127.0, 1e-30)
    vscale = jnp.maximum(jnp.max(jnp.abs(v), axis=-1) / 127.0, 1e-30)
    kq = jnp.clip(jnp.round(k / scale[..., None]), -127, 127).astype(jnp.int8)
    vq = jnp.clip(jnp.round(v / vscale[..., None]), -127, 127).astype(jnp.int8)

    def run(backend):
        return jax.jit(lambda q, k, v, ks, vs: helix_attention(
            mesh, _hx(backend), q, k, v, 120, window=window,
            kscale=ks, vscale=vs))(q, kq, vq, scale, vscale)

    np.testing.assert_allclose(np.asarray(run("pallas-interpret")),
                               np.asarray(run("ref")), rtol=2e-6, atol=2e-6)


def test_serve_step_backend_override_matches_ref():
    """Full serve_step with attn_backend='pallas-interpret' reproduces the
    ref-backend decode exactly (greedy tokens and state lengths)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, state0 = prefill(params, {"tokens": toks})

    def decode(backend, n=4):
        serve = jax.jit(build_serve_step(cfg, mesh, hx,
                                         attn_backend=backend))
        state = dict(state0)
        cur = jnp.zeros((2,), jnp.int32)
        outs = []
        for _ in range(n):
            cur, state = serve(params, state, cur)
            outs.append(np.asarray(cur))
        return np.stack(outs)

    np.testing.assert_array_equal(decode("pallas-interpret"), decode("ref"))


def test_invalid_backend_rejected():
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), attn_backend="cuda")
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), prefill_backend="triton")
    with pytest.raises(AssertionError):
        HelixConfig(kvp_axes=("data",), ssd_backend="cuda")


# ------------------------------------------------------- fused KV append
def test_helix_attention_fused_append_bit_exact():
    """helix_attention(k_new=...) == append_kv then helix_attention, bit for
    bit (output and caches), for scalar and per-request lengths and under
    HOP-B chunking."""
    from repro.core.helix import append_kv
    mesh = _mesh1()
    hx = _hx("pallas-interpret")
    q, k, v = _mk()
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    kn = jax.random.normal(ks[0], (2, 2, 64))
    vn = jax.random.normal(ks[1], (2, 2, 64))

    for tl, chunks in [(60, 1), (jnp.asarray([60, 23], jnp.int32), 1),
                       (60, 2)]:
        kc_u, vc_u = append_kv(k, v, kn, vn, tl, kvp=1, rr_block=hx.rr_block)
        out_u = jax.jit(lambda q, k, v: helix_attention(
            mesh, hx, q, k, v, tl, hopb_chunks=chunks))(q, kc_u, vc_u)
        out_f, kc_f, vc_f = jax.jit(lambda q, k, v, kn, vn: helix_attention(
            mesh, hx, q, k, v, tl, hopb_chunks=chunks, k_new=kn,
            v_new=vn))(q, k, v, kn, vn)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u))
        np.testing.assert_array_equal(np.asarray(kc_f), np.asarray(kc_u))
        np.testing.assert_array_equal(np.asarray(vc_f), np.asarray(vc_u))


def test_fuse_append_applicable_gating():
    """Static fusion eligibility: on for plain pallas decode, off for ref /
    opt-out / quant / contiguous / the windowed cache-slice fast path."""
    from repro.core.helix import fuse_append_applicable
    import dataclasses
    hx = _hx("pallas-interpret")
    assert fuse_append_applicable(hx, 4, 0, 100, 256)
    assert not fuse_append_applicable(_hx("ref"), 4, 0, 100, 256)
    assert not fuse_append_applicable(
        dataclasses.replace(hx, fuse_append=False), 4, 0, 100, 256)
    assert not fuse_append_applicable(hx, 4, 0, 100, 256, quant=True)
    assert not fuse_append_applicable(hx, 4, 0, 100, 256, contiguous=True)
    # static window small enough to engage the cache-slice fast path
    assert not fuse_append_applicable(hx, 4, 32, 1000, 1024)
    # traced/per-request total_len: slice path can't engage -> fusible
    assert fuse_append_applicable(hx, 4, 32, jnp.zeros((2,), jnp.int32), 1024)


def test_serve_step_fused_append_matches_unfused():
    """Full serve_step: fused-append decode == unfused decode == ref decode
    (greedy tokens identical; caches bit-exact between fused and unfused)."""
    cfg = get_config("granite-3-2b").reduced()
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=64))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    _, state0 = prefill(params, {"tokens": toks})

    def decode(backend, fuse, n=3):
        serve = jax.jit(build_serve_step(cfg, mesh, hx, attn_backend=backend,
                                         fuse_append=fuse))
        state = dict(state0)
        cur = jnp.zeros((2,), jnp.int32)
        outs = []
        for _ in range(n):
            cur, state = serve(params, state, cur)
            outs.append(np.asarray(cur))
        return np.stack(outs), state

    t_ref, _ = decode("ref", None)
    t_unf, s_unf = decode("pallas-interpret", False)
    t_fus, s_fus = decode("pallas-interpret", True)
    np.testing.assert_array_equal(t_unf, t_ref)
    np.testing.assert_array_equal(t_fus, t_unf)
    np.testing.assert_array_equal(np.asarray(s_fus["kcache"]),
                                  np.asarray(s_unf["kcache"]))
    np.testing.assert_array_equal(np.asarray(s_fus["vcache"]),
                                  np.asarray(s_unf["vcache"]))
