"""On-device sampling: device sampler == numpy oracle, bit-exact.

The sampler (serving/sampling.py) is a fused epilogue inside
``serve_step`` — tokens are chosen on device and the host only ever sees
the result.  That is only safe if the device decision is *pinned*: these
tests hold ``sample_tokens`` bit-exact against the independent numpy
``sample_oracle`` on synthetic logits (full kind lattice, mixed-policy
batches) and through the real model/engine across
{greedy, temperature, top_k, top_p} x {ref, pallas-interpret} x
{fp, kv8} (+ the w8a16-quantized lm_head), where the logits themselves
come out of the decode step the engine runs.

Also covered: ``SamplingParams`` validation, per-request seed
decorrelation, greedy's bit-identity with the plain argmax epilogue, and
the engine-level contracts (reproducible streams, per-request overrides,
rejection when the engine has no sampler armed).
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import build_serve_step, make_prefill_step
from repro.models.transformer import init_params
from repro.serving import DECODE, DecodeEngine, Request
from repro.serving.sampling import (SAMPLING_KINDS, SamplingParams,
                                    gumbel_noise, request_seed,
                                    sample_oracle, sample_tokens)
from repro.utils import make_mesh, set_mesh

CFG = get_config("granite-3-2b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MESH = make_mesh((1, 1), ("data", "model"))

KIND_PARAMS = {
    "greedy": SamplingParams(kind="greedy"),
    "temperature": SamplingParams(kind="temperature", temperature=0.7,
                                  seed=11),
    "top_k": SamplingParams(kind="top_k", temperature=0.9, top_k=20,
                            seed=11),
    "top_p": SamplingParams(kind="top_p", temperature=0.9, top_p=0.8,
                            seed=11),
}


def _hx(backend="ref", kv8=False, w8=False):
    return HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16,
                       attn_backend=backend, prefill_backend=backend,
                       kv_cache_bits=8 if kv8 else 16, lm_head_w8=w8)


def _decoding_engine(hx, sp, *, n=2, max_new=8):
    """Engine mid-decode: ``n`` admitted requests, a few tokens in."""
    rng = np.random.default_rng(5)
    with set_mesh(MESH):
        eng = DecodeEngine(CFG, PARAMS, build_serve_step(CFG, MESH, hx),
                           make_prefill_step(CFG, MESH, hx), max_batch=n,
                           max_seq=48, hx=hx, tp_width=1, sampling=sp)
        reqs = [Request(rid=i, prompt=rng.integers(0, CFG.vocab, 9).tolist(),
                        max_new_tokens=max_new) for i in range(n)]
        for r in reqs:
            eng.submit(r)
        for _ in range(2):
            eng.step()
    assert all(r.state == DECODE for r in reqs), [r.state for r in reqs]
    return eng, reqs


def _leaves(state):
    return tuple(np.asarray(state[k]) for k in
                 ("sample_temp", "sample_topk", "sample_topp",
                  "sample_seed", "sample_idx"))


# ------------------------------------------------- pure sampler vs oracle
def test_sampler_matches_oracle_synthetic_mixed_batch():
    """One batch mixing every policy row-wise — the engine's real shape
    (per-request leaves), pinned bit-exact against the numpy oracle."""
    rng = np.random.default_rng(0)
    b, v = 8, 512
    logits = rng.normal(0, 4, (b, v)).astype(np.float32)
    temp = np.asarray([0.0, 0.0, 0.5, 1.0, 2.0, 0.9, 0.9, 0.7], np.float32)
    topk = np.asarray([0, 7, 0, 3, 0, 50, 0, 5], np.int32)
    topp = np.asarray([1.0, 1.0, 0.3, 1.0, 0.8, 1.0, 0.95, 0.5], np.float32)
    seed = np.arange(b).astype(np.uint32) * 13 + 1
    idx = np.asarray([0, 1, 2, 0, 7, 3, 100, 5], np.int32)
    dev = np.asarray(sample_tokens(jnp.asarray(logits), jnp.asarray(temp),
                                   jnp.asarray(topk), jnp.asarray(topp),
                                   jnp.asarray(seed), jnp.asarray(idx)))
    want = sample_oracle(logits, temp, topk, topp, seed, idx)
    assert np.array_equal(dev, want), (dev, want)
    # greedy rows are bit-identical to the plain argmax epilogue
    assert np.array_equal(dev[:2], np.argmax(logits[:2], axis=-1))


def test_sampler_idx_advances_stream():
    """Different ``sample_idx`` -> different Gumbel draw -> (generically)
    different token; same idx replays the same token."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(0, 1, (1, 256)).astype(np.float32))
    args = (jnp.asarray([1.0], jnp.float32), jnp.asarray([0], jnp.int32),
            jnp.asarray([1.0], jnp.float32), jnp.asarray([3], jnp.uint32))
    t0 = np.asarray(sample_tokens(logits, *args, jnp.asarray([0])))
    t0b = np.asarray(sample_tokens(logits, *args, jnp.asarray([0])))
    ts = [int(np.asarray(sample_tokens(logits, *args, jnp.asarray([i])))[0])
          for i in range(8)]
    assert np.array_equal(t0, t0b)
    assert len(set(ts)) > 1, ts


def test_request_seed_decorrelates_requests():
    seeds = {request_seed(7, rid) for rid in range(200)}
    assert len(seeds) == 200
    # and the derived noise streams differ row-to-row
    g = np.asarray(gumbel_noise(np.asarray(sorted(seeds))[:4],
                                np.zeros(4, np.int32), 64))
    assert len({tuple(np.round(r, 6)) for r in g}) == 4


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(kind="nucleus")
    with pytest.raises(ValueError):
        SamplingParams(kind="temperature", temperature=0.0)
    with pytest.raises(ValueError):
        SamplingParams(kind="top_k", top_k=0)
    with pytest.raises(ValueError):
        SamplingParams(kind="top_p", top_p=1.5)
    # foreign knobs collapse to no-ops in the device row encoding
    assert SamplingParams(kind="greedy", temperature=9.0).row() \
        == (0.0, 0, 1.0)
    assert SamplingParams(kind="top_k", temperature=0.5, top_k=4,
                          top_p=0.1).row() == (0.5, 4, 1.0)
    assert SamplingParams(kind="temperature", temperature=2.0,
                          top_k=9).row() == (2.0, 0, 1.0)


# ------------------------------------- through the model: the full lattice
@pytest.mark.parametrize("backend,kv8", [("ref", False), ("ref", True),
                                         ("pallas-interpret", False),
                                         ("pallas-interpret", True)])
@pytest.mark.parametrize("kind", SAMPLING_KINDS)
def test_device_sampler_matches_oracle_through_model(kind, backend, kv8):
    """Decode real engine state for 3 steps; at each step the fused
    epilogue's token must equal the numpy oracle applied to that step's
    logits and the pre-step ``sample_*`` leaves."""
    hx = _hx(backend, kv8=kv8)
    eng, _ = _decoding_engine(hx, KIND_PARAMS[kind])
    step_l = jax.jit(build_serve_step(CFG, MESH, hx, return_logits=True))
    st, cur = eng.state, eng.cur_tokens
    with set_mesh(MESH):
        for _ in range(3):
            leaves = _leaves(st)
            (toks, logits), st = step_l(eng.params, st, cur)
            want = sample_oracle(np.asarray(logits), *leaves)
            assert np.array_equal(np.asarray(toks), want), (kind, backend)
            cur = toks


def test_device_sampler_matches_oracle_w8a16_lm_head():
    """The epilogue consumes the w8a16-quantized lm_head logits
    unchanged — oracle parity holds over the quantized matmul too."""
    hx = _hx("ref", w8=True)
    eng, _ = _decoding_engine(hx, KIND_PARAMS["top_p"])
    step_l = jax.jit(build_serve_step(CFG, MESH, hx, return_logits=True))
    with set_mesh(MESH):
        leaves = _leaves(eng.state)
        (toks, logits), _ = step_l(eng.params, eng.state, eng.cur_tokens)
    want = sample_oracle(np.asarray(logits), *leaves)
    assert np.array_equal(np.asarray(toks), want)


# --------------------------------------------------- engine-level contracts
def _run_engine(sp, *, seed=5, n=3, max_new=6):
    rng = np.random.default_rng(seed)
    hx = _hx("ref")
    with set_mesh(MESH):
        eng = DecodeEngine(CFG, PARAMS, build_serve_step(CFG, MESH, hx),
                           make_prefill_step(CFG, MESH, hx), max_batch=n,
                           max_seq=48, hx=hx, tp_width=1, sampling=sp)
        reqs = [Request(rid=i, prompt=rng.integers(0, CFG.vocab, 9).tolist(),
                        max_new_tokens=max_new) for i in range(n)]
        for r in reqs:
            eng.submit(r)
        eng.run_to_completion()
    assert all(r.done for r in reqs)
    return [tuple(r.out_tokens) for r in reqs]


def test_engine_sampled_streams_reproducible():
    sp = KIND_PARAMS["top_p"]
    a = _run_engine(sp)
    b = _run_engine(sp)
    assert a == b
    # a different base seed moves the streams (seed actually reaches the
    # device PRNG; 512-way vocab x 15 sampled tokens can't all collide)
    c = _run_engine(dataclasses.replace(sp, seed=99))
    assert a != c


def test_engine_greedy_sampling_matches_argmax_engine():
    """kind='greedy' through the sampler leaves is bit-identical to the
    sampler-free engine (the pre-sampling argmax path)."""
    assert _run_engine(KIND_PARAMS["greedy"]) == _run_engine(None)


def test_per_request_sampling_override():
    """Engine-default greedy + one request overriding to top-p: the
    greedy request's stream matches the all-greedy run; the override
    request actually samples (differs from its greedy self)."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, CFG.vocab, 9).tolist() for _ in range(2)]
    hx = _hx("ref")

    def run(override):
        with set_mesh(MESH):
            eng = DecodeEngine(CFG, PARAMS, build_serve_step(CFG, MESH, hx),
                               make_prefill_step(CFG, MESH, hx), max_batch=2,
                               max_seq=48, hx=hx, tp_width=1,
                               sampling=KIND_PARAMS["greedy"])
            reqs = [Request(rid=i, prompt=list(p), max_new_tokens=6,
                            sampling=(override if i == 1 else None))
                    for i, p in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            eng.run_to_completion()
        return [tuple(r.out_tokens) for r in reqs]

    plain = run(None)
    mixed = run(SamplingParams(kind="temperature", temperature=0.6, seed=3))
    assert mixed[0] == plain[0]          # untouched request: bit-identical
    assert mixed[1] != plain[1]          # override request: really sampled


def test_per_request_sampling_needs_engine_sampler():
    hx = _hx("ref")
    with set_mesh(MESH):
        eng = DecodeEngine(CFG, PARAMS, build_serve_step(CFG, MESH, hx),
                           make_prefill_step(CFG, MESH, hx), max_batch=2,
                           max_seq=48, hx=hx, tp_width=1)
        req = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2,
                      sampling=KIND_PARAMS["top_p"])
        with pytest.raises(ValueError, match="sampling"):
            eng.submit(req)
