"""Trace-driven workload model (serving/workload.py): row validation,
JSONL round-trip + schema versioning, arrival-process generators (incl.
the pin that keeps legacy ``--traffic poisson`` behavior reproducible),
tenant-mix parsing, deterministic prompt materialization, and the
trace -> engine ``Request`` bridge.  Pure host python — no engine."""
import dataclasses
import json

import numpy as np
import pytest

from repro.serving.workload import (TenantSpec, TraceRow,
                                    bursty_arrival_steps, generate_trace,
                                    load_trace, parse_tenants,
                                    poisson_arrival_steps, prompt_tokens,
                                    requests_from_trace, save_trace,
                                    trace_id)


# ------------------------------------------------------------------ rows
def test_row_json_roundtrip_is_identity():
    row = TraceRow(rid=3, arrival_step=7, tenant="chat",
                   slo_class="batch", prompt_len=40, max_tokens=9,
                   session_id="s3", seed=12345)
    assert TraceRow.from_json(row.to_json()) == row


def test_row_rejects_unknown_fields_and_bad_values():
    with pytest.raises(AssertionError, match="unknown trace row fields"):
        TraceRow.from_json(json.dumps({"rid": 0, "arrival_step": 0,
                                       "surprise": 1}))
    for bad in (dict(rid=-1), dict(arrival_step=-2), dict(tenant=""),
                dict(slo_class="gold"), dict(prompt_len=0),
                dict(max_tokens=0), dict(seed=-1)):
        with pytest.raises(AssertionError):
            TraceRow(**{"rid": 0, "arrival_step": 0, **bad}).validate()


# ------------------------------------------------------------- trace I/O
def test_save_load_roundtrip(tmp_path):
    rows = generate_trace(17, arrival="bursty", rate=1.0, seed=3)
    path = tmp_path / "t.jsonl"
    save_trace(path, rows, meta={"note": "test"})
    loaded = load_trace(path)
    assert loaded == rows
    assert trace_id(loaded) == trace_id(rows)


def test_load_refuses_unknown_schema_and_kind(tmp_path):
    rows = generate_trace(3, seed=0)
    path = tmp_path / "t.jsonl"
    save_trace(path, rows)
    lines = path.read_text().splitlines()

    head = json.loads(lines[0])
    head["schema"] = 99
    path.write_text("\n".join([json.dumps(head)] + lines[1:]) + "\n")
    with pytest.raises(ValueError, match="unsupported trace schema"):
        load_trace(path)

    head = json.loads(lines[0])
    head["kind"] = "not-a-trace"
    path.write_text("\n".join([json.dumps(head)] + lines[1:]) + "\n")
    with pytest.raises(ValueError, match="not a helix-trace"):
        load_trace(path)


def test_load_refuses_duplicate_rids(tmp_path):
    rows = generate_trace(2, seed=0)
    dup = dataclasses.replace(rows[1], rid=rows[0].rid)
    path = tmp_path / "t.jsonl"
    save_trace(path, [rows[0], dup])
    with pytest.raises(AssertionError, match="duplicate rids"):
        load_trace(path)


def test_trace_id_stable_and_content_sensitive():
    rows = generate_trace(5, seed=7)
    assert trace_id(rows) == trace_id(list(rows))
    bumped = [dataclasses.replace(rows[0], max_tokens=rows[0].max_tokens + 1),
              *rows[1:]]
    assert trace_id(bumped) != trace_id(rows)


# -------------------------------------------------------------- arrivals
def test_generated_poisson_arrivals_pin_legacy_process():
    """The regression pin for satellite #5: a default single-tenant
    poisson trace arrives at exactly the steps the old serve.py helper
    produced — and serve.py still re-exports that helper."""
    from repro.launch.serve import poisson_arrival_steps as serve_reexport
    assert serve_reexport is poisson_arrival_steps
    for n, rate, seed in ((1, 0.25, 0), (16, 0.5, 0), (32, 2.0, 9)):
        rows = generate_trace(n, arrival="poisson", rate=rate, seed=seed)
        assert [r.arrival_step for r in rows] == \
            poisson_arrival_steps(n, rate, seed)


def test_poisson_arrivals_sorted_and_seeded():
    a = poisson_arrival_steps(64, 0.5, seed=1)
    assert a == sorted(a) and len(a) == 64
    assert a == poisson_arrival_steps(64, 0.5, seed=1)
    assert a != poisson_arrival_steps(64, 0.5, seed=2)


def test_bursty_arrivals_form_closed_bursts():
    steps = bursty_arrival_steps(20, rate=1.0, burst=4, seed=0)
    assert len(steps) == 20 and steps == sorted(steps)
    # requests land in groups of exactly `burst` sharing one step value
    # (closed bursts), except possibly the final partial burst
    for i in range(0, 20, 4):
        assert len(set(steps[i:i + 4])) == 1, steps
    assert steps == bursty_arrival_steps(20, rate=1.0, burst=4, seed=0)


def test_generate_trace_rejects_unknown_arrival():
    with pytest.raises(ValueError, match="unknown arrival shape"):
        generate_trace(4, arrival="diurnal")


# ----------------------------------------------------------- tenant mix
def test_parse_tenants_full_and_defaulted_fields():
    specs = parse_tenants("chat:3:interactive,jobs:1:batch:5, solo")
    assert [t.name for t in specs] == ["chat", "jobs", "solo"]
    assert specs[0] == TenantSpec("chat", weight=3.0,
                                  slo_class="interactive", share=3.0)
    assert specs[1].slo_class == "batch" and specs[1].share == 5.0
    # omitted fields: weight 1.0, interactive, share = weight
    assert specs[2] == TenantSpec("solo")
    with pytest.raises(AssertionError, match="slo"):
        parse_tenants("chat:1:gold")
    with pytest.raises(AssertionError, match="no tenants"):
        parse_tenants(" , ")


def test_generate_trace_tenant_mix_and_length_ranges():
    tenants = (TenantSpec("a", share=3.0, prompt_len=(8, 16),
                          max_tokens=(2, 4)),
               TenantSpec("b", slo_class="batch", share=1.0))
    rows = generate_trace(400, arrival="batch", tenants=tenants,
                          prompt_len=32, max_tokens=6, seed=0)
    by = {"a": [r for r in rows if r.tenant == "a"],
          "b": [r for r in rows if r.tenant == "b"]}
    assert len(by["a"]) + len(by["b"]) == 400
    # shares 3:1 -> tenant a gets ~75% of arrivals
    assert 0.65 < len(by["a"]) / 400 < 0.85
    assert all(8 <= r.prompt_len <= 16 and 2 <= r.max_tokens <= 4
               and r.slo_class == "interactive" for r in by["a"])
    # spec leaves lengths None -> the driver defaults fill in, degenerate
    # (lo == hi) ranges stay exact
    assert all(r.prompt_len == 32 and r.max_tokens == 6
               and r.slo_class == "batch" for r in by["b"])


def test_tenant_mix_never_perturbs_arrival_process():
    """Adding tenants redraws assignment/lengths but the arrival steps
    come from the base seed — identical with 1 or N tenants."""
    solo = generate_trace(25, arrival="poisson", rate=0.7, seed=4)
    duo = generate_trace(25, arrival="poisson", rate=0.7, seed=4,
                         tenants=parse_tenants("x:2,y:1:batch"))
    assert ([r.arrival_step for r in solo]
            == [r.arrival_step for r in duo])


# -------------------------------------------------- prompts -> requests
def test_prompt_tokens_deterministic_per_row_seed():
    row = TraceRow(rid=0, arrival_step=0, prompt_len=24, seed=99)
    a = prompt_tokens(row, vocab=1000)
    assert a == prompt_tokens(row, vocab=1000)
    assert len(a) == 24 and all(0 <= t < 1000 for t in a)
    other = prompt_tokens(dataclasses.replace(row, seed=100), vocab=1000)
    assert a != other


def test_prompt_tokens_shared_prefix_truncates():
    shared = list(range(10))
    row = TraceRow(rid=0, arrival_step=0, prompt_len=16, seed=1)
    toks = prompt_tokens(row, vocab=50, shared_prefix=shared)
    assert toks[:10] == shared and len(toks) == 16
    short = TraceRow(rid=1, arrival_step=0, prompt_len=6, seed=1)
    assert prompt_tokens(short, vocab=50, shared_prefix=shared) == shared[:6]


def test_requests_from_trace_carries_tenancy():
    rows = generate_trace(6, tenants=parse_tenants("u:2,v:1:batch"),
                          prompt_len=9, max_tokens=3, seed=2)
    rows = [dataclasses.replace(r, session_id=f"s{r.rid}") for r in rows]
    reqs = requests_from_trace(rows, vocab=128, eos_id=0)
    assert [q.rid for q in reqs] == [r.rid for r in rows]
    for q, r in zip(reqs, rows):
        assert (q.tenant, q.slo_class, q.session_id) == \
            (r.tenant, r.slo_class, r.session_id)
        assert q.max_new_tokens == r.max_tokens and q.eos_id == 0
        assert q.prompt == prompt_tokens(r, 128)
        assert len(q.prompt) == r.prompt_len
