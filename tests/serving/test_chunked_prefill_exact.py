"""Chunked-vs-oneshot prefill bit-exactness across the mode lattice.

The chunked path attends each chunk to the already-cached prefix through
flash_prefill's runtime q_offset contract over a carry buffer sized to the
one-shot sequence length, so every backend must reproduce the one-shot
prefill *bit for bit*: the contiguous carry buffers, the round-robin
decode-state handoff, the first generated token, and the decode stream that
follows.  Lattice: {ref, pallas-interpret} x prune {on, off} x chunk sizes
{1, 17, T} x {global, sliding-window} x {fp16-ish, int8 kv}.  The KVP=8
shard_map case lives in tests/distributed/scripts/helix_exact.py."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.kvcache import quantize_decode_state
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import (build_serve_step, chunked_prefill_supported,
                                    finalize_chunked_prefill,
                                    init_prefill_buffers,
                                    make_chunk_prefill_step, make_prefill_step)
from repro.models.transformer import init_params
from repro.utils import make_mesh

T = 19
CHUNKS = (1, 17, T)
S_CAP = 64


@functools.lru_cache(maxsize=None)
def _cfg(windowed: bool):
    cfg = get_config("granite-3-2b").reduced()
    if windowed:
        # one local + one global layer (gemma3-style mix) without paying for
        # gemma3's 6-layer reduced period
        cfg = dataclasses.replace(cfg, local_window=8, local_ratio=1)
    return cfg


@functools.lru_cache(maxsize=None)
def _params(windowed: bool):
    return init_params(_cfg(windowed), jax.random.PRNGKey(0))


def _mesh1():
    return make_mesh((1, 1), ("data", "model"))


def _toks(cfg, b=1, t=T, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab)


def _oneshot(cfg, mesh, hx, params, toks):
    prefill = jax.jit(make_prefill_step(cfg, mesh, hx, s_cap=S_CAP))
    last_logits, state = prefill(params, {"tokens": toks})
    return int(jnp.argmax(last_logits[0, :cfg.vocab])), state


def _chunked(cfg, mesh, hx, params, toks, chunk):
    step = jax.jit(make_chunk_prefill_step(cfg, mesh, hx))
    t = toks.shape[1]
    bufs = init_prefill_buffers(cfg, toks.shape[0], t)
    pos = 0
    while pos < t:
        c = min(chunk, t - pos)
        nt, bufs = step(params, toks[:, pos:pos + c], bufs,
                        jnp.asarray(pos, jnp.int32))
        pos += c
    state = finalize_chunked_prefill(cfg, hx, bufs, t, s_cap=S_CAP, kvp=1)
    return int(nt[0, -1]), state, bufs


def _decode_n(cfg, mesh, hx, params, state, first_tok, n=3):
    serve = jax.jit(build_serve_step(cfg, mesh, hx))
    state = dict(state)
    cur = jnp.full((1,), first_tok, jnp.int32)
    outs = []
    for _ in range(n):
        cur, state = serve(params, state, cur)
        outs.append(int(cur[0]))
    return outs, state


@pytest.mark.parametrize("backend,prune", [("ref", True),
                                           ("pallas-interpret", True),
                                           ("pallas-interpret", False)],
                         ids=["ref", "pallas-prune", "pallas-dense"])
@pytest.mark.parametrize("windowed", [False, True],
                         ids=["global", "windowed"])
def test_chunked_prefill_bit_exact(backend, prune, windowed):
    """Chunked == one-shot: rr-layout cache state bit-identical and the
    greedy continuation (first token + 3 decode steps incl. final caches)
    identical, for chunk sizes {1, 17, T}."""
    cfg, params = _cfg(windowed), _params(windowed)
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None,
                     prefill_backend=backend, prune_blocks=prune)
    toks = _toks(cfg)
    tok1, st1 = _oneshot(cfg, mesh, hx, params, toks)
    dec1, fin1 = _decode_n(cfg, mesh, hx, params, st1, tok1)
    for chunk in CHUNKS:
        tok2, st2, _ = _chunked(cfg, mesh, hx, params, toks, chunk)
        assert tok2 == tok1, (chunk, tok2, tok1)
        assert int(st2["total_len"]) == int(st1["total_len"])
        np.testing.assert_array_equal(np.asarray(st2["kcache"]),
                                      np.asarray(st1["kcache"]),
                                      err_msg=f"chunk={chunk}")
        np.testing.assert_array_equal(np.asarray(st2["vcache"]),
                                      np.asarray(st1["vcache"]))
        dec2, fin2 = _decode_n(cfg, mesh, hx, params, st2, tok2)
        assert dec2 == dec1, (chunk, dec2, dec1)
        for key in ("kcache", "vcache"):
            np.testing.assert_array_equal(np.asarray(fin2[key]),
                                          np.asarray(fin1[key]))


def test_chunked_buffers_match_oneshot_contiguous_cache():
    """The contiguous carry buffers themselves (pre-handoff layout) equal
    the one-shot forward's return_cache extras row for row — the rr state
    comparison above can't silently pass via matching zero padding."""
    from repro.models.transformer import forward
    cfg, params = _cfg(False), _params(False)
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None)
    toks = _toks(cfg)
    _, extras = forward(cfg, params, toks, return_cache=True)
    _, _, bufs = _chunked(cfg, mesh, hx, params, toks, 5)
    np.testing.assert_array_equal(np.asarray(bufs["kcache"]),
                                  np.asarray(extras["kcache"]))
    np.testing.assert_array_equal(np.asarray(bufs["vcache"]),
                                  np.asarray(extras["vcache"]))


def test_chunked_prefill_int8_state_bit_exact():
    """int8 KV mode: quantizing the chunked and one-shot prefill states
    (the engine's kv8 handoff) yields bit-identical payloads and scales,
    and the kv8 decode streams agree."""
    cfg, params = _cfg(False), _params(False)
    mesh = _mesh1()
    hx = HelixConfig(kvp_axes=("data",), tpa_axis=None, kv_cache_bits=8,
                     attn_backend="pallas-interpret")
    toks = _toks(cfg)
    tok1, st1 = _oneshot(cfg, mesh, hx, params, toks)
    q1 = quantize_decode_state(st1)
    for chunk in (1, 17):
        tok2, st2, _ = _chunked(cfg, mesh, hx, params, toks, chunk)
        q2 = quantize_decode_state(st2)
        assert tok2 == tok1
        for key in ("kcache", "vcache", "kscale", "vscale"):
            np.testing.assert_array_equal(np.asarray(q2[key]),
                                          np.asarray(q1[key]), err_msg=key)
    dec1, _ = _decode_n(cfg, mesh, hx, params, q1, tok1)
    dec2, _ = _decode_n(cfg, mesh, hx, params, q2, tok2)
    assert dec1 == dec2


def test_ragged_seq_lens_packing_matches_single():
    """Packed ragged chunk calls (per-request seq_lens) reproduce each
    request's solo prefill bit for bit on the valid rows: the seq_lens mask
    only ever affects pad rows for causal self-attention."""
    from repro.models.attention import prefill_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 8, 4, 16))
    k = jax.random.normal(ks[1], (2, 8, 2, 16))
    v = jax.random.normal(ks[2], (2, 8, 2, 16))
    lens = jnp.asarray([8, 5], jnp.int32)
    for backend in ("ref", "pallas-interpret"):
        packed = prefill_attention(q, k, v, causal=True, backend=backend,
                                   seq_lens=lens)
        solo0 = prefill_attention(q[:1], k[:1], v[:1], causal=True,
                                  backend=backend)
        np.testing.assert_array_equal(np.asarray(packed[0]),
                                      np.asarray(solo0[0]))
        # row 1: valid query rows [0, 5) match its solo run over its own
        # 5-long kv prefix padded into the same S=8 operand
        k1 = k.at[1, 5:].set(0.0)[1:]
        v1 = v.at[1, 5:].set(0.0)[1:]
        solo1 = prefill_attention(q[1:], k1, v1, causal=True,
                                  backend=backend)
        np.testing.assert_array_equal(np.asarray(packed[1, :5]),
                                      np.asarray(solo1[0, :5]))


def test_unsupported_archs_fall_back():
    """Non-attention-only archs refuse the chunked builders (the engine
    falls back to one-shot prefill for them)."""
    ssm = get_config("mamba2-780m").reduced()
    assert not chunked_prefill_supported(ssm)
    with pytest.raises(AssertionError):
        make_chunk_prefill_step(ssm, None, HelixConfig(kvp_axes=()))
    assert chunked_prefill_supported(_cfg(False))
