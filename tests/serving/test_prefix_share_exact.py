"""Prefix sharing + grouped shared-prefix decode: engine-level exactness.

The PR's serving-layer acceptance criteria:

  * shared-prefix engine streams are token-for-token identical to the
    unshared engine across {ref, pallas-interpret} x {fp, kv8} x
    {grouped decode on/off} x {prune on/off} — sharing and grouping are
    pure memory/bandwidth optimisations, never numerics;
  * divergence immediately after the shared prefix: a prompt that IS the
    registered prefix (every generated token diverges from the first
    appended one) stays bit-exact — the admission path CoWs the shared
    partial page before the first append writes it;
  * admission regression: a same-prefix batch whose *unshared* page
    demand exceeds the pool still admits (and completes) shared, because
    fits/can_admit_now charge only the unshared suffix;
  * the grouped engine actually forms groups mid-run (group_np > 0) and
    dissolves them by drain time (leaves reset to singleton defaults).

Kernel-level grouped exactness (two-pass prefix+suffix vs ungrouped) is
covered in tests/kernels/test_flash_decode_paged.py; the accounting
bound in tests/kernels/test_block_accounting.py.
"""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import (build_serve_step, make_chunk_prefill_step,
                                    make_prefill_step)
from repro.models.transformer import init_params
from repro.serving import DecodeEngine, Request
from repro.utils import make_mesh, set_mesh

CFG = get_config("granite-3-2b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MESH = make_mesh((1, 1), ("data", "model"))

_RNG = np.random.default_rng(11)
PREFIX = _RNG.integers(0, CFG.vocab, 40).tolist()
SUFFIXES = [_RNG.integers(0, CFG.vocab, n).tolist() for n in (7, 9, 5)]
PROMPTS = [PREFIX + s for s in SUFFIXES]


def _hx(backend="ref", *, grouped=False, kv8=False, prune=True):
    return HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16,
                       attn_backend=backend, prefill_backend=backend,
                       paged_kv=True, kv_cache_bits=8 if kv8 else 16,
                       prune_blocks=prune, grouped_decode=grouped)


def _engine(hx, *, share, max_batch=3, max_seq=96, chunk=8,
            pool_blocks=None):
    with set_mesh(MESH):
        serve = build_serve_step(CFG, MESH, hx)
        prefill = make_prefill_step(CFG, MESH, hx)
        cs = make_chunk_prefill_step(CFG, MESH, hx)
        return DecodeEngine(CFG, PARAMS, serve, prefill, max_batch=max_batch,
                            max_seq=max_seq, hx=hx, chunk_tokens=chunk,
                            chunk_prefill_step=cs, tp_width=1,
                            pool_blocks=pool_blocks, prefix_share=share)


def _run(hx, *, share, prompts=PROMPTS, max_new=6, probe=None):
    """Staggered submission: request 0 prefills fully (registering its
    prefix) before the same-prefix followers arrive — an immediate batch
    would race registration, which happens at prefill finalize."""
    eng = _engine(hx, share=share)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    with set_mesh(MESH):
        eng.submit(reqs[0])
        while reqs[0].state != "decode":
            eng.step()
        for r in reqs[1:]:
            eng.submit(r)
        while not all(r.done for r in reqs):
            eng.step()
            if probe is not None:
                probe(eng)
    return [tuple(r.out_tokens) for r in reqs], eng


_BASELINES: dict[tuple, list] = {}


def _baseline(backend, kv8):
    key = (backend, kv8)
    if key not in _BASELINES:
        _BASELINES[key], _ = _run(_hx(backend, kv8=kv8), share=False)
    return _BASELINES[key]


# ------------------------------------------------------ bit-exact lattice
@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
@pytest.mark.parametrize("kv8", [False, True])
@pytest.mark.parametrize("grouped", [False, True])
@pytest.mark.parametrize("prune", [True, False])
def test_prefix_share_stream_parity(backend, kv8, grouped, prune):
    streams, eng = _run(
        _hx(backend, grouped=grouped, kv8=kv8, prune=prune), share=True)
    assert streams == _baseline(backend, kv8)
    stats = eng.pool_stats()
    assert stats["prefix_hit_rate"] > 0          # followers actually matched
    assert stats["pages_shared_peak"] >= 2       # full prefix pages mapped 2x
    assert eng.pool.free_count == eng.pool.capacity   # refcounts drained


def test_grouped_engine_forms_and_dissolves_groups():
    """The grouped engine's group_np leaf goes positive while same-prefix
    requests decode together and returns to the singleton default (all
    zeros, identical-to-ungrouped semantics) once they retire."""
    seen = []

    def probe(eng):
        seen.append(np.asarray(eng.state["group_np"]).max())

    _, eng = _run(_hx("ref", grouped=True), share=True, probe=probe)
    assert max(seen) >= 2                        # >= 2 full pages grouped
    assert np.asarray(eng.state["group_np"]).max() == 0
    assert eng.pool.free_count == eng.pool.capacity


# --------------------------------------- divergence right after the prefix
@pytest.mark.parametrize("grouped", [False, True])
def test_divergence_immediately_after_prefix(grouped):
    """A follower whose prompt IS the shared prefix: its very first
    appended token lands right after the shared span, so the admission
    CoW of the shared partial page is what keeps request 0's cache
    intact.  Streams must match the unshared engine exactly."""
    prompts = [list(PREFIX), list(PREFIX)]
    base, _ = _run(_hx("ref"), share=False, prompts=prompts)
    streams, eng = _run(_hx("ref", grouped=grouped), share=True,
                        prompts=prompts)
    assert streams == base
    assert streams[0] == streams[1]              # same prompt, same stream
    assert eng.pool_stats()["prefix_hit_rate"] > 0
    assert eng.pool.free_count == eng.pool.capacity


# --------------------------------------------------- admission regression
def test_same_prefix_batch_admits_shared_when_unshared_exceeds_pool():
    """fits/can_admit_now charge only the unshared suffix: with request 0
    holding 4 of 7 pool pages, an unshared follower (4 pages) could never
    be admitted concurrently, but the same-prefix follower shares 2 full
    pages and walks straight in."""
    hx = _hx("ref")
    eng = _engine(hx, share=True, max_batch=3, max_seq=96, pool_blocks=8)
    assert eng.pool.capacity == 7
    r0 = Request(rid=0, prompt=list(PROMPTS[0]), max_new_tokens=12)
    with set_mesh(MESH):
        eng.submit(r0)
        while r0.state != "decode":
            eng.step()
        # r0: pages_for(47+1) = 4 pages held -> 3 free
        assert eng.pool.free_count == 3
        followers = [Request(rid=i, prompt=list(PROMPTS[i]),
                             max_new_tokens=3) for i in (1, 2)]
        for r in followers:
            assert eng.sched.fits(r)             # suffix-only charge
            eng.submit(r)
        eng.step()
        # both placed immediately despite 2 x 4 > 3 free pages unshared
        assert all(r.state in ("prefill", "decode") for r in followers)
        eng.run_to_completion()
    assert all(r.finish_reason == "max_tokens" for r in followers)
    assert eng.pool_stats()["pages_shared_peak"] >= 2
    assert eng.pool.free_count == eng.pool.capacity
