"""Host-tier spill/restore: engine-level exactness + the fault matrix.

The PR's acceptance criteria above the store itself:

  * preempt -> spill -> resume produces token streams bit-identical to a
    never-preempted run, with **zero re-prefill chunks** (the request
    never re-enters PREFILL), across {ref, pallas-interpret} x {fp, kv8}
    on the paged engine — int8 payloads and scale planes restore as exact
    bytes, not a re-quantized copy;
  * EVERY injected fault (restore_fail / corrupt / store_full / delay)
    degrades to the counted re-prefill fallback with the same streams —
    graceful degradation, never divergence;
  * session KV: a retired session's next turn restores its history
    instead of re-prefilling it (zero re-prefill chunks), bit-exact vs a
    sessionless engine given the same turn-2 prompt;
  * a delayed restore holds only the restoring slot — concurrent streams
    keep decoding (slow host tier degrades the victim's TTFT, not TTL).

Never-preempted baselines are computed once per (backend, kv8) cell and
cached at module scope — the fault matrix reuses them.
"""
import numpy as np
import pytest
import jax

from repro.configs import get_config
from repro.core.sharding import HelixConfig
from repro.models.model_zoo import (build_serve_step, make_chunk_prefill_step,
                                    make_prefill_step)
from repro.models.transformer import init_params
from repro.serving import DecodeEngine, Request
from repro.serving.faults import FaultPlan
from repro.utils import make_mesh, set_mesh

CFG = get_config("granite-3-2b").reduced()
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
MESH = make_mesh((1, 1), ("data", "model"))

CHUNK = 4
LENGTHS = (14, 9)
MAX_NEW = 5
PREEMPT_AFTER = 2


def _hx(backend, kv8):
    return HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16,
                       attn_backend=backend, prefill_backend=backend,
                       paged_kv=True, kv_cache_bits=8 if kv8 else 16)


def _engine(hx, **kw):
    with set_mesh(MESH):
        serve = build_serve_step(CFG, MESH, hx)
        prefill = make_prefill_step(CFG, MESH, hx)
        cs = make_chunk_prefill_step(CFG, MESH, hx)
        return DecodeEngine(CFG, PARAMS, serve, prefill, max_batch=2,
                            max_seq=64, hx=hx, chunk_tokens=CHUNK,
                            chunk_prefill_step=cs, tp_width=1, **kw)


def _prompts(seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab, n).tolist() for n in LENGTHS]


def _run(hx, *, host_pages=0, fault_plan=None, preempt=False,
         session_kv=False):
    eng = _engine(hx, host_pages=host_pages, fault_plan=fault_plan,
                  session_kv=session_kv)
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=MAX_NEW)
            for i, p in enumerate(_prompts())]
    preempted = False
    post_prefills = 0
    with set_mesh(MESH):
        for r in reqs:
            eng.submit(r)
        for _ in range(500):
            if all(r.done for r in reqs):
                break
            eng.step()
            if (preempt and not preempted
                    and len(reqs[0].out_tokens) >= PREEMPT_AFTER
                    and reqs[0].state == "decode"):
                eng.preempt(0)
                preempted = True
            if preempted:
                post_prefills += reqs[0].state == "prefill"
    assert all(r.done for r in reqs)
    assert not preempt or preempted
    assert eng.pool.free_count == eng.pool.capacity
    if eng.store is not None:
        eng.store.check_invariants()
    return [tuple(r.out_tokens) for r in reqs], eng, post_prefills


_BASELINES: dict = {}


def _baseline(backend, kv8):
    key = (backend, kv8)
    if key not in _BASELINES:
        streams, eng, _ = _run(_hx(backend, kv8))
        assert eng.metrics.summary()["preempts"] == 0
        _BASELINES[key] = streams
    return _BASELINES[key]


FAULTS = {
    "none": None,
    "restore_fail": FaultPlan(seed=1, restore_fail=1.0),
    "corrupt": FaultPlan(seed=2, corrupt=1.0),
    "store_full": FaultPlan(seed=3, store_full=1.0),
    "delay": FaultPlan(seed=4, delay=1.0, delay_steps=3),
}


# ------------------------------------------------------------ fault matrix
@pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
@pytest.mark.parametrize("kv8", [False, True])
@pytest.mark.parametrize("fault", list(FAULTS))
def test_spill_restore_fault_matrix(backend, kv8, fault):
    base = _baseline(backend, kv8)
    streams, eng, pf = _run(_hx(backend, kv8), host_pages=64,
                            fault_plan=FAULTS[fault], preempt=True)
    assert streams == base, f"{fault}: stream diverged from baseline"
    s = eng.metrics.summary()
    assert s["preempts"] == 1
    if fault in ("none", "delay"):
        # happy path (delay = late but healthy): restored, zero re-prefill
        assert s["preempt_spills"] == 1 and s["restores"] == 1, s
        assert s["restores_failed"] == 0, s
        assert s["resume_reprefill_chunks"] == 0 and pf == 0, (s, pf)
    elif fault == "store_full":
        # the save is refused: degrade to the drop/re-prefill path
        assert s["spills"] == 0 and s["preempt_drops"] == 1, s
        assert s["resume_reprefill_chunks"] > 0 and pf > 0, (s, pf)
    else:
        # spill worked, restore failed: counted fallback
        assert s["preempt_spills"] == 1 and s["restores_failed"] >= 1, s
        assert s["resume_reprefill_chunks"] > 0 and pf > 0, (s, pf)
        if fault == "corrupt":
            assert s["checksum_mismatches"] >= 1, s


def test_delay_holds_only_the_restoring_slot():
    """While r0's restore is withheld, r1 keeps decoding: the delayed
    steps must not freeze the other stream (TTFT degradation only)."""
    hx = _hx("ref", False)
    # longer streams than the shared baseline so the held window overlaps
    # live decode on r1 — build a matching never-preempted reference
    base_eng = _engine(hx)
    base_reqs = [Request(rid=i, prompt=list(p), max_new_tokens=8)
                 for i, p in enumerate(_prompts())]
    with set_mesh(MESH):
        for r in base_reqs:
            base_eng.submit(r)
        base_eng.run_to_completion()
    base = [tuple(r.out_tokens) for r in base_reqs]

    eng = _engine(hx, host_pages=64,
                  fault_plan=FaultPlan(seed=4, delay=1.0, delay_steps=4))
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=8)
            for i, p in enumerate(_prompts())]
    with set_mesh(MESH):
        for r in reqs:
            eng.submit(r)
        while not (len(reqs[0].out_tokens) >= 2
                   and reqs[0].state == "decode"):
            eng.step()
        eng.preempt(0)
        # step until the restore job is queued (r0 re-admitted RESTORING)
        while not eng._restores:
            eng.step()
        r1_before = len(reqs[1].out_tokens)
        restoring_steps = 0
        while eng._restores:
            eng.step()
            restoring_steps += 1
        assert restoring_steps >= 2              # the delay really held
        if not reqs[1].done:
            assert len(reqs[1].out_tokens) > r1_before
        eng.run_to_completion()
    assert [tuple(r.out_tokens) for r in reqs] == base
    assert eng.metrics.summary()["resume_reprefill_chunks"] == 0


# --------------------------------------------------------------- sessions
@pytest.mark.parametrize("kv8", [False, True])
def test_session_restore_next_turn_zero_reprefill(kv8):
    """Turn 2 of a session restores turn 1's pages: same stream as a
    sessionless engine prefilling the full turn-2 prompt, but with zero
    prefill chunks run (TTFT independent of history length)."""
    hx = _hx("ref", kv8)
    rng = np.random.default_rng(3)
    turn1 = rng.integers(0, CFG.vocab, 13).tolist()
    fresh = rng.integers(0, CFG.vocab, 6).tolist()

    eng = _engine(hx, session_kv=True)
    r1 = Request(rid=0, prompt=list(turn1), max_new_tokens=4,
                 session_id="s0")
    with set_mesh(MESH):
        eng.submit(r1)
        eng.run_to_completion()
    assert r1.done
    turn2_prompt = list(turn1) + list(r1.out_tokens) + list(fresh)

    # sessionless reference: full prefill of the same turn-2 prompt
    ref_eng = _engine(hx)
    ref = Request(rid=1, prompt=list(turn2_prompt), max_new_tokens=4)
    with set_mesh(MESH):
        ref_eng.submit(ref)
        ref_eng.run_to_completion()

    r2 = Request(rid=1, prompt=list(turn2_prompt), max_new_tokens=4,
                 session_id="s0")
    prefill_steps = 0
    with set_mesh(MESH):
        eng.submit(r2)
        while not r2.done:
            eng.step()
            prefill_steps += r2.state == "prefill"
    assert tuple(r2.out_tokens) == tuple(ref.out_tokens)
    assert prefill_steps == 0                    # zero re-prefill chunks
    s = eng.metrics.summary()
    assert s["restores"] == 1 and s["resume_reprefill_chunks"] == 0, s
    assert s["spills"] >= 1, s                   # turn-1 retirement saved
    eng.store.check_invariants()


def test_session_eviction_falls_back_to_full_prefill():
    """An evicted session entry must degrade to the normal full prefill —
    same stream, counted, never a crash."""
    hx = _hx("ref", False)
    rng = np.random.default_rng(5)
    turn1 = rng.integers(0, CFG.vocab, 9).tolist()

    eng = _engine(hx, session_kv=True)
    r1 = Request(rid=0, prompt=list(turn1), max_new_tokens=3,
                 session_id="s0")
    with set_mesh(MESH):
        eng.submit(r1)
        eng.run_to_completion()
    eng.store.drop("session:s0")                 # simulate eviction
    turn2_prompt = list(turn1) + list(r1.out_tokens) + [5, 6, 7]

    ref_eng = _engine(hx)
    ref = Request(rid=1, prompt=list(turn2_prompt), max_new_tokens=3)
    with set_mesh(MESH):
        ref_eng.submit(ref)
        ref_eng.run_to_completion()

    r2 = Request(rid=1, prompt=list(turn2_prompt), max_new_tokens=3,
                 session_id="s0")
    with set_mesh(MESH):
        eng.submit(r2)
        eng.run_to_completion()
    assert tuple(r2.out_tokens) == tuple(ref.out_tokens)
    assert eng.metrics.summary()["restores"] == 0


# ------------------------------------------------------------- guard rails
def test_host_tier_requires_paged_kv():
    hx = HelixConfig(kvp_axes=(), tpa_axis=None, attn_block_s=16)
    with set_mesh(MESH):
        serve = build_serve_step(CFG, MESH, hx)
        prefill = make_prefill_step(CFG, MESH, hx)
        with pytest.raises(ValueError, match="paged"):
            DecodeEngine(CFG, PARAMS, serve, prefill, max_batch=2,
                         max_seq=64, hx=hx, host_pages=8)


def test_preempt_without_store_still_drops_cleanly():
    """No host tier: preemption keeps the PR-7 drop semantics and the
    split counters record it as a drop."""
    streams, eng, pf = _run(_hx("ref", False), preempt=True)
    assert streams == _baseline("ref", False)
    s = eng.metrics.summary()
    assert s["preempt_drops"] == 1 and s["preempt_spills"] == 0, s
    assert s["spills"] == 0 and s["restores"] == 0, s
    assert pf > 0
