"""Scheduler property suite: random arrival/length/eos streams through a
simulated engine loop must never double-assign a slot, lose or duplicate a
request, break token conservation, or violate the capacity invariant.

Hypothesis-driven when available (repro.testing.optional_hypothesis —
skips, never collection-errors, without it); the deterministic siblings
at the bottom always run."""
import math

from repro.serving.scheduler import (DECODE, DONE, PREFILL, QUEUED,
                                     Request, Scheduler)
from repro.testing import optional_hypothesis

given, settings, st = optional_hypothesis()


# ---------------------------------------------------------------- simulator
def predicted_tokens(prompt_len, max_new, eos_at, cap):
    """Tokens an uninterrupted request emits under engine retirement rules:
    first eos (index in its stream), then max_new, then capacity
    (cap - prompt_len tokens fit before the slot fills)."""
    n = min(max_new, cap - prompt_len)
    if eos_at is not None:
        n = min(n, eos_at + 1)
    return max(n, 0)


def simulate(specs, *, max_batch, cap, policy, chunk, preempt_at=(),
             max_steps=10_000):
    """Drive a ``Scheduler`` exactly the way ``DecodeEngine.step`` does —
    admission, one chunk of prefill progress for one same-progress group,
    one decode token per decoding slot — with token emission replaced by
    counters.  ``specs`` = [(arrival_step, prompt_len, max_new, eos_at)].
    ``preempt_at`` = {(rid, token_count)} -> preempt rid when it has that
    many tokens.  Returns (requests, scheduler, steps_run)."""
    sched = Scheduler(max_batch=max_batch, cap=cap, policy=policy)
    reqs = [Request(rid=i, prompt=list(range(p)), max_new_tokens=m,
                    eos_id=None) for i, (_, p, m, _) in enumerate(specs)]
    eos_at = {i: e for i, (_, _, _, e) in enumerate(specs)}
    arrivals = sorted(range(len(specs)), key=lambda i: specs[i][0])
    slot_req: dict[int, Request] = {}
    prefill_left: dict[int, int] = {}       # slot -> chunks remaining
    preempts = set(preempt_at)

    def emit(req, slot):
        """One generated token for req: append, retire per engine rules."""
        req.out_tokens.append(0)
        n = len(req.out_tokens)
        if eos_at[req.rid] is not None and n == eos_at[req.rid] + 1:
            reason = "eos"
        elif n >= req.max_new_tokens:
            reason = "max_tokens"
        elif sched.at_capacity(slot):
            reason = "capacity"
        else:
            return
        req.done, req.state, req.finish_reason = True, DONE, reason
        sched.release(slot)
        del slot_req[slot]

    step = 0
    while step < max_steps:
        # 1) arrivals
        while arrivals and specs[arrivals[0]][0] <= step:
            sched.submit(reqs[arrivals.pop(0)])
        # 2) admission
        for req, slot in sched.admit():
            slot_req[slot] = req
            n_toks = len(req.resume_tokens())
            prefill_left[slot] = max(math.ceil(n_toks / chunk), 1)
        # 3) one prefill chunk for the first prefilling group
        pre = sorted(s for s, r in slot_req.items() if r.state == PREFILL)
        if pre:
            lead = prefill_left[pre[0]]
            group = [s for s in pre if prefill_left[s] == lead]
            for s in group:
                prefill_left[s] -= 1
                if prefill_left[s] == 0:
                    slot_req[s].state = DECODE
                    emit(slot_req[s], s)        # first (prefill) token
        # 4) decode step
        for s in sorted(slot_req):
            if slot_req[s].state == DECODE:
                sched.on_token(s)
                emit(slot_req[s], s)
        # 5) injected preemptions
        for s, r in list(slot_req.items()):
            if (r.rid, len(r.out_tokens)) in preempts:
                preempts.discard((r.rid, len(r.out_tokens)))
                del slot_req[s]
                prefill_left.pop(s, None)
                sched.preempt(s, r)
        sched.check_invariants()
        _assert_partition(reqs, sched, slot_req)
        step += 1
        if not sched.queue and not slot_req and not arrivals:
            break
    return reqs, sched, step


def _assert_partition(reqs, sched, slot_req):
    """No lost or duplicated requests: queued / placed / done / rejected
    partition the submitted set."""
    queued = {r.rid for r in sched.queue}
    placed = {r.rid for r in slot_req.values()}
    done = {r.rid for r in reqs if r.done}
    assert not queued & placed and not queued & done and not placed & done
    # every bucketed rid is a real request (nothing invented or duplicated)
    all_rids = {r.rid for r in reqs}
    assert (queued | placed | done) <= all_rids
    # a request not in any bucket must simply not have arrived yet
    for r in reqs:
        if r.rid not in queued | placed | done:
            assert r.state == QUEUED and not r.out_tokens or r.rid in queued


# ------------------------------------------------------------- properties
SPEC = st.tuples(st.integers(0, 20),          # arrival step
                 st.integers(1, 30),          # prompt len
                 st.integers(1, 10),          # max_new
                 st.one_of(st.none(), st.integers(0, 9)))   # eos index


@given(st.lists(SPEC, min_size=1, max_size=20),
       st.sampled_from(["fcfs", "sjf"]),
       st.integers(1, 4),                     # max_batch
       st.integers(1, 8))                     # chunk
@settings(max_examples=60, deadline=None)
def test_random_streams_conserve_requests_and_tokens(specs, policy,
                                                     max_batch, chunk):
    cap = 32
    reqs, sched, steps = simulate(list(specs), max_batch=max_batch, cap=cap,
                                  policy=policy, chunk=chunk)
    # everything drained
    assert not sched.queue and all(r is None for r in sched.slot_rids)
    assert all(r.done for r in reqs)
    # conservation: emitted tokens match the retirement rules exactly
    for i, (_, p, m, e) in enumerate(specs):
        if p + 1 > cap:
            assert reqs[i].finish_reason == "rejected"
            assert reqs[i].out_tokens == []
        else:
            assert len(reqs[i].out_tokens) == predicted_tokens(p, m, e, cap)


@given(st.lists(SPEC, min_size=1, max_size=12),
       st.lists(st.tuples(st.integers(0, 11), st.integers(1, 5)),
                max_size=4),
       st.integers(1, 3))
@settings(max_examples=40, deadline=None)
def test_preemptions_never_lose_requests_or_tokens(specs, preempts,
                                                   max_batch):
    cap = 64                                  # roomy: resumes always fit
    specs = [(a, min(p, 20), m, e) for a, p, m, e in specs]
    reqs, sched, _ = simulate(specs, max_batch=max_batch, cap=cap,
                              policy="fcfs", chunk=4,
                              preempt_at=set(preempts))
    assert all(r.done for r in reqs)
    for i, (_, p, m, e) in enumerate(specs):
        assert len(reqs[i].out_tokens) == predicted_tokens(p, m, e, cap)


# ------------------------------------------------------- deterministic twins
def test_fcfs_order_and_slot_accounting():
    specs = [(0, 8, 4, None), (0, 6, 2, None), (1, 5, 3, 1), (3, 40, 2, None)]
    reqs, sched, _ = simulate(specs, max_batch=2, cap=32, policy="fcfs",
                              chunk=4)
    assert [len(r.out_tokens) for r in reqs] == [4, 2, 2, 0]
    assert reqs[2].finish_reason == "eos"
    assert reqs[3].finish_reason == "rejected"


def test_sjf_prefers_short_prefills():
    """With one slot busy and two queued, sjf admits the shorter prompt
    first even though it arrived later."""
    sched = Scheduler(max_batch=1, cap=64, policy="sjf")
    long_r = Request(rid=0, prompt=list(range(30)))
    short_r = Request(rid=1, prompt=list(range(5)))
    sched.submit(long_r)
    sched.submit(short_r)
    placed = sched.admit()
    assert [r.rid for r, _ in placed] == [1]


def test_capacity_invariant_holds_under_pressure():
    specs = [(0, 30, 10, None)] * 3           # each nearly fills cap=32
    reqs, sched, _ = simulate(specs, max_batch=2, cap=32, policy="fcfs",
                              chunk=8)
    assert all(r.finish_reason == "capacity" for r in reqs)
    assert all(len(r.out_tokens) == 2 for r in reqs)


def test_sjf_resumes_preempted_before_shorter_arrivals():
    """A preempted request resumes before fresh shorter prompts under sjf
    too — its spent prefill/decode work must not be stranded."""
    sched = Scheduler(max_batch=1, cap=64, policy="sjf")
    big = Request(rid=0, prompt=list(range(30)))
    sched.submit(big)
    [(_, slot)] = sched.admit()
    big.out_tokens.extend([7, 7])              # mid-decode
    sched.preempt(slot, big)
    sched.submit(Request(rid=1, prompt=list(range(3))))
    [(resumed, _)] = sched.admit()
    assert resumed is big and not big.preempted


def test_preempt_requeues_at_front():
    sched = Scheduler(max_batch=1, cap=32, policy="fcfs")
    a = Request(rid=0, prompt=[1, 2, 3])
    b = Request(rid=1, prompt=[4, 5])
    sched.submit(a)
    [(got, slot)] = sched.admit()
    assert got is a
    sched.submit(b)
    sched.preempt(slot, a)
    assert [r.rid for r in sched.queue] == [0, 1]
    [(resumed, _)] = sched.admit()
    assert resumed is a
